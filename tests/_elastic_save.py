"""Child A: train 3 steps on an 8-device mesh, checkpoint, dump a logit
fingerprint. Usage: _elastic_save.py <workdir>"""
import os
import sys

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"

import numpy as np                      # noqa: E402
import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.registry import reduced_arch  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.data.pipeline import DataConfig, get_batch  # noqa: E402
from repro.models import init_params, forward  # noqa: E402
from repro.optim import adamw, apply_updates  # noqa: E402
from repro.models import loss_fn  # noqa: E402
from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.parallel.sharding import param_specs, to_named  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

WORKDIR = sys.argv[1]


def main():
    assert len(jax.devices()) == 8
    cfg = reduced_arch("yi-9b", num_layers=2, d_model=128, num_heads=4,
                       num_kv_heads=4, d_ff=256, vocab_size=512, head_dim=32)
    mesh = make_mesh((4, 2), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    pshard = to_named(param_specs(params, mesh), mesh)
    params = jax.device_put(params, pshard)
    opt = adamw(1e-3)
    state = {"step": jnp.zeros((), jnp.int32), "params": params,
             "opt_state": opt.init(params)}
    dc = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3)

    @jax.jit
    def step(state, batch):
        (_, m), g = jax.value_and_grad(
            lambda p, b: loss_fn(cfg, p, b), has_aux=True)(
            state["params"], batch)
        u, os_, _ = opt.update(g, state["opt_state"], state["params"],
                               state["step"])
        return {"step": state["step"] + 1,
                "params": apply_updates(state["params"], u),
                "opt_state": os_}

    for i in range(3):
        state = step(state, get_batch(dc, i))
    mgr = CheckpointManager(WORKDIR, async_save=False)
    mgr.save(3, state)

    logits = forward(cfg, state["params"],
                     jnp.asarray(get_batch(dc, 99)["inputs"]),
                     mode="train")[0]
    np.save(os.path.join(WORKDIR, "fingerprint.npy"),
            np.asarray(logits, np.float32))
    print("SAVE_OK")


if __name__ == "__main__":
    main()
