"""Child B: restore the child-A checkpoint on a DIFFERENT device count
(4 devices, (2,2) mesh) with resharding-on-load; logits must match.
Usage: _elastic_restore.py <workdir>"""
import os
import sys

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=4"

import numpy as np                      # noqa: E402
import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402

from repro.configs.registry import reduced_arch  # noqa: E402
from repro.data.pipeline import DataConfig, get_batch  # noqa: E402
from repro.models import forward  # noqa: E402
from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.parallel.sharding import param_specs, to_named  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

WORKDIR = sys.argv[1]


def main():
    assert len(jax.devices()) == 4
    cfg = reduced_arch("yi-9b", num_layers=2, d_model=128, num_heads=4,
                       num_kv_heads=4, d_ff=256, vocab_size=512, head_dim=32)
    mesh = make_mesh((2, 2), ("data", "model"))     # HALF the devices
    mgr = CheckpointManager(WORKDIR)
    raw, meta = mgr.restore()
    assert meta["step"] == 3
    # reshard-on-load: place the host arrays with the NEW mesh's shardings
    pshard = to_named(param_specs(raw["params"], mesh), mesh)
    params = jax.device_put(raw["params"], pshard)
    dc = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3)
    logits = forward(cfg, params,
                     jnp.asarray(get_batch(dc, 99)["inputs"]),
                     mode="train")[0]
    want = np.load(os.path.join(WORKDIR, "fingerprint.npy"))
    got = np.asarray(logits, np.float32)
    err = np.abs(got - want).max()
    # bf16 matmul partial sums regroup on a different topology: tolerance
    # is bf16 noise, NOT an exactness bound (the restored *values* are
    # bit-identical; only reduction order differs).
    assert err < 5e-2, f"elastic restore mismatch: {err}"
    print(f"RESTORE_OK err={err:.2e}")


if __name__ == "__main__":
    main()
