"""Robustness drills: GramEngine under injected faults, the degradation
ladder, crash-recoverable streaming, and corrupt-artifact recovery
(DESIGN.md §13)."""
import json
import os
import warnings

import numpy as np
import pytest

from repro.gram import (CheckpointedGramStream, GramEngine,
                        VerificationError, freivalds_gram)
from repro.gram import autotune as gram_autotune
from repro.gram import stream as gram_stream
from repro.runtime import faults
from repro.runtime.faults import FaultSpec


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.reset()
    yield
    faults.reset()


def _trace(rng, requests, lo=5, hi=60):
    shapes = [(int(rng.integers(lo, hi)), int(rng.integers(lo, hi // 2 + 2)))
              for _ in range(requests)]
    return [rng.standard_normal(s).astype(np.float32) for s in shapes]


# ---------------------------------------------------------------------------
# satellite (b): exception-safe step — a failing executable never wedges
# ---------------------------------------------------------------------------

def test_failing_executable_drains_queue_as_failed():
    rng = np.random.default_rng(0)
    eng = GramEngine(slots=2, levels=0, min_bucket=16, max_retries=1)
    uids = [eng.submit(a).uid for a in _trace(rng, 6)]
    with faults.inject(FaultSpec("exec_fail", site="gram.engine.exec*")):
        finished = eng.run_to_completion()
    assert not eng.waiting, "queue did not drain"
    assert {r.uid for r in finished} == set(uids)
    for r in finished:
        assert r.status == "failed" and not r.result
        assert "InjectedFault" in r.error
    assert eng.stats()["failed"] == 6
    # and the engine recovers once the fault clears
    a = rng.standard_normal((20, 10)).astype(np.float32)
    uid = eng.submit(a).uid
    (r,) = eng.step()
    assert r.uid == uid and r.status == "ok"


def test_step_survives_real_exception_not_just_injected():
    eng = GramEngine(slots=2, levels=0, min_bucket=16, max_retries=0)
    eng.submit(np.ones((16, 16), np.float32))
    eng._local_executable = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("xla died"))
    (r,) = eng.run_to_completion()
    assert r.status == "failed" and "xla died" in r.error


# ---------------------------------------------------------------------------
# acceptance: the 10% chaos trace — 100% served, zero NaN, probes pass
# ---------------------------------------------------------------------------

def test_ten_percent_fault_trace_serves_everything_clean():
    rng = np.random.default_rng(1)
    arrays = _trace(rng, 24)
    eng = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16,
                     verify=2, max_retries=6, breaker_threshold=2,
                     verify_seed=5)
    uid_to_a = {eng.submit(a).uid: a for a in arrays}
    specs = [
        FaultSpec("poison_output", rate=0.10),              # NaN tiles
        FaultSpec("poison_output", rate=0.10, value=2.5),   # silent finite
        FaultSpec("exec_fail", rate=0.10, site="gram.engine.exec*"),
    ]
    with faults.inject(*specs, seed=7) as reg:
        finished = eng.run_to_completion()
    assert len(reg.events) > 0, "chaos trace injected nothing"
    assert len(finished) == len(arrays)
    for r in finished:
        assert r.status == "ok", (r.uid, r.error)
        assert np.isfinite(r.result).all(), "served a NaN/Inf result"
        # independent Freivalds probe with a fresh rng on every result
        passed, err = freivalds_gram(
            uid_to_a[r.uid], r.result, probes=4,
            rng=np.random.default_rng(100 + r.uid))
        assert passed, (r.uid, err)
    stats = eng.stats()
    assert stats["served"] == len(arrays) and stats["failed"] == 0
    assert stats["retries"] > 0, "10% chaos should have forced retries"


def test_guard_vetoes_silent_corruption_and_recovers():
    """A finite poisoned output passes the NaN scan; only the Freivalds
    probe catches it — the batch retries on clean data and serves."""
    rng = np.random.default_rng(2)
    # fill the bucket exactly (16x16, slots=1): the poisoned tile cannot
    # hide in padding that gets sliced away
    a = rng.standard_normal((16, 16)).astype(np.float32)
    eng = GramEngine(slots=1, levels=0, min_bucket=16, verify=2,
                     max_retries=3)
    eng.submit(a)
    with faults.inject(FaultSpec("poison_output", value=5.0, times=1)):
        (r,) = eng.run_to_completion()
    assert r.status == "ok"
    assert eng.stats()["guard_failures"] == 1
    want = a.astype(np.float64).T @ a.astype(np.float64)
    np.testing.assert_allclose(r.result, want, rtol=1e-4, atol=1e-4)


def test_finite_default_guard_catches_nan_without_probes():
    rng = np.random.default_rng(3)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)  # verify="finite"
    eng.submit(rng.standard_normal((20, 10)).astype(np.float32))
    with faults.inject(FaultSpec("poison_output", times=1)):
        (r,) = eng.run_to_completion()
    assert r.status == "ok" and np.isfinite(r.result).all()
    assert eng.stats()["guard_failures"] == 1


# ---------------------------------------------------------------------------
# the degradation ladder: breaker trips, rung escalates, service degrades
# ---------------------------------------------------------------------------

def test_breaker_escalates_to_reference_mode():
    rng = np.random.default_rng(4)
    a = rng.standard_normal((20, 10)).astype(np.float32)
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16,
                     max_retries=4, breaker_threshold=1)
    eng.submit(a)
    # two failures: rung 0 -> 1 (quarantine) -> 2 (reference mode);
    # the third attempt succeeds degraded
    with faults.inject(FaultSpec("exec_fail", times=2,
                                 site="gram.engine.exec*")):
        (r,) = eng.run_to_completion()
    assert r.status == "ok" and r.degraded
    assert r.served_by == "local:rung2"
    assert r.attempts == 3
    key = (32, 16, "float32", "cols", "native")
    assert eng._health[key].rung == 2
    assert len(eng._health[key].quarantined) == 2
    assert eng.stats()["quarantined"][str(key)]
    want = a.astype(np.float64).T @ a.astype(np.float64)
    np.testing.assert_allclose(r.result, want, rtol=1e-4, atol=1e-4)


def test_rung_is_sticky_but_counts_reset_on_success():
    rng = np.random.default_rng(5)
    eng = GramEngine(slots=2, levels=0, min_bucket=16, max_retries=4,
                     breaker_threshold=1)
    eng.submit(rng.standard_normal((16, 16)).astype(np.float32))
    with faults.inject(FaultSpec("exec_fail", times=1,
                                 site="gram.engine.exec*")):
        eng.run_to_completion()
    key = (16, 16, "float32", "cols", "native")
    assert eng._health[key].rung == 1          # sticky after recovery
    assert eng._health[key].consecutive_failures == 0
    uid = eng.submit(rng.standard_normal((16, 16)).astype(np.float32)).uid
    (r,) = eng.run_to_completion()[-1:]
    assert r.uid == uid and r.status == "ok" and r.degraded


def test_deadline_fails_fast():
    rng = np.random.default_rng(6)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    ok_uid = eng.submit(rng.standard_normal((16, 16)).astype(np.float32)).uid
    late = eng.submit(rng.standard_normal((16, 16)).astype(np.float32),
                      deadline_s=0.0).uid
    done = {r.uid: r for r in eng.run_to_completion()}
    assert done[ok_uid].status == "ok"
    assert done[late].status == "failed"
    assert "deadline" in done[late].error


def test_exec_delay_injection_slows_but_serves():
    rng = np.random.default_rng(7)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    eng.submit(rng.standard_normal((16, 16)).astype(np.float32))
    with faults.inject(FaultSpec("exec_delay", delay=0.05, times=1)):
        (r,) = eng.run_to_completion()
    assert r.status == "ok"
    assert r.latency_s >= 0.05


# ---------------------------------------------------------------------------
# satellite (a): corrupted autotune cache never aborts serving
# ---------------------------------------------------------------------------

def test_truncated_autotune_cache_warns_once_and_serves(tmp_path,
                                                        monkeypatch):
    p = tmp_path / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(p))
    # a real entry, then truncate the file mid-JSON
    gram_autotune._save_entry("k", {"mode": "reference"}, p)
    raw = p.read_text()
    p.write_text(raw[:len(raw) // 2])
    gram_autotune._memo.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert gram_autotune.load_cache(p) == {}
        assert gram_autotune.load_cache(p) == {}   # memoized: no 2nd warn
    corrupt = [x for x in w if "corrupt" in str(x.message)]
    assert len(corrupt) == 1
    # serving straight through the poisoned cache path works
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    eng.submit(np.ones((16, 16), np.float32))
    (r,) = eng.run_to_completion()
    assert r.status == "ok"
    # the next save repairs the file wholesale
    gram_autotune._save_entry("k2", {"mode": "reference"}, p)
    assert "k2" in gram_autotune.load_cache(p)


def test_cache_corrupt_fault_exercises_recovery(tmp_path, monkeypatch):
    p = tmp_path / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(p))
    gram_autotune._save_entry("k", {"mode": "reference"}, p)
    gram_autotune._memo.clear()
    with faults.inject(FaultSpec("cache_corrupt",
                                 site="gram.autotune.cache")):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            assert gram_autotune.load_cache(p) == {}


# ---------------------------------------------------------------------------
# crash-recoverable streaming: kill mid-trace, resume bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout,kw", [
    ("packed", dict(levels=1, leaf=8)),
    ("stack", dict(levels=1, block=8)),
])
def test_stream_resumes_bit_exact_after_kill(tmp_path, layout, kw):
    rng = np.random.default_rng(8)
    chunks = [rng.standard_normal((6, 12)).astype(np.float32)
              for _ in range(7)]

    s_ref = CheckpointedGramStream(12, str(tmp_path / "ref"), every=2,
                                   layout=layout, **kw)
    for c in chunks:
        s_ref.update(c)
    ref = np.asarray(s_ref.finalize(guard=True))

    # "crash" after 5 chunks: last commit at chunk 4, chunk 5 lost
    wd = str(tmp_path / "wal")
    s1 = CheckpointedGramStream(12, wd, every=2, layout=layout, **kw)
    for c in chunks[:5]:
        s1.update(c)
    del s1

    s2 = CheckpointedGramStream(12, wd, every=2, layout=layout, **kw)
    assert s2.resumed and s2.next_chunk == 4
    for i, c in enumerate(chunks):
        if i < s2.next_chunk:
            continue
        s2.update(c)
    out = np.asarray(s2.finalize())
    assert out.dtype == ref.dtype
    assert np.array_equal(ref, out), "resumed stream is not bit-exact"


def test_stream_checkpoint_rejects_mismatched_geometry(tmp_path):
    s = CheckpointedGramStream(12, str(tmp_path), every=1, levels=0)
    s.update(np.ones((4, 12), np.float32))
    with pytest.raises(ValueError, match="n=12"):
        CheckpointedGramStream(16, str(tmp_path), every=1, levels=0)
    with pytest.raises(ValueError, match="packed"):
        CheckpointedGramStream(12, str(tmp_path), layout="stack")


def test_stream_finalize_guard_raises_on_poisoned_state():
    st = gram_stream.init(8)
    st = gram_stream.update(st, np.ones((4, 8), np.float32), levels=0)
    bad = gram_stream.GramStream(
        packed=st.packed.at[3].set(np.nan), rows=st.rows)
    with pytest.raises(VerificationError, match="non-finite"):
        gram_stream.finalize(bad, guard=True)
    gram_stream.finalize(st, guard=True)       # clean state passes


def test_checkpoint_restore_skips_corrupt_latest(tmp_path):
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, {"x": np.arange(4)})
    mgr.save(2, {"x": np.arange(8)})
    # rot the newest committed checkpoint
    npz = os.path.join(str(tmp_path), "step_00000002", "state.npz")
    with open(npz, "wb") as f:
        f.write(b"not a zipfile")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        state, meta = mgr.restore()
    assert meta["step"] == 1
    assert np.array_equal(state["x"], np.arange(4))
    assert any("unreadable" in str(x.message) for x in w)
    # explicitly requested corrupt step still raises
    with pytest.raises(Exception):
        mgr.restore(step=2)


# ---------------------------------------------------------------------------
# satellite (c): mesh shrink mid-bfs25d -> scheme fallback chain
# ---------------------------------------------------------------------------

def test_scheme_fallback_chain_orders_and_filters():
    from types import SimpleNamespace as NS
    from repro.core.distributed import scheme_fallback_chain
    mesh = NS(shape={"rep": 2, "data": 2, "model": 2},
              axis_names=("rep", "data", "model"))
    axes = dict(row_axis="data", col_axis="model", rep_axis="rep")
    chain = scheme_fallback_chain(128, 64, mesh, scheme="bfs25d", **axes)
    assert chain == ["bfs25d", "ring", "reducescatter", "allreduce"]
    # auto: cost-model head, every feasible scheme present exactly once
    auto = scheme_fallback_chain(128, 64, mesh, scheme="auto", **axes)
    assert sorted(auto) == sorted(chain) and len(set(auto)) == len(auto)
    # infeasible pin: the pinned scheme is absent, the rest still degrade
    mesh3 = NS(shape={"data": 2, "model": 3},
               axis_names=("data", "model"))
    chain3 = scheme_fallback_chain(
        128, 64, mesh3, scheme="ring",
        row_axis="data", col_axis="model", rep_axis=None)
    assert "ring" not in chain3 and chain3 == ["reducescatter", "allreduce"]
    # nothing feasible -> empty (engine goes local)
    none = scheme_fallback_chain(127, 63, mesh, scheme="auto", **axes)
    assert none == []


@pytest.mark.multidevice(8)
def test_mesh_shrink_falls_back_through_schemes(multidevice_count):
    """Drop a replica group mid-run: one request serves over the full
    mesh via bfs25d; then an injected mesh_shrink plus a bfs25d
    executable failure force the fallback chain — the next request
    completes on the surviving sub-mesh via the half-ring scheme, with
    a parity-correct Gram."""
    from repro.launch.mesh import make_gram_mesh

    rng = np.random.default_rng(9)
    mesh = make_gram_mesh(8, rep=2, ring=2)    # (rep=2, data=2, model=2)
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16,
                     mesh=mesh, dist_scheme="bfs25d",
                     dist_threshold=128 * 64, verify=2,
                     max_retries=6, breaker_threshold=1)

    def check(r, a):
        want = a.astype(np.float64).T @ a.astype(np.float64)
        err = np.abs(r.result - want).max() / np.abs(want).max()
        assert r.status == "ok" and err < 1e-4, (r.status, r.error, err)

    a1 = rng.standard_normal((120, 60)).astype(np.float32)   # -> 128x64
    eng.submit(a1)
    (r1,) = eng.run_to_completion()
    check(r1, a1)
    assert r1.served_by == "dist:bfs25d"

    a2 = rng.standard_normal((120, 60)).astype(np.float32)
    u2 = eng.submit(a2).uid
    with faults.inject(
            FaultSpec("mesh_shrink", times=1),
            FaultSpec("exec_fail", site="*bfs25d*")) as reg:
        (r2,) = [r for r in eng.run_to_completion() if r.uid == u2]
    check(r2, a2)
    assert reg.count("mesh_shrink") == 1
    assert r2.served_by == "dist:ring"         # one rung down the ladder
    assert r2.degraded
    stats = eng.stats()
    assert stats["mesh_changes"] == 1
    assert dict(eng.mesh.shape) == {"rep": 1, "data": 2, "model": 2}
    assert stats["served"] == 2 and stats["failed"] == 0
