"""Optimizers: AdamW / ATA-Shampoo convergence + equivalences +
gradient compression error-feedback properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, shampoo, apply_updates, warmup_cosine,
                         int8_quantize, int8_dequantize, ErrorFeedback,
                         lowrank_basis)


def _run_quadratic(opt, steps=120, shape=(8, 6)):
    """min ||W - T||^2 for a 2-D param (exercises the Shampoo path)."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, shape)
    params = {"w": jnp.zeros(shape)}
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        grads = jax.tree.map(lambda w: 2 * (w - target), params)
        updates, state, _ = opt.update(grads, state, params, i)
        return apply_updates(params, updates), state

    for i in range(steps):
        params, state = step(params, state, jnp.int32(i))
    return float(jnp.sum((params["w"] - target) ** 2))


def test_adamw_converges():
    loss = _run_quadratic(adamw(0.05, weight_decay=0.0))
    assert loss < 1e-2, loss


def test_shampoo_converges():
    loss = _run_quadratic(
        shampoo(0.05, weight_decay=0.0, block_size=8, precond_interval=5,
                ata_levels=1, ata_leaf=2))
    assert loss < 1e-2, loss


def test_shampoo_strassen_equals_classical():
    """The ATA variant (paper's Strassen recursion) must be numerically
    equivalent to classical grams inside Shampoo."""
    kw = dict(weight_decay=0.0, block_size=8, precond_interval=3,
              ata_leaf=2)
    opt_s = shampoo(0.05, ata_levels=2, ata_variant="strassen", **kw)
    opt_c = shampoo(0.05, ata_levels=0, ata_variant="classical", **kw)
    key = jax.random.PRNGKey(1)
    target = jax.random.normal(key, (8, 6))
    outs = []
    for opt in (opt_s, opt_c):
        params = {"w": jnp.zeros((8, 6))}
        state = opt.init(params)
        for i in range(10):
            grads = jax.tree.map(lambda w: 2 * (w - target), params)
            updates, state, _ = opt.update(grads, state, params,
                                           jnp.int32(i))
            params = apply_updates(params, updates)
        outs.append(np.asarray(params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)


def test_shampoo_ata_mode_reference_matches_default():
    """ata_mode= is threaded to the batched Gram path; forcing the
    reference recursion must match the auto-dispatched default."""
    kw = dict(weight_decay=0.0, block_size=8, precond_interval=3,
              ata_levels=1, ata_leaf=2)
    opt_auto = shampoo(0.05, **kw)
    opt_ref = shampoo(0.05, ata_mode="reference", **kw)
    target = jax.random.normal(jax.random.PRNGKey(2), (8, 6))
    outs = []
    for opt in (opt_auto, opt_ref):
        params = {"w": jnp.zeros((8, 6))}
        state = opt.init(params)
        for i in range(6):
            grads = jax.tree.map(lambda w: 2 * (w - target), params)
            updates, state, _ = opt.update(grads, state, params,
                                           jnp.int32(i))
            params = apply_updates(params, updates)
        outs.append(np.asarray(params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)


def test_shampoo_blocks_large_dim():
    """dims > block_size are split into independent blocks; still converges
    and the gram stats have the blocked shape."""
    opt = shampoo(0.05, weight_decay=0.0, block_size=4, precond_interval=5,
                  ata_leaf=2)
    params = {"w": jnp.zeros((8, 6))}     # 2x2 blocks of (4, 3)... 4|8, 6->pad
    state = opt.init(params)
    gr = state["gram"]["w"]
    assert gr["l"].shape == (2 * 2, 4, 4)
    assert gr["r"].shape == (2 * 2, 4, 4) or gr["r"].shape == (4, 3, 3)


def test_shampoo_1d_falls_back_to_adam():
    opt_s = shampoo(0.05, weight_decay=0.0)
    opt_a = adamw(0.05, weight_decay=0.0, b2=0.95)
    params = {"b": jnp.ones((16,))}
    ss, sa = opt_s.init(params), opt_a.init(params)
    grads = {"b": jnp.linspace(-1, 1, 16)}
    us, _, _ = opt_s.update(grads, ss, params, jnp.int32(0))
    ua, _, _ = opt_a.update(grads, sa, params, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(us["b"]), np.asarray(ua["b"]),
                               rtol=1e-6)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup=10, total=100)
    assert float(s(jnp.int32(0))) < 0.2
    assert abs(float(s(jnp.int32(10))) - 1.0) < 0.11
    assert float(s(jnp.int32(99))) < 0.2


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(2), (256,)) * 3
    q, scale = int8_quantize(x)
    err = np.abs(np.asarray(int8_dequantize(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_lowrank_basis_orthonormal_and_exact_for_lowrank_grads():
    """The Gram-derived basis is orthonormal, and a gradient that is
    exactly rank-r is reconstructed exactly by its rank-r projection."""
    key = jax.random.PRNGKey(3)
    u = jax.random.normal(key, (64, 3))
    v = jax.random.normal(jax.random.PRNGKey(4), (12, 3))
    g = u @ v.T                                  # exactly rank 3, tall
    q = lowrank_basis(g, 3, levels=1, leaf=4)
    qq = np.asarray(q.T @ q)
    np.testing.assert_allclose(qq, np.eye(3), atol=1e-5)
    recon = np.asarray((g @ q) @ q.T)
    np.testing.assert_allclose(recon, np.asarray(g), rtol=1e-4, atol=1e-4)


def test_lowrank_psum_error_feedback_invariant():
    """Inside shard_map (1-device axis): emitted + residual tracks the true
    gradient, and tall 2-D leaves take the low-rank path (residual is the
    orthogonal complement, not a quantization residual)."""
    from repro.optim import lowrank_psum
    from repro.core.distributed import shard_map_compat
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("pod",))
    shard_map, unchecked = shard_map_compat()
    g = {"tall": jax.random.normal(jax.random.PRNGKey(5), (64, 8)),
         "bias": jnp.linspace(-1, 1, 16)}
    ef = ErrorFeedback.init(g)

    def body(grads, resid):
        out, new_ef = lowrank_psum(grads, "pod", ErrorFeedback(resid),
                                   rank=4, levels=1, leaf=4)
        return out, new_ef.residual

    out, resid = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                           out_specs=(P(), P()), **unchecked)(g, ef.residual)
    # emitted + residual == true gradient, leafwise (EF invariant, 1 dev)
    for k in g:
        np.testing.assert_allclose(np.asarray(out[k] + resid[k]),
                                   np.asarray(g[k]), rtol=1e-4, atol=1e-5)
    # the tall leaf went low-rank: its emission has rank <= 4
    s = np.linalg.svd(np.asarray(out["tall"]), compute_uv=False)
    assert (s > 1e-4 * s[0]).sum() <= 4


def test_error_feedback_accumulates_residual():
    """With error feedback, the SUM of quantized emissions tracks the sum
    of true gradients (residual never lost) — key convergence property."""
    g = jnp.full((64,), 0.003)            # much smaller than typical scale
    big = jnp.zeros((64,)).at[0].set(1.0)  # forces a coarse scale
    ef_resid = jnp.zeros((64,))
    emitted = jnp.zeros((64,))
    for _ in range(50):
        gt = g + big
        q, s = int8_quantize(gt + ef_resid)
        deq = int8_dequantize(q, s)
        ef_resid = gt + ef_resid - deq
        emitted = emitted + deq
    total_true = 50 * (g + big)
    # emitted + residual == exact running sum (error feedback invariant)
    np.testing.assert_allclose(np.asarray(emitted + ef_resid),
                               np.asarray(total_true), rtol=1e-5, atol=1e-5)
    # and the residual itself stays bounded by one quantization step
    assert float(jnp.abs(ef_resid).max()) < float(s) * 1.0 + 1e-6
