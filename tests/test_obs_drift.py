"""obs.drift: the cost-model drift detector — EWMA mechanics, the
wall-channel median normalization, traffic-channel direct banding, and
the engine's invalidate_drifted action (DESIGN.md §14).

Acceptance: a synthetically falsified bucket is flagged while
well-modeled buckets stay unflagged."""
import json

import numpy as np
import pytest

from repro.gram import GramEngine
from repro.gram import autotune as at
from repro.obs.drift import DriftDetector


def _feed(det, key, measured, predicted, n=4, channel="wall"):
    for _ in range(n):
        det.observe(key, measured=measured, predicted=predicted,
                    channel=channel)


# ---------------------------------------------------------------------------
# EWMA mechanics
# ---------------------------------------------------------------------------

def test_observe_returns_ewma_and_seeds_on_first_sample():
    det = DriftDetector(alpha=0.5)
    assert det.observe("k", measured=2.0, predicted=1.0) == 2.0
    # 0.5 * 2.0 + 0.5 * 4.0
    assert det.observe("k", measured=4.0, predicted=1.0) == pytest.approx(3.0)
    rec = det.record("k")
    assert rec.n == 2
    assert rec.last_measured == 4.0 and rec.last_predicted == 1.0


def test_non_positive_samples_carry_no_ratio_and_are_dropped():
    det = DriftDetector()
    assert det.observe("k", measured=0.0, predicted=1.0) is None
    assert det.observe("k", measured=1.0, predicted=-2.0) is None
    assert det.record("k") is None


def test_constructor_validates_theta_and_alpha():
    with pytest.raises(ValueError, match="theta"):
        DriftDetector(theta=1.0)
    with pytest.raises(ValueError, match="alpha"):
        DriftDetector(alpha=0.0)


# ---------------------------------------------------------------------------
# Findings: acceptance semantics
# ---------------------------------------------------------------------------

def test_wall_channel_flags_only_the_falsified_bucket():
    """Three buckets whose measured/predicted share a machine constant
    (1e-6 s/byte) — except one that runs 20x its model.  Only that one
    may be flagged, despite NO channel sharing units with the model."""
    det = DriftDetector(theta=2.0, min_samples=3)
    _feed(det, "64x64/float32/ata", 1.0, 1e6)
    _feed(det, "128x128/float32/ata", 4.0, 4e6)
    _feed(det, "256x256/float32/ata", 80.0, 4e6)    # falsified: 20x
    findings = det.findings("wall")
    assert [f.key for f in findings] == ["256x256/float32/ata"]
    (f,) = findings
    assert f.channel == "wall"
    assert f.ratio > f.theta                # normalized ratio escaped band
    assert f.n == 4
    assert det.stale_keys("wall") == ["256x256/float32/ata"]


def test_wall_channel_is_robust_to_whole_machine_slowdown():
    """Every bucket 10x slower (thermals, noisy neighbour): ratios move
    together, the median normalization cancels it, nothing is flagged."""
    det = DriftDetector(theta=2.0, min_samples=2)
    _feed(det, "a", 10.0, 1e6)
    _feed(det, "b", 40.0, 4e6)
    _feed(det, "c", 160.0, 16e6)
    assert det.findings("wall") == []


def test_wall_channel_needs_peer_keys_to_flag():
    """One bucket cannot be told apart from the machine constant; once
    honest peers pin the median, the outlier is attributable."""
    det = DriftDetector(theta=2.0, min_samples=2)
    _feed(det, "only", 1e9, 1.0)            # wildly off, but alone
    assert det.findings("wall") == []
    _feed(det, "peer1", 1.0, 1e6)
    _feed(det, "peer2", 1.1, 1e6)
    assert [f.key for f in det.findings("wall")] == ["only"]


def test_min_samples_gates_findings():
    det = DriftDetector(theta=2.0, min_samples=3)
    _feed(det, "ok1", 1.0, 1e6, n=3)
    _feed(det, "ok2", 1.1, 1e6, n=3)
    _feed(det, "young", 100.0, 1e6, n=2)    # off-band but immature
    assert det.findings("wall") == []
    det.observe("young", measured=100.0, predicted=1e6)
    assert [f.key for f in det.findings("wall")] == ["young"]


def test_traffic_channel_bands_directly_both_sides():
    """Same units (bytes vs bytes): no normalization, one key suffices,
    and both over- and under-prediction escape the band."""
    det = DriftDetector(theta=2.0, min_samples=2)
    _feed(det, "honest", 1.1e6, 1e6, channel="traffic")
    _feed(det, "hungry", 5e6, 1e6, channel="traffic")
    _feed(det, "phantom", 1e5, 1e6, channel="traffic")
    keys = {f.key for f in det.findings("traffic")}
    assert keys == {"hungry", "phantom"}
    # channels are independent namespaces
    assert det.findings("wall") == []


def test_reset_scopes_and_snapshot_is_json_friendly():
    det = DriftDetector(min_samples=1)
    det.observe("k1", measured=1.0, predicted=1.0, config="c1")
    det.observe("k1", measured=1.0, predicted=1.0, channel="traffic")
    det.observe("k2", measured=9.0, predicted=1.0)
    det.reset("k1", channel="wall")
    assert det.record("k1", "wall") is None
    assert det.record("k1", "traffic") is not None
    snap = json.loads(json.dumps(det.snapshot()))
    assert snap["theta"] == det.theta
    assert "k1|traffic" in snap["records"]
    assert snap["records"]["k2|wall"]["n"] == 1
    det.reset()
    assert det.snapshot()["records"] == {}


# ---------------------------------------------------------------------------
# The engine action: drift finding -> autotune winner dropped
# ---------------------------------------------------------------------------

def test_engine_invalidate_drifted_drops_winner_and_history(tmp_path,
                                                            monkeypatch):
    cache = tmp_path / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    eng = GramEngine(slots=2, levels=0, min_bucket=32)

    # a persisted winner for the 64x64 ata bucket...
    at.autotune(64, 64, blocks=(16,), levels=(0,), measure=False)
    assert at.lookup(64, 64) is not None

    # ...whose wall-channel EWMA is 20x off its peers (two honest peers
    # pin the cross-key median)
    key = (64, 64, "float32", "cols", "native")
    _feed(eng.drift, "64x64/float32/ata", 80.0, 4e6)
    _feed(eng.drift, "128x128/float32/ata", 1.0, 1e6)
    _feed(eng.drift, "256x256/float32/ata", 1.1, 1e6)
    eng._executables[("local", key)] = object()
    eng._drift_pred_cache[(key, "fp")] = 1.0

    st = eng.stats()
    assert [f["key"] for f in st["drift"]] == ["64x64/float32/ata"]

    dropped = eng.invalidate_drifted()
    assert dropped == ["64x64/float32/ata"]
    assert at.lookup(64, 64) is None, "stale winner must leave the cache"
    assert ("local", key) not in eng._executables
    assert (key, "fp") not in eng._drift_pred_cache
    # history forgotten: the re-measured bucket starts clean
    assert eng.drift.record("64x64/float32/ata") is None
    assert eng.stats()["drift"] == []
    # healthy bucket untouched
    assert eng.drift.record("128x128/float32/ata") is not None


def test_engine_feeds_wall_drift_from_real_serving():
    """An end-to-end smoke: serving at rung 0 populates the wall channel
    with the model's predicted bytes for the served bucket."""
    rng = np.random.default_rng(5)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    for _ in range(3):
        eng.submit(rng.standard_normal((40, 20)).astype(np.float32))
    eng.run_to_completion()
    # one observation per executed batch (3 requests over 2 slots -> 2)
    rec = eng.drift.record("64x32/float32/ata")
    assert rec is not None and rec.n == 2
    assert rec.last_measured > 0 and rec.last_predicted > 0
