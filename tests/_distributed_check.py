"""Child script for distributed-gram tests. Run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the parent test
via subprocess so the main pytest process keeps 1 device)."""
import os
import sys

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P, NamedSharding  # noqa: E402

from repro.core import distributed_gram  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    m, n = 128, 64
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n), dtype=jnp.float32)
    want = np.asarray(a.T @ a, np.float64)

    # 1D mesh: paper-faithful all-reduce + beyond-paper reduce-scatter.
    mesh1 = jax.make_mesh((8,), ("data",))
    for scheme in ("allreduce", "reducescatter"):
        got = distributed_gram(a, mesh1, scheme=scheme, row_axis="data",
                               levels=2, leaf=8)
        got = np.asarray(jax.device_get(got), np.float64)
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 1e-4, (scheme, err)
        print(f"OK {scheme} rel_err={err:.2e}")

    # 2D mesh: half-ring schedule (rows x cols).
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    got = distributed_gram(a, mesh2, scheme="ring", row_axis="data",
                           col_axis="model", levels=1, leaf=8)
    got = np.asarray(jax.device_get(got), np.float64)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, ("ring", err)
    print(f"OK ring rel_err={err:.2e}")

    # odd ring size (no antipodal masking path)
    mesh3 = jax.make_mesh((1, 8), ("data", "model"))
    got = distributed_gram(a, mesh3, scheme="ring", row_axis="data",
                           col_axis="model", levels=0, leaf=8)
    got = np.asarray(jax.device_get(got), np.float64)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, ("ring8", err)
    print(f"OK ring8 rel_err={err:.2e}")
    print("ALL_OK")


if __name__ == "__main__":
    main()
