"""ISSUE 10: pipelined execution + the low-precision operand path.

Three contracts:

* the double-buffered DMA pipeline (``pipeline_depth > 1``) is BIT-EXACT
  vs the depth-1 schedule on every kind — same tiles, same signed sums,
  same accumulate seeding, only the fetch schedule differs;
* fp8/bf16 operand tiles quantize once (after padding) and accumulate in
  fp32, so the output matches the quantized-operand oracle to fp32
  accuracy and still satisfies the Freivalds identity vs the ORIGINAL
  operand at the precision-scaled tolerance;
* the new knobs persist and replay: autotune winners carry
  ``pipeline_depth``/``operand_dtype`` through a cache round-trip, the
  engine buckets quantized requests separately from native ones, and the
  candidate dedupe collapses identically-scored duplicates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gram import GramEngine
from repro.gram import autotune as at
from repro.gram.verify import default_rtol, freivalds_gram
from repro.kernels import ops


def _rand(seed, m, n):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, n), jnp.float32)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    return path


# --------------------------------------------------------------------------
# pipeline_depth parity: depth>1 must be bit-exact vs depth=1, all kinds
# --------------------------------------------------------------------------

def _run_kind(kind, depth):
    a = _rand(0, 96, 64)
    if kind == "ata":
        return ops.ata_fused(a, levels=1, bk=32, bn=32,
                             pipeline_depth=depth)
    if kind == "aat":
        return ops.aat_fused(a, levels=1, bm=32, bk=32,
                             pipeline_depth=depth)
    if kind == "matmul":
        b = _rand(1, 64, 96)
        return ops.matmul_fused(a, b, levels=1, bm=32, bk=32, bn=32,
                                pipeline_depth=depth)
    if kind == "symm":
        s_packed = ops.ata_fused_packed(a, levels=1, bk=32, bn=32)
        x = _rand(2, 48, 64)
        return ops.symm_matmul(x, s_packed, levels=1, bm=32,
                               pipeline_depth=depth)
    assert kind == "rank_k"
    stack = jnp.asarray(np.random.default_rng(3).standard_normal(
        (3 * 32, 32)).astype(np.float32))   # t=2 tiles of edge 32
    return ops.rank_k_update(stack, a, levels=1, bk=32, donate=False,
                             pipeline_depth=depth)


@pytest.mark.parametrize("kind", ["ata", "aat", "matmul", "symm", "rank_k"])
@pytest.mark.parametrize("depth", [2, 3])
def test_pipeline_depth_bit_exact_parity(kind, depth):
    base = np.asarray(_run_kind(kind, 1))
    got = np.asarray(_run_kind(kind, depth))
    assert np.array_equal(base, got), (
        f"{kind}: depth={depth} differs from depth=1 "
        f"(max abs {np.abs(base - got).max()})")


@pytest.mark.parametrize("kind,depth", [("ata", 2), ("ata", 3), ("aat", 2)])
def test_pipeline_depth_parity_ragged_rect(kind, depth):
    """257x511: every padding/clamping path live at once (ragged in both
    dims, rectangular) — the pipeline must still be bit-exact."""
    a = _rand(7, 257, 511)
    fn = ops.ata_fused if kind == "ata" else ops.aat_fused
    kw = (dict(bk=64, bn=64) if kind == "ata" else dict(bm=64, bk=64))
    base = np.asarray(fn(a, levels=1, pipeline_depth=1, **kw))
    got = np.asarray(fn(a, levels=1, pipeline_depth=depth, **kw))
    assert np.array_equal(base, got)


def test_pipeline_depth_validated():
    a = _rand(0, 64, 64)
    with pytest.raises(ValueError):
        ops.ata_fused(a, levels=1, bk=32, bn=32, pipeline_depth=0)


# --------------------------------------------------------------------------
# fp8 / bf16 operand tiles
# --------------------------------------------------------------------------

@pytest.mark.parametrize("od", ["bfloat16", "float8_e4m3fn", "float8_e5m2"])
def test_operand_tile_parity_512(od):
    """The kernel's quantize-after-pad + fp32-accumulate semantics: the
    output matches the quantized-operand float64 oracle to fp32-Strassen
    accuracy (the quantized values are exact in fp32, so the only error
    left is accumulation), and the end-to-end result still satisfies the
    Freivalds identity vs the ORIGINAL operand at default_rtol(od)."""
    a = _rand(11, 512, 512)
    got = np.asarray(ops.ata_fused(a, levels=2, bk=128, bn=128,
                                   operand_dtype=od), np.float64)
    aq = np.asarray(a.astype(jnp.dtype(od)).astype(jnp.float32), np.float64)
    want = np.tril(aq.T @ aq)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-4, od
    ok, err = freivalds_gram(np.asarray(a), got, probes=4, full=False,
                             rtol=default_rtol(od))
    assert ok, (od, err, default_rtol(od))


def test_operand_dtype_rejects_unknown():
    a = _rand(0, 64, 64)
    with pytest.raises(ValueError):
        ops.ata_fused(a, levels=1, bk=32, bn=32, operand_dtype="int8")


def test_precision_scaled_rtol_ordering():
    """Tolerance must widen with the quantization step: fp32 < bf16 <
    e4m3 (eps 2^-3) < e5m2 (eps 2^-2)."""
    assert (default_rtol("float32") < default_rtol("bfloat16")
            < default_rtol("float8_e4m3fn") < default_rtol("float8_e5m2"))


# --------------------------------------------------------------------------
# autotune: dedupe + cache round-trip of the new knobs
# --------------------------------------------------------------------------

def test_candidate_dedupe_collapses_aat_square_duplicates():
    """For aat at bm == bk the (bm, bk) and (bk, bm) candidates are the
    same program; dedupe keeps one."""
    cands = at.candidate_space(64, 64, kind="aat", blocks=(32, 64),
                               levels=(1,), modes=("fused",))
    sigs = [(c["levels"], c["variant"], c.get("gram"), c["bm"], c["bk"],
             c.get("pipeline_depth"), c.get("operand_dtype"))
            for c in cands]
    assert len(sigs) == len(set(sigs)), "duplicate candidates survived"


def test_candidate_space_carries_pipeline_and_operand_axes():
    cands = at.candidate_space(64, 64, blocks=(32,), levels=(1,),
                               modes=("fused",),
                               pipeline_depths=(1, 2),
                               operand_dtypes=(None, "bfloat16"))
    fused = [c for c in cands if c["mode"] == "fused"]
    assert {c["pipeline_depth"] for c in fused} == {1, 2}
    assert {c["operand_dtype"] for c in fused} == {None, "bfloat16"}


def test_autotune_cache_roundtrips_new_knobs(tmp_cache):
    """The persisted winner carries pipeline_depth/operand_dtype and a
    fresh lookup (new process simulated by a cache reload) replays them."""
    entry = at.autotune(64, 64, blocks=(32,), levels=(1,),
                        modes=("fused",), measure=False,
                        pipeline_depths=(1, 2), operand_dtypes=(None,))
    assert entry["pipeline_depth"] in (1, 2)
    assert "operand_dtype" in entry
    # load_cache memoizes on (path, mtime): lookup below re-reads the
    # persisted file, i.e. what a fresh process would see
    hit = at.lookup(64, 64)
    assert hit is not None
    assert hit["pipeline_depth"] == entry["pipeline_depth"]
    assert hit["operand_dtype"] == entry["operand_dtype"]


def test_model_score_prefers_pipelined_on_balanced_shapes():
    """With the roofline term live, depth=2 overlap can only help (score
    is max+fill vs sum), so at fixed everything-else the pd=2 candidate
    never scores WORSE than pd=1."""
    base = {"mode": "fused", "variant": "strassen", "gram": "strassen",
            "levels": 1, "bk": 64, "bn": 64, "operand_dtype": None}
    s1 = at.model_score(512, 512, {**base, "pipeline_depth": 1})
    s2 = at.model_score(512, 512, {**base, "pipeline_depth": 2})
    assert s2 <= s1


# --------------------------------------------------------------------------
# engine: quantized buckets are separate, guarded at the scaled rtol
# --------------------------------------------------------------------------

def test_engine_buckets_quantized_requests_separately():
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16)
    a = np.random.default_rng(0).standard_normal((64, 32)).astype(np.float32)
    k_native = eng._bucket_key(a.shape, a.dtype)
    k_fp8 = eng._bucket_key(a.shape, a.dtype,
                            operand_dtype="float8_e4m3fn")
    assert len(k_native) == 5 and k_native[4] == "native"
    assert k_fp8[4] == "float8_e4m3fn"
    assert k_native != k_fp8
    # native label keeps the historical format (drift keys pin it)
    assert eng._blabel(k_native) == "64x32/float32/cols"
    assert eng._blabel(k_fp8) == "64x32/float32/cols/float8_e4m3fn"


def test_engine_serves_fp8_request_verified():
    """A quantized submit serves through its own bucket, passes the
    precision-scaled Freivalds guard, and lands within default_rtol of
    the true gram."""
    rng = np.random.default_rng(5)
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    eng.submit(a)                                    # native
    r8 = eng.submit(a, operand_dtype="float8_e4m3fn")
    done = {r.uid: r for r in eng.run_to_completion()}
    want = a.astype(np.float64).T @ a.astype(np.float64)
    scale = max(np.abs(want).max(), 1.0)
    err8 = np.abs(done[r8.uid].result - want).max() / scale
    assert err8 < default_rtol("float8_e4m3fn")
    assert err8 > 1e-4          # it really quantized (not native served)


def test_engine_pipeline_depth_bit_exact_serving():
    """Engine-level depth-2 serving returns bit-identical grams to the
    depth-1 engine (the knob changes scheduling, never numerics)."""
    rng = np.random.default_rng(6)
    a = rng.standard_normal((48, 24)).astype(np.float32)
    outs = []
    for depth in (1, 2):
        eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16,
                         pipeline_depth=depth)
        eng.submit(a)
        (r,) = eng.run_to_completion()
        outs.append(np.asarray(r.result))
    assert np.array_equal(outs[0], outs[1])
