"""Per-architecture smoke + decode-cache equivalence for all 10 archs.

Each arch runs at a REDUCED config of the same family (same code paths,
small dims) per the assignment; full configs are exercised by the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduced_arch
from repro.models import (init_params, forward, loss_fn, init_cache,
                          prefill, decode_step)

ALL_ARCHS = sorted(ARCHS)


def _cfg(name):
    cfg = reduced_arch(name)
    if cfg.moe is not None:
        # dropless capacity so full-seq routing == per-token routing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _batch(cfg, key, b=2, s=24):
    batch = {
        "inputs": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["enc_inputs"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, aux, _ = jax.jit(
        lambda p, b: forward(cfg, p, b["inputs"],
                             enc_inputs=b.get("enc_inputs"), mode="train")
    )(params, batch)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    gnorms = [float(jnp.abs(g.astype(jnp.float32)).max())
              for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(gnorms)), arch
    assert max(gnorms) > 0, f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = _cfg(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    b, s, extra = 2, 16, 3
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab_size)
    enc = (jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                             jnp.bfloat16) if cfg.family == "audio" else None)

    full = jax.jit(lambda p, t: forward(cfg, p, t, enc_inputs=enc,
                                        mode="train"))(params, toks)[0]
    cache = init_cache(cfg, b, s + extra)
    pf = jax.jit(lambda p, t, c: prefill(cfg, p, t, c, enc_inputs=enc))
    dc = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    last, cache = pf(params, toks[:, :s], cache)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(full[:, s - 1], np.float32),
        rtol=4e-2, atol=4e-2)
    for i in range(extra):
        last, cache = dc(params, toks[:, s + i:s + i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(last, np.float32),
            np.asarray(full[:, s + i], np.float32), rtol=5e-2, atol=5e-2)


def test_gemma2_window_masks_differ():
    """Alternating local/global layers must produce different attention
    reach: with a tiny window, late tokens lose early context in local
    layers — logits must differ from the all-global variant."""
    cfg = dataclasses.replace(_cfg("gemma2-9b"), sliding_window=4)
    cfg_g = dataclasses.replace(cfg, sliding_window=None,
                                alt_local_global=False)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    a = forward(cfg, params, toks, mode="train")[0]
    b = forward(cfg_g, params, toks, mode="train")[0]
    assert not np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))


def test_moe_routing_selects_topk():
    from repro.models.layers import _moe_dispatch_compute, init_moe
    cfg = _cfg("arctic-480b")
    key = jax.random.PRNGKey(3)
    p = init_moe(cfg, key)
    x = jax.random.normal(key, (32, cfg.d_model), jnp.bfloat16)
    pl = {k: v for k, v in p.items() if k != "shared"}
    out, aux = jax.jit(
        lambda pl, x: _moe_dispatch_compute(pl, x, cfg))(pl, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert float(aux) >= 1.0 - 1e-3   # load-balance loss lower bound is 1


def test_moe_ep_shards_match_full():
    """EP decomposition invariant: sum of per-shard expert outputs (each
    shard computing its expert range) == full-expert computation."""
    from repro.models.layers import _moe_dispatch_compute, init_moe
    cfg = _cfg("arctic-480b")
    key = jax.random.PRNGKey(4)
    p = init_moe(cfg, key)
    pl = {k: v for k, v in p.items() if k != "shared"}
    x = jax.random.normal(key, (16, cfg.d_model), jnp.float32)
    e = cfg.moe.num_experts
    full, _ = _moe_dispatch_compute(pl, x, cfg)
    parts = []
    nsh = 4
    el = e // nsh
    for r in range(nsh):
        # slice this shard's expert weights, as shard_map would
        pr = dict(pl)
        for w in ("w_gate", "w_up", "w_down"):
            pr[w] = pl[w][r * el:(r + 1) * el]
        out, _ = _moe_dispatch_compute(pr, x, cfg, e_offset=r * el,
                                       e_count=el)
        parts.append(np.asarray(out, np.float32))
    np.testing.assert_allclose(sum(parts), np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_mamba2_ssd_chunked_matches_sequential():
    """SSD chunked scan == naive per-step recurrence."""
    from repro.models.layers import _ssd_chunked
    key = jax.random.PRNGKey(5)
    b, s, h, p, g, n = 2, 32, 4, 8, 1, 8
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    cm = jax.random.normal(ks[0], (b, s, g, n), jnp.float32)

    y_chunk, final = _ssd_chunked(xh, dt, a, bm, cm, chunk=8)

    # naive recurrence
    state = np.zeros((b, h, p, n))
    ys = []
    xh_, dt_, bm_, cm_ = map(np.asarray, (xh, dt, bm, cm))
    a_ = np.asarray(a)
    for t in range(s):
        decay = np.exp(dt_[:, t] * a_)[:, :, None, None]
        upd = (dt_[:, t][:, :, None] * xh_[:, t])[..., None] \
            * np.repeat(bm_[:, t], h // g, 1)[:, :, None, :]
        state = state * decay + upd
        y = np.einsum("bhpn,bhn->bhp", state, np.repeat(cm_[:, t], h // g, 1))
        ys.append(y)
    y_naive = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_naive, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


def test_attention_chunked_matches_plain():
    from repro.models.layers import attention
    key = jax.random.PRNGKey(6)
    b, sq, hq, hkv, d = 2, 64, 8, 2, 16
    q = jax.random.normal(key, (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(7), (b, sq, hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(8), (b, sq, hkv, d), jnp.float32)
    pos = jnp.arange(sq)
    plain = attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    chunked = attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                        chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)
    # sliding window agrees between paths too
    w = attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=8)
    wc = attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True, window=8,
                   chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wc), rtol=2e-5,
                               atol=2e-5)
