"""Distributed ATA-P (shard_map) == sequential, via an 8-device subprocess.

The multi-device run happens in a child process so that the main pytest
process keeps the default 1-device CPU platform (see system constraints:
XLA_FLAGS must not be set globally)."""
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def test_distributed_gram_schemes_match_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(HERE / "_distributed_check.py")],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout
