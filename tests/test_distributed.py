"""Multi-device parity for ALL distributed-gram schemes, on 8 forced-host
devices via the ``multidevice`` marker (tests/conftest.py): each marked
test re-runs itself in a child pytest where XLA_FLAGS forces the device
count, so the main pytest process keeps the default 1-device platform.

Covers, per the half-ring/2.5D layout contract of ``core.distributed``:
odd and even ring sizes, odd and even replication factors, rectangular
(m != n) shards, fp32/bf16 wire dtypes, ``assemble=False`` layouts, the
``scheme="auto"`` cost-model dispatch, and the antipodal-dedup
non-finite regression (jnp.where vs multiply-by-mask).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (assemble_ring_gram, distributed_gram,
                        feasible_schemes, ring_layout_coords)

AX3 = ("rep", "data", "model")
KW2 = dict(row_axis="data", col_axis="model")
KW3 = dict(row_axis="data", col_axis="model", rep_axis="rep")

# (mesh shape, axis names, distributed_gram axis kwargs) per scheme —
# odd and even ring sizes T and replication factors c, with and without
# a nontrivial row axis.  Meshes smaller than 8 use a device subset.
MESHES = {
    "allreduce": [((8,), ("data",), {}),
                  ((2, 4), ("data", "model"), KW2)],
    "reducescatter": [((8,), ("data",), {}),
                      ((4,), ("data",), {})],
    "ring": [((2, 4), ("data", "model"), KW2),      # even ring, 2 rows
             ((1, 8), ("data", "model"), KW2),      # even ring, row size 1
             ((2, 3), ("data", "model"), KW2)],     # odd ring (6 devices)
    "bfs25d": [((2, 1, 4), AX3, KW3),               # even ring, even rep
               ((2, 2, 2), AX3, KW3),               # 2x2x2, all axes real
               ((4, 1, 2), AX3, KW3),               # rep 4
               ((3, 1, 2), AX3, KW3),               # odd rep (6 devices)
               ((2, 1, 3), AX3, KW3)],              # odd ring (6 devices)
}


def _mesh(shape, names):
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), names)


def _oracle(a):
    a64 = np.asarray(a, np.float64)
    return a64.T @ a64


@pytest.mark.multidevice(8)
@pytest.mark.parametrize("scheme", sorted(MESHES))
def test_scheme_parity_8dev(scheme, multidevice_count):
    """Every scheme x mesh x recursion depth x dtype x (rectangular and
    square) shard shape matches the float64 dense oracle."""
    shapes = [(120, 48), (48, 48)]      # m=120: rows divide 1/2/3/4/8
    cases = [                           # classical leaf, 1 and 2 levels
        (0, jnp.float32, 1e-4),
        (1, jnp.float32, 1e-4),
        (1, jnp.bfloat16, 5e-2),
        (2, jnp.float32, 1e-4),
    ]
    for mesh_shape, names, kw in MESHES[scheme]:
        mesh = _mesh(mesh_shape, names)
        for m, n in shapes:
            for levels, dtype, tol in cases:
                a = jax.random.normal(
                    jax.random.PRNGKey(0), (m, n)).astype(dtype)
                got = distributed_gram(a, mesh, scheme=scheme,
                                       levels=levels, leaf=8, **kw)
                got = np.asarray(jax.device_get(got), np.float64)
                want = _oracle(a)
                err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
                assert err < tol, (scheme, mesh_shape, (m, n), levels,
                                   str(dtype), err)


@pytest.mark.multidevice(8)
@pytest.mark.parametrize("scheme,mesh_shape,names,kw", [
    ("ring", (2, 4), ("data", "model"), KW2),
    ("ring", (1, 8), ("data", "model"), KW2),
    ("ring", (2, 3), ("data", "model"), KW2),
    ("bfs25d", (2, 1, 4), AX3, KW3),
    ("bfs25d", (2, 1, 3), AX3, KW3),
    ("bfs25d", (4, 1, 2), AX3, KW3),
])
def test_half_ring_layout_contract(scheme, mesh_shape, names, kw,
                                   multidevice_count):
    """``assemble=False`` returns the documented circulant block layout:
    stack entry s, ring device d == C[d, (d - s) % T]; the masked
    antipodal duplicates are EXACT zeros; assemble_ring_gram rebuilds the
    dense oracle."""
    m, n = 96, 48
    T = mesh_shape[-1]
    n_loc = n // T
    half = T // 2
    a = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.float32)
    mesh = _mesh(mesh_shape, names)
    stacks = distributed_gram(a, mesh, scheme=scheme, levels=1, leaf=8,
                              assemble=False, **kw)
    stacks = np.asarray(jax.device_get(stacks), np.float64)
    assert stacks.shape == (half + 1, n_loc, n)
    want = _oracle(a)

    owned = set()
    for dev, s, i, j in ring_layout_coords(T):
        owned.add((dev, s))
        jdev = (dev - s) % T
        got = stacks[s][:, dev * n_loc:(dev + 1) * n_loc]
        blk = want[dev * n_loc:(dev + 1) * n_loc,
                   jdev * n_loc:(jdev + 1) * n_loc]
        np.testing.assert_allclose(got, blk, rtol=1e-4, atol=1e-4,
                                   err_msg=f"dev={dev} s={s}")
    # slots NOT in the ownership map are the antipodal duplicates: zeros
    for dev in range(T):
        for s in range(half + 1):
            if (dev, s) not in owned:
                got = stacks[s][:, dev * n_loc:(dev + 1) * n_loc]
                assert np.all(got == 0.0), (dev, s)

    dense = np.asarray(
        assemble_ring_gram(jnp.asarray(stacks, jnp.float32), T, n),
        np.float64)
    np.testing.assert_allclose(dense, want, rtol=1e-4, atol=1e-4)


@pytest.mark.multidevice(8)
def test_antipodal_mask_is_select_not_multiply(multidevice_count):
    """Regression: the even-ring antipodal dedup must use jnp.where, not
    multiply-by-mask — 0 * Inf = NaN would leak a discarded non-finite
    block into the stack (and poison the bfs25d merging psum)."""
    m, n, T = 64, 48, 4
    n_loc, half = n // T, T // 2
    a = np.array(jax.random.normal(jax.random.PRNGKey(2), (m, n)),
                 np.float32)
    a[0, 40] = np.inf            # lives in ring column block 3
    a = jnp.asarray(a)

    mesh = _mesh((2, 4), ("data", "model"))
    stacks = distributed_gram(a, mesh, scheme="ring", levels=1, leaf=8,
                              assemble=False, **KW2)
    stacks = np.asarray(jax.device_get(stacks))
    # discarded antipodal slots (s=half, dev >= half) are exact zeros even
    # though device 3's discarded product contains the Inf column block
    for dev in range(half, T):
        got = stacks[half][:, dev * n_loc:(dev + 1) * n_loc]
        assert np.all(got == 0.0), dev

    # bfs25d relies on those exact zeros for its merging psum: entries of
    # C that the oracle keeps finite must stay finite (no 0*Inf=NaN).
    # levels=0 (classical leaves): Strassen's own operand sums would turn
    # Inf into NaN at finite-oracle entries regardless of the mask.
    mesh3 = _mesh((2, 1, 4), AX3)
    dense = np.asarray(jax.device_get(
        distributed_gram(a, mesh3, scheme="bfs25d", levels=0, leaf=8,
                         **KW3)), np.float64)
    want = _oracle(a)
    finite = np.isfinite(want)
    assert finite[:40, :40].all()
    np.testing.assert_allclose(dense[finite], want[finite],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.multidevice(8)
def test_auto_scheme_matches_oracle(multidevice_count):
    """scheme="auto" picks a feasible scheme via the comm cost model and
    matches the oracle on 1-, 2- and 3-axis meshes."""
    cases = [
        ((8,), ("data",), {}),
        ((2, 4), ("data", "model"), KW2),
        ((2, 2, 2), AX3, KW3),
    ]
    for mesh_shape, names, kw in cases:
        mesh = _mesh(mesh_shape, names)
        for m, n in [(512, 32), (64, 64)]:
            a = jax.random.normal(jax.random.PRNGKey(3), (m, n), jnp.float32)
            assert feasible_schemes(m, n, mesh, **kw)
            got = np.asarray(jax.device_get(
                distributed_gram(a, mesh, scheme="auto", levels=1, leaf=8,
                                 **kw)), np.float64)
            want = _oracle(a)
            err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
            assert err < 1e-4, (mesh_shape, (m, n), err)


def test_feasible_schemes_single_device_logic():
    """Pure axis/divisibility logic — no multi-device platform needed
    (feasible_schemes only reads ``mesh.shape``)."""
    from types import SimpleNamespace as NS
    mesh = NS(shape={"rep": 2, "data": 2, "model": 4})
    assert feasible_schemes(64, 48, mesh, **KW3) == \
        ["allreduce", "reducescatter", "ring", "bfs25d"]
    # n not divisible by the ring axis: ring family drops out
    assert feasible_schemes(64, 46, mesh, **KW3) == \
        ["allreduce", "reducescatter"]
    # n not divisible by the row axis: reducescatter drops out
    assert feasible_schemes(63, 50, NS(shape={"data": 7})) == ["allreduce"]
    # m not divisible by the row axis: nothing fits
    assert feasible_schemes(65, 48, NS(shape={"data": 2})) == []
    # missing col axis: no ring family
    assert "ring" not in feasible_schemes(64, 48, NS(shape={"data": 2}),
                                          col_axis="model")


def test_default_gram_axes_never_duplicates_row_as_col():
    """A mesh with a 'model' axis but no 'data' axis must not map row and
    col onto the same axis (P(model, model) would fail at compile time)."""
    from types import SimpleNamespace as NS
    from repro.core import default_gram_axes

    ax = default_gram_axes(NS(axis_names=("model",)))
    assert ax["row_axis"] == "model" and ax["col_axis"] is None
    ax = default_gram_axes(NS(axis_names=("rep", "model")))
    assert ax == {"row_axis": "model", "col_axis": None, "rep_axis": "rep"}
    ax = default_gram_axes(NS(axis_names=("rep", "data", "model")))
    assert ax == {"row_axis": "data", "col_axis": "model",
                  "rep_axis": "rep"}
    ax = default_gram_axes(NS(axis_names=("x", "y")))
    assert ax == {"row_axis": "x", "col_axis": "y", "rep_axis": None}
