"""Oracle tests for the core ATA / Strassen recursions."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ata, ata_full, strassen_matmul
from repro.core.symmetry import (
    pack_tril, unpack_tril, pack_tril_blocks, unpack_tril_blocks,
    symmetrize_from_lower,
)


def _rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32).astype(dtype)


@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (16, 16, 16), (64, 64, 64),
    (33, 17, 9), (100, 50, 70), (128, 256, 64), (1, 5, 3), (65, 65, 65),
])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
@pytest.mark.parametrize("variant", ["strassen", "winograd"])
def test_strassen_matches_dot(m, k, n, levels, variant):
    a, b = _rand((m, k), seed=1), _rand((k, n), seed=2)
    got = strassen_matmul(a, b, levels=levels, leaf=4, variant=variant)
    want = a @ b
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n", [
    (8, 8), (32, 32), (64, 64), (33, 17), (17, 33), (100, 70),
    (128, 96), (1, 7), (7, 1), (129, 65),
])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_ata_matches_tril(m, n, levels):
    a = _rand((m, n), seed=3)
    got = ata(a, levels=levels, leaf=4)
    want = jnp.tril(a.T @ a)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
    # strictly upper triangle is exactly zero
    assert np.allclose(np.triu(np.asarray(got), 1), 0.0)


def test_ata_full_symmetric_psd():
    a = _rand((96, 48), seed=4)
    c = ata_full(a, levels=2, leaf=8)
    np.testing.assert_allclose(c, c.T, rtol=0, atol=0)
    evals = np.linalg.eigvalsh(np.asarray(c, np.float64))
    assert evals.min() > -1e-3  # PSD up to fp error


def test_ata_bf16_accumulates_fp32():
    a = _rand((256, 128), dtype=jnp.bfloat16, seed=5)
    # Default out_dtype is the promoted ACCUMULATION dtype (fp32 for bf16
    # inputs) — no silent downcast of fp32-accumulated results.
    got = ata(a, levels=2, leaf=16)
    want = jnp.tril(a.astype(jnp.float32).T @ a.astype(jnp.float32))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=5e-2, atol=5e-1)
    # explicit opt-in gets the input dtype back
    got_bf16 = ata(a, levels=2, leaf=16, out_dtype=jnp.bfloat16)
    assert got_bf16.dtype == jnp.bfloat16


def test_out_dtype_knob_matches_across_apis():
    a = _rand((64, 32), dtype=jnp.bfloat16, seed=11)
    assert ata(a, levels=1, leaf=8).dtype == jnp.float32
    assert ata_full(a, levels=1, leaf=8, out_dtype=jnp.bfloat16).dtype == jnp.bfloat16
    b = _rand((32, 24), dtype=jnp.bfloat16, seed=12)
    assert strassen_matmul(a, b, levels=1, leaf=8).dtype == jnp.float32
    assert strassen_matmul(a, b, levels=1, leaf=8,
                           out_dtype=jnp.bfloat16).dtype == jnp.bfloat16


def test_levels_auto():
    a = _rand((96, 80), seed=13)
    got = ata(a, levels="auto", leaf=16)
    np.testing.assert_allclose(got, jnp.tril(a.T @ a), rtol=3e-4, atol=3e-4)
    b = _rand((80, 64), seed=14)
    got = strassen_matmul(a, b, levels="auto", leaf=16)
    np.testing.assert_allclose(got, a @ b, rtol=3e-4, atol=3e-4)


def test_levels_for_terminates_at_leaf_zero():
    from repro.core.ata import ata_levels_for
    from repro.core.strassen import strassen_levels_for
    # (1+1)//2 == 1: leaf=0 (the cost_model convention) must not hang
    assert ata_levels_for(8, 8, 0) == 3
    assert strassen_levels_for(8, 8, 8, 0) == 3


def test_strassen_classical_variant():
    a, b = _rand((31, 19), seed=6), _rand((19, 23), seed=7)
    got = strassen_matmul(a, b, levels=3, variant="classical")
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


def test_pack_unpack_roundtrip():
    a = _rand((40, 24), seed=8)
    c = jnp.tril(a.T @ a)
    full = symmetrize_from_lower(c)
    packed = pack_tril(full)
    assert packed.shape == (24 * 25 // 2,)
    np.testing.assert_allclose(unpack_tril(packed, 24), full, rtol=1e-6)


def test_pack_unpack_blocks_roundtrip():
    a = _rand((64, 32), seed=9)
    full = symmetrize_from_lower(jnp.tril(a.T @ a))
    packed = pack_tril_blocks(full, 8)
    assert packed.shape == (4 * 5 // 2 * 8, 8)
    np.testing.assert_allclose(unpack_tril_blocks(packed, 32, 8), full, rtol=1e-6)


def test_ata_jit_and_grad():
    a = _rand((32, 16), seed=10)
    f = jax.jit(lambda x: ata_full(x, levels=1, leaf=4).sum())
    g = jax.grad(lambda x: ata_full(x, levels=1, leaf=4).sum())(a)
    # d/dA sum(A^T A) = A @ (ones + ones^T)
    ones = jnp.ones((16, 16))
    np.testing.assert_allclose(g, a @ (ones + ones.T), rtol=1e-4, atol=1e-4)
    assert np.isfinite(float(f(a)))
