"""Fused leaf-task pipeline: schedule + Pallas kernel (interpret mode).

Covers the acceptance criteria of the fused-pipeline PR:
  * numerical parity of the fused path with tril(a.T @ a) across odd /
    rectangular shapes, bf16 and fp32, levels 0-3 (interpret mode on CPU);
  * fp32 parity vs the reference recursion at 512x512 within 1e-5;
  * schedule property: signed leaf contributions reproduce the operation
    and its exact multiplication count from core/cost_model;
  * HBM-materialized intermediates: reference recursion >= 2x the fused
    pipeline at levels=2.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ata, ata_full, strassen_matmul
from repro.core.schedule import (
    plan_ata, plan_matmul, evaluate_ata_plan, evaluate_matmul_plan,
)
from repro.core.cost_model import ata_mults_exact, strassen_mults_exact
from repro.core.symmetry import unpack_tril_blocks
from repro.kernels.strassen_fused import (
    fused_ata, fused_ata_packed, fused_matmul, ata_traffic_model,
)
from repro.roofline.hlo_census import hbm_intermediate_census


def _rand(shape, dtype=jnp.float32, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def _oracle(a):
    af = np.asarray(a, np.float64)
    return np.tril(af.T @ af)


# ---------------------------------------------------------------------------
# Fused kernel parity (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [
    (16, 16), (32, 24), (24, 40), (64, 64), (57, 31),
])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_ata_matches_oracle(m, n, levels):
    a = _rand((m, n), seed=levels + 1)
    got = fused_ata(a, levels=levels, bk=8, bn=8, interpret=True)
    want = _oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5
    assert np.abs(np.triu(np.asarray(got), 1)).max() == 0.0


@pytest.mark.parametrize("levels", [1, 2])
def test_fused_ata_odd_rectangular(levels):
    a = _rand((257, 511), seed=7)
    got = fused_ata(a, levels=levels, bk=64, bn=64, interpret=True)
    want = _oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert got.shape == (511, 511)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


@pytest.mark.parametrize("variant", ["strassen", "winograd", "classical"])
def test_fused_ata_variants(variant):
    a = _rand((48, 32), seed=9)
    got = fused_ata(a, levels=2, variant=variant, bk=8, bn=8, interpret=True)
    want = _oracle(a)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_fused_ata_bf16_accumulates_fp32():
    a = _rand((128, 64), dtype=jnp.bfloat16, seed=3)
    got = fused_ata(a, levels=2, bk=16, bn=16, interpret=True)
    assert got.dtype == jnp.float32   # promoted accumulation dtype
    want = _oracle(a.astype(jnp.float32))
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 3e-2
    # explicit downcast knob
    got16 = fused_ata(a, levels=1, bk=16, bn=16, out_dtype=jnp.bfloat16,
                      interpret=True)
    assert got16.dtype == jnp.bfloat16


def test_fused_packed_layout_matches_syrk_convention():
    a = _rand((64, 32), seed=5)
    packed, n_pad = fused_ata_packed(a, levels=1, bk=16, bn=16,
                                     interpret=True)
    t = n_pad // 16
    assert packed.shape == (t * (t + 1) // 2 * 16, 16)
    dense = jnp.tril(unpack_tril_blocks(packed, n_pad, 16, symmetrize=False))
    np.testing.assert_allclose(np.asarray(dense)[:32, :32], _oracle(a),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (33, 17, 9), (24, 40, 32)])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
@pytest.mark.parametrize("variant", ["strassen", "winograd"])
def test_fused_matmul_matches_dot(m, k, n, levels, variant):
    a, b = _rand((m, k), seed=1), _rand((k, n), seed=2)
    got = fused_matmul(a, b, levels=levels, variant=variant,
                       bm=8, bk=8, bn=8, interpret=True)
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


# ---------------------------------------------------------------------------
# API integration: ata(..., mode=...) / strassen_matmul(..., mode=...)
# ---------------------------------------------------------------------------

def test_ata_mode_fused_equals_reference():
    a = _rand((96, 64), seed=11)
    fused = ata(a, levels=2, mode="fused", block=16, interpret=True)
    ref = ata(a, levels=2, leaf=16, mode="reference")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    full = ata_full(a, levels=1, mode="fused", block=16, interpret=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(full).T,
                               rtol=0, atol=0)


def test_strassen_matmul_mode_fused():
    a, b = _rand((40, 24), seed=12), _rand((24, 56), seed=13)
    got = strassen_matmul(a, b, levels="auto", leaf=8, mode="fused",
                          block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=1e-4, atol=1e-4)


def test_fused_under_jit():
    a = _rand((64, 48), seed=14)
    f = jax.jit(lambda x: ata(x, levels=2, mode="fused", block=16,
                              interpret=True))
    np.testing.assert_allclose(np.asarray(f(a)),
                               np.asarray(ata(a, levels=2, leaf=16,
                                              mode="reference")),
                               rtol=1e-5, atol=1e-4)


def test_mode_validation():
    a = _rand((8, 8), seed=15)
    with pytest.raises(ValueError):
        ata(a, mode="bogus")
    # fused cannot honor leaf hooks — explicit request must fail loudly
    with pytest.raises(ValueError):
        ata(a, mode="fused", base_syrk=lambda x: x)
    with pytest.raises(ValueError):
        strassen_matmul(a, a, mode="fused", base_matmul=lambda x, y: x @ y)


def test_fused_ata_grad_matches_reference():
    """Dense fused path carries a custom VJP, so mode='auto'->fused on
    TPU keeps jax.grad working; check it against the reference grad."""
    a = _rand((48, 32), seed=21)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(22), (32, 32)))
    def loss(fn):
        return lambda x: jnp.sum(fn(x) * g)
    fused = jax.grad(loss(lambda x: ata(
        x, levels=2, mode="fused", block=8, interpret=True)))(a)
    ref = jax.grad(loss(lambda x: ata(
        x, levels=2, leaf=8, mode="reference")))(a)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # and through ata_full (the shampoo/solver path)
    gf = jax.grad(lambda x: ata_full(x, levels=1, mode="fused", block=8,
                                     interpret=True).sum())(a)
    gr = jax.grad(lambda x: ata_full(x, levels=1, leaf=8,
                                     mode="reference").sum())(a)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


def test_fused_matmul_grad():
    a, b = _rand((24, 16), seed=23), _rand((16, 8), seed=24)
    da, db = jax.grad(
        lambda x, y: strassen_matmul(x, y, levels=1, mode="fused", block=8,
                                     interpret=True).sum(),
        argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(da),
                               np.ones((24, 8)) @ np.asarray(b).T,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(db),
                               np.asarray(a).T @ np.ones((24, 8)),
                               rtol=1e-5, atol=1e-5)


def test_fused_fan_in_clamp():
    """Deep winograd plans exceed the VMEM operand budget; the executor
    must clamp rather than schedule 2*16 gathered tiles per step."""
    from repro.kernels.strassen_fused import _ata_geometry, MAX_OPERAND_TERMS
    geo = _ata_geometry(1 << 12, 1 << 12, 3, "winograd", 256, 256)
    assert geo["plan"].max_terms <= MAX_OPERAND_TERMS
    assert geo["levels"] < 3
    # strassen L3 fan-in (4) fits and is untouched
    geo = _ata_geometry(1 << 12, 1 << 12, 3, "strassen", 256, 256)
    assert geo["levels"] == 3
    # parity still holds where the clamp engages
    a = _rand((64, 64), seed=25)
    got = fused_ata(a, levels=3, variant="winograd", bk=8, bn=8,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(got), _oracle(a),
                               rtol=1e-4, atol=1e-4)


def test_fan_in_clamp_warns_once_with_clamped_value():
    """The MAX_OPERAND_TERMS clamp used to silently shallow the schedule;
    it must warn (naming the clamped value), exactly once per distinct
    clamp, and the shallower plan must actually be used."""
    import warnings as _warnings
    from repro.kernels import strassen_fused as sf

    sf._CLAMP_WARNED.clear()
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        # shape alone allows 3+ levels (4096/256 tiles); winograd ATA L3
        # fan-in is 16 > MAX_OPERAND_TERMS -> clamp to 2 with a warning
        geo = sf._ata_geometry(1 << 12, 1 << 12, 3, "winograd", 256, 256)
        assert geo["levels"] == 2 < 3          # the shallower plan is used
        msgs = [str(w.message) for w in caught
                if "MAX_OPERAND_TERMS" in str(w.message)]
        assert len(msgs) == 1, msgs
        assert "levels=3" in msgs[0] and "clamped to levels=2" in msgs[0]
        # same clamp again -> no second warning
        sf._ata_geometry(1 << 12, 1 << 12, 3, "winograd", 256, 256)
        msgs = [str(w.message) for w in caught
                if "MAX_OPERAND_TERMS" in str(w.message)]
        assert len(msgs) == 1, msgs
    # shape-driven clamps stay silent (expected behaviour, not a surprise)
    sf._CLAMP_WARNED.clear()
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        sf._ata_geometry(128, 128, 3, "strassen", 256, 256)
        assert not [w for w in caught
                    if "MAX_OPERAND_TERMS" in str(w.message)]


def test_dimension_semantics_parity_interpret():
    """All three Pallas grids now declare dimension_semantics (output
    tiles "parallel", contribution/K sweeps "arbitrary") so TPU megacore
    can partition output tiles; results must be bit-for-bit unchanged in
    interpret mode."""
    from repro.kernels import ops

    a = _rand((96, 64), seed=31)
    want = _oracle(a)
    # syrk grid (parallel, arbitrary)
    got = ops.syrk(a, bk=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    # fused-ATA grid (parallel, arbitrary, arbitrary)
    got = fused_ata(a, levels=2, bk=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
    # fused-matmul grid (parallel, parallel, arbitrary, arbitrary)
    b = _rand((64, 48), seed=32)
    got = fused_matmul(a, b, levels=2, bm=16, bk=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_fused_level_clamp_avoids_empty_leaves():
    """Small inputs must not pad to 2^levels x block per dim: the unroll
    depth clamps so each leaf holds at least one tile of real data."""
    model = ata_traffic_model(128, 128, levels=2, bk=256, bn=256)
    assert model["padded_shape"] == (256, 256)      # not (1024, 1024)
    a = _rand((128, 100), seed=16)
    got = ata(a, levels=2, mode="fused", block=256, interpret=True)
    assert got.shape == (100, 100)
    np.testing.assert_allclose(np.asarray(got), _oracle(a),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Schedule properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_schedule_mult_count_matches_cost_model(levels):
    """The schedule's signed leaf contributions sum to exactly the
    multiplication count of Algorithm 1 from core/cost_model (leaf=0 pins
    the cost recursion to the same fixed unroll depth)."""
    plan = plan_ata(levels, "strassen")
    B = plan.blocks
    for mb, nb in [(4, 4), (8, 4), (6, 10)]:
        assert plan.mult_count(mb, nb) == ata_mults_exact(
            mb * B, nb * B, leaf=0, levels=levels)
    mm = plan_matmul(levels, "strassen")
    assert mm.mult_count(8, 4, 6) == strassen_mults_exact(
        8 * B, 6 * B, 4 * B, leaf=0, levels=levels)
    # Strassen saves multiplications over classical from level 1 on
    if levels:
        cl = plan_matmul(levels, "classical")
        assert len(mm.products) == 7 ** levels < len(cl.products)


@pytest.mark.parametrize("levels", [1, 2, 3])
@pytest.mark.parametrize("variant", ["strassen", "winograd"])
def test_schedule_dense_evaluation(levels, variant):
    """Plans evaluated densely in numpy reproduce the operations — the
    schedule is correct independent of the Pallas executor."""
    rng = np.random.RandomState(levels)
    B = 1 << levels
    a = rng.randn(B * 3, B * 2)
    np.testing.assert_allclose(
        evaluate_ata_plan(plan_ata(levels, variant), a),
        np.tril(a.T @ a), rtol=1e-9, atol=1e-9)
    b = rng.randn(B * 2, B * 4)
    np.testing.assert_allclose(
        evaluate_matmul_plan(plan_matmul(levels, variant), a, b),
        a @ b, rtol=1e-9, atol=1e-9)


def test_schedule_destinations_lower_triangular():
    for levels in range(4):
        plan = plan_ata(levels)
        for p in plan.products:
            for di, dj, *_ in p.dests:
                assert di >= dj, "upper-triangular destination scheduled"
        # every lower-triangular leaf destination is covered
        B = plan.blocks
        assert set(plan.by_dest()) == {
            (i, j) for i in range(B) for j in range(i + 1)}


# ---------------------------------------------------------------------------
# Acceptance: 512x512 parity at 1e-5 + HBM intermediate ratio >= 2x
# ---------------------------------------------------------------------------

def test_acceptance_512_parity_and_hbm_ratio():
    a = _rand((512, 512), seed=20)
    fused = fused_ata(a, levels=2, bk=128, bn=128, interpret=True)
    ref = ata(a, levels=2, leaf=64, mode="reference")
    want = _oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(fused, np.float64) - want).max() / scale < 1e-5
    assert np.abs(np.asarray(ref, np.float64) - want).max() / scale < 1e-5

    # reference recursion materializes operand sums / M_i / pad+concat
    # copies in HBM (visible in its compiled HLO); the fused kernel's only
    # HBM temporaries are pad copies (here: none — shape is tile-aligned).
    ref_hlo = jax.jit(
        lambda x: ata(x, levels=2, leaf=64, mode="reference")
    ).lower(a).compile().as_text()
    ref_bytes = hbm_intermediate_census(ref_hlo)["total_bytes"]
    model = ata_traffic_model(512, 512, levels=2, bk=128, bn=128)
    fused_bytes = model["intermediate_bytes"]
    assert ref_bytes >= 2 * fused_bytes and ref_bytes > 1_000_000, (
        ref_bytes, fused_bytes)
    # the analytic side must be a real model, not a constant: its write
    # term is exactly the packed output, its read term covers the padded
    # contribution sweep, and misaligned shapes surface the pad copy.
    t = 512 // 128
    n_tri = t * (t + 1) // 2
    assert model["write_bytes"] == n_tri * 128 * 128 * 4
    plan = plan_ata(2, "strassen")
    assert model["grid_steps"] == n_tri * plan.max_contributions * 1
    assert model["read_bytes"] == (model["grid_steps"] * 2 * plan.max_terms
                                   * 128 * 128 * 4)
    misaligned = ata_traffic_model(257, 511, levels=2, bk=64, bn=64)
    assert misaligned["padded_shape"] == (512, 512)
    assert misaligned["intermediate_bytes"] == 512 * 512 * 4
