"""FlashAttention Pallas kernel vs pure-jnp oracle (interpret mode),
swept over shapes, GQA ratios, dtypes, masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref


def _mk(b, sq, skv, h, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,skv,h,hkv,d", [
    (2, 64, 64, 4, 4, 32),        # MHA square
    (2, 64, 64, 8, 2, 32),        # GQA 4:1
    (1, 128, 128, 4, 1, 16),      # MQA
    (1, 48, 48, 2, 2, 64),        # non-block-multiple seq (padding)
    (2, 32, 96, 4, 4, 32),        # cross-length causal (skv > sq)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(b, sq, skv, h, hkv, d, dtype):
    q, k, v = _mk(b, sq, skv, h, hkv, d, dtype)
    got = ops.flash_mha(q, k, v, causal=True, block_q=32, block_kv=32)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 128, 128, 4, 2, 32, jnp.float32)
    got = ops.flash_mha(q, k, v, causal=True, window=window,
                        block_q=32, block_kv=32)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True,
                               window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_softcap():
    q, k, v = _mk(1, 64, 64, 2, 2, 32, jnp.float32)
    got = ops.flash_mha(q, k, v, causal=True, softcap=50.0,
                        block_q=32, block_kv=32)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True,
                               softcap=50.0).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention():
    """Cross-check vs the model-side attention (layers.attention)."""
    from repro.models.layers import attention
    q, k, v = _mk(2, 64, 64, 4, 2, 32, jnp.float32)
    pos = jnp.arange(64)
    want = attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    got = ops.flash_mha(q, k, v, causal=True, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
