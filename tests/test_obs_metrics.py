"""obs.metrics: instrument semantics, the log-bucketed histogram's O(1)
observe / bucket-resolution quantiles, label-schema pinning, Prometheus
rendering, and the capped-history engine stats they back (DESIGN.md §14)."""
import math

import numpy as np
import pytest

from repro.gram import GramEngine
from repro.obs import metrics
from repro.obs.metrics import Histogram, MetricsRegistry


@pytest.fixture(autouse=True)
def _fresh_registry():
    metrics.reset()
    yield
    metrics.reset()


# ---------------------------------------------------------------------------
# Counters / gauges
# ---------------------------------------------------------------------------

def test_counter_inc_value_total_and_monotonicity():
    c = metrics.counter("served_total", "requests served")
    c.inc(bucket="64x64")
    c.inc(2.5, bucket="64x64")
    c.inc(bucket="128x64")
    assert c.value(bucket="64x64") == 3.5
    assert c.value(bucket="128x64") == 1.0
    assert c.value(bucket="nope") == 0.0
    assert c.total() == 4.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, bucket="64x64")


def test_gauge_set_inc_dec():
    g = metrics.gauge("queue_depth")
    g.set(5, engine="e0")
    g.inc(2, engine="e0")
    g.dec(engine="e0")
    assert g.value(engine="e0") == 6.0


def test_label_schema_pinned_by_first_observation():
    c = metrics.counter("pinned")
    c.inc(bucket="a", rung="0")
    with pytest.raises(ValueError, match="schema"):
        c.inc(bucket="a")                       # missing label
    with pytest.raises(ValueError, match="schema"):
        c.inc(bucket="a", scheme="ring")        # renamed label


def test_registry_rejects_kind_conflicts_and_is_idempotent():
    c = metrics.counter("x_total")
    assert metrics.counter("x_total") is c      # same instrument back
    with pytest.raises(ValueError, match="already registered"):
        metrics.gauge("x_total")


# ---------------------------------------------------------------------------
# Log-bucketed histogram
# ---------------------------------------------------------------------------

def test_histogram_single_sample_quantile_is_that_sample():
    h = metrics.histogram("lat_s")
    h.observe(0.0123, engine="e0")
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(0.0123)


def test_histogram_quantiles_within_one_bucket_ratio():
    """Bucket resolution is base 2^(1/4): any quantile answer must land
    within one bucket ratio of the exact order statistic."""
    h = metrics.histogram("lat_s")
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-5.0, sigma=1.0, size=2000)
    for v in vals:
        h.observe(float(v))
    base = h.base
    for q in (0.10, 0.50, 0.90, 0.99):
        exact = float(np.quantile(vals, q))
        est = h.quantile(q)
        assert exact / base <= est <= exact * base, (q, exact, est)
    assert h.count() == 2000
    assert h.sum() == pytest.approx(float(vals.sum()), rel=1e-9)


def test_histogram_out_of_range_clamps_to_observed_extremes():
    h = metrics.histogram("clamped", lo=1e-3, hi=1.0)
    h.observe(1e-7)                      # underflow bucket
    h.observe(50.0)                      # overflow bucket
    assert h.quantile(0.0) == pytest.approx(1e-7)
    assert h.quantile(1.0) == pytest.approx(50.0)


def test_histogram_partial_label_merge():
    """quantile({"engine": "e0"}) merges that engine's per-bucket series;
    quantile(None) merges everything — the fleet-wide view."""
    h = metrics.histogram("lat_s")
    for v in (1e-3, 2e-3):
        h.observe(v, engine="e0", bucket="64x64")
    for v in (4e-3, 8e-3):
        h.observe(v, engine="e0", bucket="128x64")
    h.observe(1e2, engine="e1", bucket="64x64")
    assert h.count({"engine": "e0"}) == 4
    assert h.count({"engine": "e1"}) == 1
    assert h.count(None) == 5
    # e0's p100 never sees e1's 100s outlier (answers are bucket
    # resolution: within one base ratio, clamped to the observed max)
    p100_e0 = h.quantile(1.0, {"engine": "e0"})
    assert 8e-3 / h.base <= p100_e0 <= 8e-3
    p100_all = h.quantile(1.0)
    assert 1e2 / h.base <= p100_all <= 1e2
    assert h.quantile(0.5, {"engine": "nope"}) is None


def test_histogram_validates_construction():
    with pytest.raises(ValueError):
        Histogram("bad", lo=0.0)
    with pytest.raises(ValueError):
        Histogram("bad", lo=1.0, hi=0.5)
    with pytest.raises(ValueError):
        Histogram("bad", base=1.0)


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def test_snapshot_shape():
    metrics.counter("a_total", "help a").inc(2, k="v")
    metrics.histogram("h").observe(0.5)
    snap = metrics.snapshot()
    assert snap["a_total"]["kind"] == "counter"
    assert snap["a_total"]["series"]["k=v"] == 2.0
    hs = snap["h"]["series"][""]
    assert hs["count"] == 1 and hs["sum"] == 0.5
    assert hs["min"] == 0.5 and hs["max"] == 0.5


def test_render_prometheus_counter_suffix_and_histogram_series():
    metrics.counter("gram_served_total", "served").inc(3, rung="0")
    metrics.counter("plain", "no suffix yet").inc()
    h = metrics.histogram("lat", lo=1e-3, hi=1.0)
    h.observe(5e-3)
    text = metrics.render_prometheus()
    # already-suffixed counters are NOT doubled; bare ones gain _total
    assert 'gram_served_total{rung="0"} 3' in text
    assert "gram_served_total_total" not in text
    assert "plain_total 1" in text
    # histogram: cumulative le buckets, +Inf == count, sum/count lines
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_sum 0.005" in text
    assert "lat_count 1" in text
    buckets = [ln for ln in text.splitlines() if ln.startswith("lat_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts), "le buckets must be cumulative"
    assert "# TYPE lat histogram" in text


def test_local_registry_is_isolated_from_process_registry():
    local = MetricsRegistry()
    local.counter("only_here_total").inc()
    assert "only_here_total" not in metrics.snapshot()
    assert local.snapshot()["only_here_total"]["series"][""] == 1.0


# ---------------------------------------------------------------------------
# The engine stats these instruments back (tentpole satellite: capped
# history + O(1)-update percentiles instead of the unbounded re-sort)
# ---------------------------------------------------------------------------

def test_engine_finished_history_is_capped_but_stats_count_everything():
    rng = np.random.default_rng(3)
    eng = GramEngine(slots=4, levels=0, min_bucket=16, history_cap=8)
    for _ in range(12):
        eng.submit(rng.standard_normal((24, 12)).astype(np.float32))
    finished = eng.run_to_completion()
    assert len(finished) == 8, "finished ring must stay at history_cap"
    st = eng.stats()
    assert st["served"] == 12, "counters must survive history eviction"
    assert st["history_cap"] == 8
    assert st["queue_depth"] == 0
    assert st["p50_latency_s"] is not None
    assert st["p99_latency_s"] >= st["p50_latency_s"]
    # percentiles come from the histogram over ALL 12 observations
    lat = metrics.histogram("gram_request_latency_s")
    assert lat.count({"engine": st["engine"]}) == 12


def test_two_engines_keep_separate_metric_slices():
    rng = np.random.default_rng(4)
    e1 = GramEngine(slots=2, levels=0, min_bucket=16)
    e2 = GramEngine(slots=2, levels=0, min_bucket=16)
    assert e1.engine_label != e2.engine_label
    e1.submit(rng.standard_normal((20, 10)).astype(np.float32))
    e1.run_to_completion()
    lat = metrics.histogram("gram_request_latency_s")
    assert lat.count({"engine": e1.engine_label}) == 1
    assert lat.count({"engine": e2.engine_label}) == 0
    assert e2.stats()["p50_latency_s"] is None
