"""Distributed-gram communication cost model (core.cost_model):
per-scheme wire bytes / message rounds / flops and the ranking that
drives ``distributed_gram(scheme="auto")``.  Pure closed forms — no
devices; the modeled-vs-measured comparison lives in
benchmarks/bench_distributed.py."""
import pytest

from repro.core.cost_model import (GRAM_SCHEMES, GramCommCost,
                                   choose_gram_scheme, gram_comm_cost,
                                   rank_gram_schemes)


def test_reducescatter_strictly_dominates_allreduce():
    for rows in (2, 4, 8, 64):
        ar = gram_comm_cost("allreduce", 4096, 512, rows=rows)
        rs = gram_comm_cost("reducescatter", 4096, 512, rows=rows)
        assert rs.wire_bytes < ar.wire_bytes
        assert rs.messages < ar.messages
        assert rs.flops == ar.flops


def test_bfs25d_replication_cuts_ring_wire_bytes():
    """Same (rows, ring) grid, replication c >= 2 added: the permute phase
    ships ceil(half/c) instead of half hops — per-device wire bytes drop."""
    for c in (2, 4):
        ring = gram_comm_cost("ring", 8192, 1024, rows=2, ring=8)
        bfs = gram_comm_cost("bfs25d", 8192, 1024, rows=2, ring=8, rep=c)
        assert bfs.wire_bytes < ring.wire_bytes
        assert bfs.mem_input_factor == c
        assert bfs.devices == ring.devices * c


def test_bfs25d_fewer_rounds_at_matched_device_count():
    """At equal P (trading row sharding for replication), bfs25d's skewed
    BFS walk needs fewer sequential collective rounds than the ring."""
    ring = gram_comm_cost("ring", 8192, 1024, rows=2, ring=8)      # P=16
    bfs = gram_comm_cost("bfs25d", 8192, 1024, rows=1, ring=8, rep=2)
    assert bfs.devices == ring.devices == 16
    assert bfs.messages < ring.messages


def test_dtype_bytes_scale_wire_not_messages():
    f32 = gram_comm_cost("ring", 1024, 256, rows=2, ring=4, dtype_bytes=4)
    bf16 = gram_comm_cost("ring", 1024, 256, rows=2, ring=4, dtype_bytes=2)
    assert f32.wire_bytes == 2 * bf16.wire_bytes
    assert f32.messages == bf16.messages


def test_rank_covers_requested_schemes_and_sorts_by_time():
    ranked = rank_gram_schemes(4096, 512, rows=2, ring=4, rep=2)
    assert sorted(r.scheme for r in ranked) == sorted(GRAM_SCHEMES)
    times = [r.time() for r in ranked]
    assert times == sorted(times)
    # restricting the candidate set restricts the ranking
    only = rank_gram_schemes(4096, 512, rows=8,
                             schemes=["allreduce", "reducescatter"])
    assert {r.scheme for r in only} == {"allreduce", "reducescatter"}


def test_auto_picks_row_reduction_for_tall_skinny():
    """m >> n: C is tiny, A is huge — shipping A around a ring loses to
    one reduce-scatter of C."""
    assert choose_gram_scheme(1 << 20, 128, rows=8, ring=4, rep=2) in \
        ("reducescatter", "allreduce")
    assert choose_gram_scheme(1 << 20, 128, rows=8) == "reducescatter"


def test_auto_picks_ring_family_for_wide():
    """n >> m/P: the n^2 reduction of C dominates — the ring family, which
    only ever ships (m/R)(n/T) shards and the packed stack, wins."""
    assert choose_gram_scheme(512, 8192, rows=2, ring=4, rep=2) in \
        ("ring", "bfs25d")


def test_model_crossover_between_shapes():
    """The allreduce-vs-ring ranking flips between a tall-skinny and a
    wide shape on the same mesh — the crossover bench_distributed.py
    reproduces with measured (HLO census) volumes."""
    def gap(m, n):
        ar = gram_comm_cost("allreduce", m, n, rows=2)
        ring = gram_comm_cost("ring", m, n, rows=2, ring=4)
        return ar.wire_bytes - ring.wire_bytes
    assert gap(4096, 128) < 0          # tall-skinny: allreduce cheaper
    assert gap(256, 2048) > 0          # wide: ring cheaper


def test_mixed_dtype_charges_permute_at_input_width():
    """bf16 A reduced into fp32 C: the ring's ppermutes ship 2-byte A
    shards while every reduction ships 4-byte C — out_bytes must not
    inflate the permute term."""
    mixed = gram_comm_cost("ring", 4096, 512, rows=2, ring=4,
                           dtype_bytes=2, out_bytes=4)
    all4 = gram_comm_cost("ring", 4096, 512, rows=2, ring=4,
                          dtype_bytes=4, out_bytes=4)
    all2 = gram_comm_cost("ring", 4096, 512, rows=2, ring=4,
                          dtype_bytes=2, out_bytes=2)
    assert all2.wire_bytes < mixed.wire_bytes < all4.wire_bytes
    # row-reduction schemes ship only C: input width is irrelevant
    assert gram_comm_cost("allreduce", 4096, 512, rows=2, dtype_bytes=2,
                          out_bytes=4).wire_bytes == \
        gram_comm_cost("allreduce", 4096, 512, rows=2, dtype_bytes=4,
                       out_bytes=4).wire_bytes


def test_cost_is_a_pure_dataclass():
    cst = gram_comm_cost("allreduce", 64, 32, rows=2)
    assert isinstance(cst, GramCommCost)
    assert cst.time(alpha=0.0, ici_bw=1.0, flop_rate=1e30) == \
        pytest.approx(cst.wire_bytes)


def test_invalid_scheme_and_missing_ring_raise():
    with pytest.raises(ValueError):
        gram_comm_cost("nope", 64, 32, rows=2)
    with pytest.raises(ValueError):
        gram_comm_cost("ring", 64, 32, rows=2)          # ring size missing
    with pytest.raises(ValueError):
        gram_comm_cost("bfs25d", 64, 32, rows=2)
