"""Serving engine: batched slot decode == reference autoregressive loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_arch
from repro.models import init_params, forward
from repro.runtime.serving import ServingEngine


def _ref_greedy(cfg, params, prompt, n_new):
    """Reference: full re-forward per token (no cache)."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _, _ = jax.jit(
            lambda p, t: forward(cfg, p, t, mode="train"))(
            params, jnp.asarray([toks]))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_arch("qwen2.5-3b", num_layers=2, d_model=64, num_heads=2,
                       num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=n).tolist() for n in (5, 9, 13)]
    n_new = 6
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for p in prompts:
        eng.add_request(p, max_new_tokens=n_new)
    finished = eng.run_to_completion()
    assert len(finished) == 3
    by_uid = {r.uid: r for r in finished}
    for uid, prompt in enumerate(prompts):
        want = _ref_greedy(cfg, params, prompt, n_new)
        assert by_uid[uid].generated == want, (
            uid, by_uid[uid].generated, want)


def test_engine_more_requests_than_slots(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for _ in range(5):
        eng.add_request(rng.integers(0, 256, size=6).tolist(),
                        max_new_tokens=3)
    finished = eng.run_to_completion()
    assert len(finished) == 5
    assert all(len(r.generated) == 3 for r in finished)


def test_engine_eos_stops(setup):
    cfg, params = setup
    # find the first greedy token, then use it as "eos" — generation must
    # stop after 1 token.
    prompt = [3, 1, 4, 1, 5]
    first = _ref_greedy(cfg, params, prompt, 1)[0]
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    eng.add_request(prompt, max_new_tokens=8, eos_id=first)
    finished = eng.run_to_completion()
    assert finished[0].generated == [first]
