"""Async Gram serving (DESIGN.md §15): futures, background scheduler,
admission control / CoDel shedding, EDF + weighted-fair scheduling,
cancellation races, shutdown semantics, and the backoff-cap regression.
"""
import threading
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.gram import (EngineShutdown, GramEngine, GramFuture,
                        GramServeError, Overloaded)
from repro.obs import trace
from repro.obs.trace import Tracer
from repro.runtime import faults
from repro.runtime.faults import FaultSpec


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


def _a(rng, m=20, n=10):
    return rng.standard_normal((m, n)).astype(np.float32)


def _engine(**kw):
    kw.setdefault("slots", 2)
    kw.setdefault("levels", 0)
    kw.setdefault("min_bucket", 16)
    return GramEngine(**kw)


# ---------------------------------------------------------------------------
# Futures
# ---------------------------------------------------------------------------

def test_submit_returns_future_and_result_matches_sync_semantics():
    rng = np.random.default_rng(0)
    eng = _engine()
    a = _a(rng)
    fut = eng.submit(a)
    assert isinstance(fut, GramFuture)
    assert not fut.done() and not fut.cancelled()
    eng.run_to_completion()
    assert fut.done()
    np.testing.assert_allclose(fut.result(timeout=1), a.T @ a, atol=1e-3)
    assert fut.exception() is None
    assert fut.request.status == "ok"


def test_future_timeout_and_done_callbacks_fire_exactly_once():
    rng = np.random.default_rng(1)
    eng = _engine()
    fut = eng.submit(_a(rng))
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    calls = []
    fut.add_done_callback(lambda f: calls.append(f.uid))
    eng.run_to_completion()
    # registered-after-done callbacks run immediately
    fut.add_done_callback(lambda f: calls.append(-f.uid - 1))
    assert calls == [fut.uid, -fut.uid - 1]


def test_failed_request_raises_gram_serve_error_through_future():
    rng = np.random.default_rng(2)
    eng = _engine(max_retries=0, verify="off")
    fut = eng.submit(_a(rng, 16, 16))
    with faults.inject(FaultSpec("exec_fail", site="gram.engine.exec*")):
        eng.run_to_completion()
    with pytest.raises(GramServeError):
        fut.result(timeout=1)
    assert fut.request.status == "failed"


def test_serve_is_a_thin_sync_wrapper():
    rng = np.random.default_rng(3)
    eng = _engine()
    a = _a(rng, 24, 12)
    np.testing.assert_allclose(eng.serve(a, timeout=5), a.T @ a, atol=1e-3)
    assert eng.stats()["served"] == 1


# ---------------------------------------------------------------------------
# Background scheduler
# ---------------------------------------------------------------------------

def test_background_scheduler_serves_without_stepping():
    rng = np.random.default_rng(4)
    eng = _engine().start()
    try:
        arrays = [_a(rng) for _ in range(8)]
        futs = [eng.submit(a) for a in arrays]
        for f, a in zip(futs, arrays):
            np.testing.assert_allclose(f.result(timeout=30), a.T @ a,
                                       atol=1e-3)
        assert eng.drain(timeout=5)
        assert eng.stats()["scheduler_running"]
    finally:
        eng.shutdown()
    assert not eng.stats()["scheduler_running"]


def test_start_is_idempotent_and_restartable_after_shutdown():
    rng = np.random.default_rng(5)
    eng = _engine().start()
    assert eng.start() is eng
    eng.shutdown()
    eng.start()
    try:
        assert eng.submit(_a(rng)).result(timeout=30) is not None
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_global_queue_bound_sheds_with_overloaded():
    rng = np.random.default_rng(6)
    eng = _engine(max_queue=3)
    futs = [eng.submit(_a(rng)) for _ in range(5)]
    shed = [f for f in futs if f.done()]
    assert len(shed) == 2                   # 3 admitted, 2 shed at submit
    for f in shed:
        with pytest.raises(Overloaded):
            f.result()
        assert f.request.status == "shed"
    eng.run_to_completion()
    s = eng.stats()
    assert s["served"] == 3 and s["shed"] == 2
    assert s["queue_peak"] <= 3


def test_per_bucket_bound_sheds_only_that_bucket():
    rng = np.random.default_rng(7)
    eng = _engine(max_queue_per_bucket=2)
    small = [eng.submit(_a(rng, 16, 16)) for _ in range(4)]
    big = eng.submit(_a(rng, 64, 32))       # different bucket: admitted
    assert sum(f.done() for f in small) == 2
    assert not big.done()
    eng.run_to_completion()
    assert big.request.status == "ok"


def test_tenant_quota_sheds_flooder_not_neighbor():
    rng = np.random.default_rng(8)
    eng = _engine(tenant_quota=2)
    flood = [eng.submit(_a(rng), tenant="abuser") for _ in range(6)]
    good = eng.submit(_a(rng), tenant="good")
    assert sum(f.done() for f in flood) == 4
    assert not good.done()
    eng.run_to_completion()
    s = eng.stats()
    assert s["tenants"]["abuser"]["shed"] == 4
    assert s["tenants"]["good"]["shed"] == 0
    assert good.request.status == "ok"


def test_block_admission_waits_then_sheds_on_timeout():
    rng = np.random.default_rng(9)
    eng = _engine(max_queue=1, admission="block", block_timeout_s=0.05)
    eng.submit(_a(rng))
    t0 = time.perf_counter()
    fut = eng.submit(_a(rng))
    waited = time.perf_counter() - t0
    assert waited >= 0.05
    with pytest.raises(Overloaded, match="timeout"):
        fut.result()


def test_block_admission_succeeds_when_scheduler_frees_space():
    rng = np.random.default_rng(10)
    eng = _engine(max_queue=1, admission="block",
                  block_timeout_s=10.0).start()
    try:
        arrays = [_a(rng) for _ in range(6)]
        futs = [eng.submit(a) for a in arrays]
        for f, a in zip(futs, arrays):
            np.testing.assert_allclose(f.result(timeout=30), a.T @ a,
                                       atol=1e-3)
        assert eng.stats()["shed"] == 0
    finally:
        eng.shutdown()


def test_codel_sheds_unmeetable_deadlines_not_newest():
    """Once the engine has measured a batch, requests whose deadline the
    queue ahead already blows are shed at submit — the newest arrival
    with a generous deadline is still admitted."""
    rng = np.random.default_rng(11)
    eng = _engine(slots=2)
    # prime the service-time estimator with a slow measured batch
    eng.submit(_a(rng))
    with faults.inject(FaultSpec("exec_delay", delay=0.05,
                                 site="gram.engine.exec*", times=1)):
        eng.run_to_completion()
    assert eng.stats()["sec_per_work_unit"] is not None
    # backlog: 2 fill the first batch (queue ahead = 0 batches), the
    # tight-deadline 3rd is unmeetable, a deadline-less 4th still admits
    f1 = eng.submit(_a(rng), deadline_s=30.0)
    f2 = eng.submit(_a(rng), deadline_s=30.0)
    doomed = eng.submit(_a(rng), deadline_s=1e-4)
    newest = eng.submit(_a(rng))
    assert doomed.done()
    with pytest.raises(Overloaded, match="unmeetable"):
        doomed.result()
    assert not newest.done()
    eng.run_to_completion()
    assert [f.request.status for f in (f1, f2, newest)] == ["ok"] * 3


# ---------------------------------------------------------------------------
# Deadline- and tenant-aware scheduling
# ---------------------------------------------------------------------------

def test_edf_within_bucket_serves_tightest_deadline_first():
    rng = np.random.default_rng(12)
    eng = _engine(slots=2)
    loose = [eng.submit(_a(rng), deadline_s=100.0) for _ in range(2)]
    tight = [eng.submit(_a(rng), deadline_s=1.0) for _ in range(2)]
    done = eng.step()                       # one batch of 2
    assert {r.uid for r in done} == {f.uid for f in tight}
    assert all(not f.done() for f in loose)


def test_priority_beats_deadline_beats_fifo():
    rng = np.random.default_rng(13)
    eng = _engine(slots=1)
    fifo = eng.submit(_a(rng))
    dead = eng.submit(_a(rng), deadline_s=50.0)
    prio = eng.submit(_a(rng), priority=1)
    order = [eng.step()[0].uid for _ in range(3)]
    assert order == [prio.uid, dead.uid, fifo.uid]


def test_wfq_interleaves_tenants_instead_of_draining_flood_first():
    rng = np.random.default_rng(14)
    eng = _engine(slots=2)
    # the abuser floods one bucket first; the good tenant's two requests
    # land in another bucket afterwards
    ab = [eng.submit(_a(rng, 16, 16), tenant="abuser") for _ in range(8)]
    good = [eng.submit(_a(rng, 64, 32), tenant="good") for _ in range(2)]
    eng.step()                              # abuser (both vtimes equal)
    eng.step()                              # WFQ: good's turn
    assert all(f.done() for f in good), \
        "good tenant waited behind the whole flood"
    assert sum(f.done() for f in ab) == 2
    eng.run_to_completion()
    s = eng.stats()
    assert s["tenants"]["abuser"]["served"] == 8
    assert s["tenants"]["good"]["served"] == 2


def test_tenant_weights_bias_the_interleave():
    rng = np.random.default_rng(15)
    eng = _engine(slots=2, tenant_weights={"heavy": 4.0, "light": 1.0})
    heavy = [eng.submit(_a(rng, 16, 16), tenant="heavy")
             for _ in range(8)]
    light = [eng.submit(_a(rng, 64, 32), tenant="light")
             for _ in range(8)]
    # after 3 batches the 4x-weighted tenant should have served more
    for _ in range(3):
        eng.step()
    assert sum(f.done() for f in heavy) > sum(f.done() for f in light)
    eng.run_to_completion()


def test_tenant_max_inflight_caps_a_batch_share():
    rng = np.random.default_rng(16)
    eng = _engine(slots=4, tenant_max_inflight=2)
    [eng.submit(_a(rng), tenant="abuser") for _ in range(4)]
    good = eng.submit(_a(rng), tenant="good")
    done = eng.step()                       # 2 abuser + 1 good, not 4 abuser
    by_tenant = {}
    for r in done:
        by_tenant[r.tenant] = by_tenant.get(r.tenant, 0) + 1
    assert by_tenant == {"abuser": 2, "good": 1}
    assert good.done()
    eng.run_to_completion()


# ---------------------------------------------------------------------------
# Cancellation races + shutdown
# ---------------------------------------------------------------------------

def test_cancel_queued_request_is_terminal_and_counted():
    rng = np.random.default_rng(17)
    eng = _engine()
    fut = eng.submit(_a(rng))
    assert fut.cancel()
    assert fut.cancelled() and fut.done()
    with pytest.raises(CancelledError):
        fut.result()
    assert not fut.cancel()                 # second cancel: already done
    assert eng.run_to_completion() is not None
    s = eng.stats()
    assert s["cancelled"] == 1 and s["served"] == 0
    assert s["queue_depth"] == 0


def test_cancel_race_with_inflight_batch_delivers_or_cancels_exactly_once():
    """Hammer cancel() from threads while the scheduler drains slow
    batches: every future must end exactly once — delivered (cancel
    returned False) or cancelled (never both, never dropped)."""
    rng = np.random.default_rng(18)
    eng = _engine(slots=2).start()
    outcomes = []
    lock = threading.Lock()
    try:
        with faults.inject(FaultSpec("exec_delay", delay=0.02,
                                     site="gram.engine.exec*")):
            futs = [eng.submit(_a(rng)) for _ in range(24)]
            for f in futs:
                f.add_done_callback(
                    lambda g: (lock.__enter__(),
                               outcomes.append(g.uid),
                               lock.__exit__(None, None, None)))

            def hammer(fs):
                for f in fs:
                    f.cancel()
                    time.sleep(0.002)
            threads = [threading.Thread(target=hammer, args=(futs[i::3],))
                       for i in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert eng.drain(timeout=60)
    finally:
        eng.shutdown()
    # exactly-once: every future terminal, one callback each
    assert all(f.done() for f in futs)
    assert sorted(outcomes) == sorted(f.uid for f in futs)
    statuses = {f.request.status for f in futs}
    assert statuses <= {"ok", "cancelled"}
    for f in futs:
        if f.request.status == "ok":
            assert not f.cancelled() and f.result() is not None
        else:
            assert f.cancelled()
    s = eng.stats()
    assert s["served"] + s["cancelled"] == 24


def test_shutdown_with_nonempty_queue_fails_pending_futures_no_hang():
    rng = np.random.default_rng(19)
    eng = _engine(slots=2).start()
    with faults.inject(FaultSpec("exec_delay", delay=0.05,
                                 site="gram.engine.exec*")):
        futs = [eng.submit(_a(rng)) for _ in range(12)]
        t0 = time.perf_counter()
        n_failed = eng.shutdown(timeout=30)
        assert time.perf_counter() - t0 < 30
    assert n_failed > 0, "queue drained before shutdown could test it"
    for f in futs:
        assert f.done(), "shutdown left a future hanging"
        if f.request.status == "failed":
            with pytest.raises(EngineShutdown):
                f.result()
    # submits after shutdown fail fast, exceptionally
    late = eng.submit(_a(rng))
    with pytest.raises(EngineShutdown):
        late.result(timeout=1)


# ---------------------------------------------------------------------------
# Backoff cap regression (deadline_s=None must not sleep unboundedly)
# ---------------------------------------------------------------------------

def test_backoff_capped_for_deadline_less_requests():
    rng = np.random.default_rng(20)
    eng = _engine(backoff_s=0.01, max_backoff_s=0.02, max_retries=3,
                  verify="off")
    fut = eng.submit(_a(rng, 16, 16))       # no deadline
    t0 = time.perf_counter()
    with faults.inject(FaultSpec("exec_fail", site="gram.engine.exec*")):
        eng.run_to_completion()
    wall = time.perf_counter() - t0
    assert fut.request.status == "failed"
    # uncapped exponential would be 0.01*(1+2+4) = 70ms minimum and
    # grows without bound at higher retry budgets; capped is <= 3*20ms
    # plus execution overhead
    assert wall < 1.0, f"backoff not capped: {wall:.2f}s for 3 retries"


def test_backoff_unit_cap_direct():
    eng = _engine(backoff_s=0.01, max_backoff_s=0.05)
    fut = eng.submit(np.ones((16, 16), np.float32))
    t0 = time.perf_counter()
    eng._backoff(attempt=20, batch=[fut.request])   # uncapped: ~2.9h
    assert time.perf_counter() - t0 < 1.0
    eng.run_to_completion()


# ---------------------------------------------------------------------------
# Overload observability: admit/shed/deadline_miss instants + ring reuse
# ---------------------------------------------------------------------------

def test_overload_trace_has_admit_shed_and_deadline_miss_instants():
    rng = np.random.default_rng(21)
    tracer = trace.set_tracer(Tracer(enabled=True))
    try:
        eng = _engine(max_queue_per_bucket=2)
        futs = [eng.submit(_a(rng), tenant="t0") for _ in range(4)]
        late = eng.submit(_a(rng, 64, 32), tenant="t1", deadline_s=0.0)
        time.sleep(0.002)
        eng.run_to_completion()
        by_name = {}
        for e in tracer.events():
            by_name.setdefault(e.name, []).append(e)
        admits = by_name.get("admit", [])
        sheds = by_name.get("shed", [])
        misses = by_name.get("deadline_miss", [])
        assert {e.trace_id for e in admits} == {futs[0].uid, futs[1].uid,
                                                late.uid}
        assert {e.trace_id for e in sheds} == {futs[2].uid, futs[3].uid}
        assert [e.trace_id for e in misses] == [late.uid]
        # the instants carry tenant + bucket labels (the "why was this
        # shed" story in Perfetto) and the shed reason
        for e in admits + sheds + misses:
            assert e.attrs["tenant"] in ("t0", "t1")
            assert "x" in e.attrs["bucket"]
        assert all(e.attrs["reason"] == "bucket_full" for e in sheds)
        # deadline_miss is stamped at the deadline, not at detection
        assert misses[0].t0 <= time.perf_counter()
    finally:
        trace.set_tracer(None)


def test_operand_ring_reuses_buffers_in_steady_state():
    rng = np.random.default_rng(22)
    eng = _engine(slots=2, ring_depth=4)
    for _ in range(6):                      # 3 waves through one bucket
        futs = [eng.submit(_a(rng)) for _ in range(2)]
        eng.run_to_completion()
        assert all(f.request.status == "ok" for f in futs)
    ring = eng.stats()["ring"]
    assert ring["hits"] == 12 and ring["misses"] == 0
    # ring exhaustion falls back to allocation, never an error
    futs = [eng.submit(_a(rng)) for _ in range(6)]
    eng.run_to_completion()
    assert all(f.request.status == "ok" for f in futs)
    assert eng.stats()["ring"]["misses"] == 2
