"""Trainer integration: loss decreases, fault-injection restart resumes
exactly, straggler watchdog flags outliers."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import reduced_arch
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import (Trainer, FailureInjector,
                                   SimulatedFailure, StragglerWatchdog)


def _tiny_cfg():
    return reduced_arch("qwen2.5-3b", num_layers=2, d_model=64,
                        num_heads=2, num_kv_heads=2, d_ff=128,
                        vocab_size=128, head_dim=32)


def _tc(**kw):
    base = dict(learning_rate=3e-3, warmup_steps=5, total_steps=40,
                checkpoint_every=10, seed=0)
    base.update(kw)
    return TrainConfig(**base)


def _dc():
    return DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=0,
                      noise=0.0)


def test_loss_decreases(tmp_path):
    tr = Trainer(_tiny_cfg(), _tc(), _dc(), str(tmp_path))
    hist = tr.run(30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_failure_injection_and_bitexact_resume(tmp_path):
    cfg, tc, dc = _tiny_cfg(), _tc(), _dc()
    # uninterrupted reference run to step 20
    ref = Trainer(cfg, tc, dc, str(tmp_path / "ref"))
    ref.run(20)
    ref_params = jax.device_get(ref.state["params"])

    # crashing run: dies at step 14 (after checkpoint at 10)
    crash_dir = str(tmp_path / "crash")
    tr = Trainer(cfg, tc, dc, crash_dir, failure=FailureInjector(14))
    with pytest.raises(SimulatedFailure):
        tr.run(20)
    assert tr.step == 14

    # restart: must restore step 10 checkpoint and replay 11..20
    tr2 = Trainer(cfg, tc, dc, crash_dir)
    assert tr2.step == 10, "restored from the last committed checkpoint"
    tr2.run(20)
    got = jax.device_get(tr2.state["params"])
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_grad_accumulation_matches_full_batch():
    """microbatched gradient accumulation == one big batch, compared at the
    fp32 gradient level (params are bf16, so post-update comparison would
    only see rounding ulps)."""
    import jax.numpy as jnp
    from repro.data.pipeline import get_batch
    from repro.models import init_params, loss_fn

    cfg, dc = _tiny_cfg(), _dc()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = get_batch(dc, 0)

    g_full = jax.jit(jax.grad(
        lambda p, b: loss_fn(cfg, p, b)[0]))(params, batch)

    k = 4
    mb = jax.tree.map(
        lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, g_full)
    for i in range(k):
        gi = jax.jit(jax.grad(lambda p, b: loss_fn(cfg, p, b)[0]))(
            params, jax.tree.map(lambda x: x[i], mb))
        g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype) / k,
                             g_acc, gi)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        scale = np.abs(a).max() + 1e-6
        assert np.abs(a - b).max() <= 5e-2 * scale + 1e-3, \
            f"leaf diff {np.abs(a - b).max()} vs scale {scale}"


def test_straggler_watchdog_flags():
    wd = StragglerWatchdog(warmup=2, threshold=2.0)
    for _ in range(6):
        assert not wd.observe(0.1)
    assert wd.observe(0.5)               # 5x slower -> flagged
    assert len(wd.flagged) == 1
    assert not wd.observe(0.11)          # back to normal


def test_shampoo_trainer_runs(tmp_path):
    tc = _tc(optimizer="shampoo", shampoo_block_size=64,
             shampoo_precond_interval=5, ata_levels=1)
    tr = Trainer(_tiny_cfg(), tc, _dc(), str(tmp_path))
    hist = tr.run(8)
    assert np.isfinite(hist[-1]["loss"])
