"""runtime.faults: the injection registry driving the robustness drills."""
import json
import math

import numpy as np
import pytest

from repro.runtime import faults
from repro.runtime.faults import FaultRegistry, FaultSpec, InjectedFault


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.reset()
    yield
    faults.reset()


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor_strike")


def test_null_registry_hooks_are_noops():
    arr = np.ones((4, 4))
    assert faults.poison("poison_output", "anywhere", arr) is arr
    assert not faults.fire("mesh_shrink", "anywhere")
    faults.check_exec("anywhere")          # no raise


def test_inject_exec_fail_and_restore():
    with faults.inject(FaultSpec("exec_fail", site="gram.engine.exec*")):
        with pytest.raises(InjectedFault):
            faults.check_exec("gram.engine.exec.local.32x32")
        # site glob: non-matching sites stay healthy
        faults.check_exec("gram.autotune.cache")
    faults.check_exec("gram.engine.exec.local.32x32")   # registry restored


def test_inject_nests():
    with faults.inject(FaultSpec("exec_fail")) as outer:
        with faults.inject(FaultSpec("mesh_shrink")) as inner:
            assert faults.active() is inner
            faults.check_exec("x")          # exec_fail not armed inside
            assert faults.fire("mesh_shrink", "x")
        assert faults.active() is outer
        with pytest.raises(InjectedFault):
            faults.check_exec("x")


def test_times_budget_exhausts():
    with faults.inject(FaultSpec("exec_fail", times=2)) as reg:
        for _ in range(2):
            with pytest.raises(InjectedFault):
                faults.check_exec("s")
        faults.check_exec("s")              # budget spent
        assert reg.count("exec_fail") == 2


def test_poison_copies_never_mutates():
    arr = np.zeros((3, 16, 16), np.float32)
    with faults.inject(FaultSpec("poison_output", value=math.inf)) as reg:
        out = faults.poison("poison_output", "s", arr)
    assert out is not arr
    assert np.isfinite(arr).all(), "input mutated in place"
    assert np.isinf(out).any()
    assert reg.events[-1].detail.startswith("tile[")


def test_poison_finite_value_for_silent_corruption():
    arr = np.ones((16, 16), np.float32)
    with faults.inject(FaultSpec("poison_output", value=7.5)):
        out = faults.poison("poison_output", "s", arr)
    assert np.isfinite(out).all()
    assert (out == 7.5).any() and not (out == 7.5).all()  # one <=8x8 tile


def test_rate_is_seeded_and_reproducible():
    def trace(seed):
        reg = FaultRegistry([FaultSpec("exec_fail", rate=0.3)], seed=seed)
        return [reg.match("exec_fail", "s") is not None for _ in range(64)]
    a, b, c = trace(3), trace(3), trace(4)
    assert a == b
    assert a != c
    assert 0 < sum(a) < 64


def test_corrupt_file_truncates_to_half(tmp_path):
    p = tmp_path / "cache.json"
    payload = json.dumps({"entries": {str(i): i for i in range(50)}})
    p.write_text(payload)
    with faults.inject(FaultSpec("cache_corrupt")):
        assert faults.corrupt_file("gram.autotune.cache", p)
    raw = p.read_text()
    assert len(raw) == len(payload) // 2
    with pytest.raises(ValueError):
        json.loads(raw)


def test_parse_profile_roundtrip():
    reg = faults.parse_profile(
        "poison_output:rate=0.1,value=inf,site=gram.*;"
        "exec_fail:rate=0.05,times=3;exec_delay:delay=0.5", seed=9)
    kinds = [s.kind for s in reg.specs]
    assert kinds == ["poison_output", "exec_fail", "exec_delay"]
    assert reg.specs[0].rate == 0.1 and math.isinf(reg.specs[0].value)
    assert reg.specs[0].site == "gram.*"
    assert reg.specs[1].times == 3
    assert reg.specs[2].delay == 0.5


def test_parse_profile_rejects_unknown_key():
    with pytest.raises(ValueError, match="unknown fault spec key"):
        faults.parse_profile("exec_fail:severity=11")


def test_env_profile_activates_and_tracks_value(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "exec_fail:site=env.*")
    with pytest.raises(InjectedFault):
        faults.check_exec("env.site")
    faults.check_exec("other.site")
    monkeypatch.setenv(faults.ENV_VAR, "mesh_shrink:times=1")
    faults.check_exec("env.site")           # re-parsed on value change
    assert faults.fire("mesh_shrink", "env.site")
    assert not faults.fire("mesh_shrink", "env.site")


def test_installed_registry_overrides_env(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "exec_fail")
    with faults.inject():                   # nothing armed
        faults.check_exec("s")
    with pytest.raises(InjectedFault):
        faults.check_exec("s")              # env profile back in force


# ---------------------------------------------------------------------------
# Flight-recorder integration: every firing is timestamped, sequenced,
# and mirrored as an instant on the trace timeline (DESIGN.md §14)
# ---------------------------------------------------------------------------

def test_events_carry_monotonic_time_and_sequence():
    import time
    with faults.inject(FaultSpec("exec_fail", site="s*"),
                       FaultSpec("mesh_shrink")) as reg:
        for _ in range(3):
            with pytest.raises(InjectedFault):
                faults.check_exec("s1")
        assert faults.fire("mesh_shrink", "anywhere")
    evs = reg.events
    assert len(evs) == 4
    # seq: strictly increasing, 1-based, gap-free per registry
    assert [e.seq for e in evs] == [1, 2, 3, 4]
    # t: the tracer's clock (perf_counter), non-decreasing
    ts = [e.t for e in evs]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    assert all(0 < t <= time.perf_counter() for t in ts)


def test_each_registry_sequences_independently():
    with faults.inject(FaultSpec("exec_fail")) as outer:
        with pytest.raises(InjectedFault):
            faults.check_exec("a")
        with faults.inject(FaultSpec("exec_fail")) as inner:
            with pytest.raises(InjectedFault):
                faults.check_exec("b")
        with pytest.raises(InjectedFault):
            faults.check_exec("c")
    assert [e.seq for e in outer.events] == [1, 2]
    assert [e.seq for e in inner.events] == [1]


def test_fault_firings_land_on_the_trace_timeline():
    from repro.obs import trace
    tracer = trace.set_tracer(trace.Tracer(enabled=True))
    with faults.inject(FaultSpec("exec_fail")) as reg:
        with pytest.raises(InjectedFault):
            faults.check_exec("gram.engine.exec.local")
    (ev,) = tracer.events()
    assert ev.name == "fault:exec_fail" and ev.ph == "i"
    assert ev.attrs["site"] == "gram.engine.exec.local"
    assert ev.attrs["seq"] == reg.events[0].seq
