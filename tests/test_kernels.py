"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core.symmetry import unpack_tril_blocks


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-1) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


SHAPES_MM = [
    (32, 32, 32), (64, 128, 32), (100, 70, 50), (256, 256, 256),
    (257, 129, 65),  # non-divisible edge tiles
    (16, 512, 16),
]


@pytest.mark.parametrize("m,k,n", SHAPES_MM)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(m, k, n, dtype):
    a, b = _rand((m, k), dtype, 1), _rand((k, n), dtype, 2)
    got = ops.matmul(a, b, bm=32, bk=32, bn=32, interpret=True)
    want = ref.matmul_ref(a, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


SHAPES_SYRK = [(64, 64), (128, 32), (96, 96), (100, 40), (33, 65), (256, 128)]


@pytest.mark.parametrize("m,n", SHAPES_SYRK)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syrk_kernel_packed(m, n, dtype):
    bn = bk = 32
    a = _rand((m, n), dtype, 3)
    got = ops.syrk_packed(a, bk=bk, bn=bn, interpret=True)
    ap = jnp.pad(a, (((-m) % bk and (0, (-m) % bk)) or (0, 0),
                     ((-n) % bn and (0, (-n) % bn)) or (0, 0)))
    want = ref.syrk_packed_ref(ap, bn)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,n", SHAPES_SYRK)
def test_syrk_dense_matches_tril(m, n):
    a = _rand((m, n), jnp.float32, 4)
    got = ops.syrk(a, bk=32, bn=32, interpret=True)
    want = jnp.tril(a.T @ a)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    got_sym = ops.syrk(a, bk=32, bn=32, symmetrize=True, interpret=True)
    np.testing.assert_allclose(got_sym, a.T @ a, rtol=1e-4, atol=1e-4)


def test_syrk_saves_upper_blocks():
    """The packed output has T(T+1)/2 blocks — upper blocks never exist."""
    a = _rand((64, 128), jnp.float32, 5)
    packed = ops.syrk_packed(a, bk=32, bn=32, interpret=True)
    t = 128 // 32
    assert packed.shape == (t * (t + 1) // 2 * 32, 32)  # vs t*t*32 dense


@pytest.mark.parametrize("m,n", [(64, 64), (32, 96), (100, 50), (256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_kernel(m, n, dtype):
    ms = [_rand((m, n), dtype, 10 + i) for i in range(7)]
    got = ops.strassen_combine(*ms, bm=32, bn=32, interpret=True)
    want = ref.strassen_combine_ref(*ms)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,n", [(32, 32), (64, 128), (100, 70), (257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_transpose_kernel(m, n, dtype):
    if dtype == jnp.int32:
        a = jnp.arange(m * n, dtype=dtype).reshape(m, n)
    else:
        a = _rand((m, n), dtype, 6)
    got = ops.transpose(a, bm=32, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.transpose_ref(a), np.float32))


def test_ata_with_pallas_base():
    """Core ATA recursion with Pallas kernels as the leaf ops."""
    from repro.core import ata
    from repro.kernels import pallas_base_matmul, pallas_base_syrk
    a = _rand((128, 96), jnp.float32, 7)
    got = ata(a, levels=1, leaf=32,
              base_syrk=pallas_base_syrk(bk=32, bn=32, interpret=True),
              base_matmul=pallas_base_matmul(32, 32, 32, interpret=True))
    np.testing.assert_allclose(got, jnp.tril(a.T @ a), rtol=1e-4, atol=1e-4)
