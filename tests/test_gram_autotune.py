"""Autotune cache: search, persistence, invalidation, and the ops-default
consultation path (REPRO_AUTOTUNE_CACHE pointed at a tmp file so the
repo-level cache is never touched by tests)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gram import autotune as at
from repro.kernels import ops


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    return path


def test_bucketing_is_pow2(tmp_cache):
    assert at.bucket_shape(100, 60) == (128, 64)
    assert at.bucket_shape(1, 1) == (32, 32)


def test_candidate_space_drops_oversized_blocks():
    cands = at.candidate_space(64, 64, blocks=(32, 128, 512))
    assert all(c["bk"] <= 128 for c in cands)
    assert {c["mode"] for c in cands} == {"fused", "reference"}


def test_model_score_penalizes_fanin_amplification():
    """More Strassen levels -> more padded contribution slots -> more
    modeled read traffic at fixed shape/blocks (the honest null-slot
    accounting of ata_traffic_model)."""
    base = {"mode": "fused", "variant": "strassen", "bm": 32, "bk": 32,
            "bn": 32}
    s0 = at.model_score(256, 256, {**base, "levels": 0})
    s2 = at.model_score(256, 256, {**base, "levels": 2})
    assert s2 > s0


def test_autotune_persists_and_lookup_roundtrips(tmp_cache):
    entry = at.autotune(100, 60, blocks=(16, 32), levels=(0, 1),
                        measure=False)
    assert tmp_cache.exists()
    raw = json.loads(tmp_cache.read_text())
    assert raw["version"] == 2 and len(raw["entries"]) == 1
    # any shape in the same bucket hits the same entry
    assert at.lookup(70, 33) == entry
    assert at.lookup(100, 60) == entry
    # different bucket: miss
    assert at.lookup(1000, 1000) is None


def test_autotune_measured_beats_model_ranking(tmp_cache):
    """measure=True compiles+times the top-K and records the source."""
    entry = at.autotune(32, 32, blocks=(16, 32), levels=(0, 1),
                        modes=("reference",), measure=True, interpret=True)
    assert entry["source"] == "measured"
    assert entry["measured_s"] > 0


def test_cache_mtime_invalidation(tmp_cache):
    at.autotune(100, 60, blocks=(16,), levels=(0,), measure=False)
    assert at.lookup(100, 60) is not None
    tmp_cache.write_text(json.dumps({"version": 1, "entries": {}}))
    assert at.lookup(100, 60) is None      # re-read after mtime change


def test_refresh_overwrites_entry(tmp_cache):
    e1 = at.autotune(40, 40, blocks=(16,), levels=(0,), measure=False)
    e2 = at.autotune(40, 40, blocks=(32,), levels=(1,), measure=False)
    assert e2 == e1                        # cached hit wins without refresh
    e3 = at.autotune(40, 40, blocks=(32,), levels=(1,), measure=False,
                     refresh=True)
    assert e3["bk"] == 32 and e3["levels"] == 1


def test_ops_defaults_consult_cache(tmp_cache):
    """kernels/ops.py block defaults come from the tuned winner (and fall
    back to 256 when untuned); explicit arguments always win."""
    assert ops._resolve_blocks("ata", 50, 33, jnp.float32,
                               bk=None, bn=None) == {"bk": 256, "bn": 256}
    at.autotune(50, 33, blocks=(16, 32), levels=(0,), measure=False)
    tuned = at.lookup(50, 33)
    resolved = ops._resolve_blocks("ata", 50, 33, jnp.float32,
                                   bk=None, bn=None)
    assert resolved == {"bk": tuned["bk"], "bn": tuned["bn"]}
    assert ops._resolve_blocks("ata", 50, 33, jnp.float32,
                               bk=64, bn=None)["bk"] == 64


def test_tuned_blocks_run_correctly(tmp_cache):
    """End to end: tune a bucket, then call the default-blocked fused op
    and check numerics against the oracle."""
    at.autotune(64, 32, blocks=(16,), levels=(1,), modes=("fused",),
                measure=False)
    a = jax.random.normal(jax.random.PRNGKey(0), (60, 30), jnp.float32)
    got = np.asarray(ops.ata_fused(a, levels=1, interpret=True), np.float64)
    a64 = np.asarray(a, np.float64)
    want = np.tril(a64.T @ a64)
    assert np.abs(got - want).max() / np.abs(want).max() < 1e-5


def test_autotune_bwd_candidates(tmp_cache):
    """kind="ata_bwd" tunes the backward: fused candidates scored with
    the exact backward traffic model, persisted under their own kind key,
    and measurable as jax.grad wall clock through either VJP engine."""
    entry = at.autotune(64, 64, kind="ata_bwd", blocks=(16, 32),
                        levels=(0, 1), measure=False)
    assert entry["mode"] == "fused"        # model-only ranks fused only
    key_kinds = {k.split("/")[3] for k in at.load_cache()}
    assert "ata_bwd" in key_kinds
    # the backward model score separates the engines: the dense baseline
    # carries the 3 n^2 buffers the fused path does not
    fused_s = at.model_score(64, 64, {**entry, "mode": "fused"},
                             kind="ata_bwd")
    dense_s = at.model_score(64, 64, {**entry, "mode": "reference"},
                             kind="ata_bwd")
    assert fused_s != dense_s
    # forward and backward entries live side by side
    at.autotune(64, 64, kind="ata", blocks=(16,), levels=(0,),
                measure=False)
    assert at.lookup(64, 64, kind="ata_bwd") is not None
    assert at.lookup(64, 64, kind="ata") is not None
    assert at.lookup(64, 64, kind="ata_bwd") != at.lookup(64, 64, kind="ata")


def test_autotune_bwd_measured(tmp_cache):
    """measure=True times jax.grad through the fused forward with the
    candidate's VJP engine."""
    entry = at.autotune(32, 32, kind="ata_bwd", blocks=(16,), levels=(0, 1),
                        measure=True, top_k=1, interpret=True)
    assert entry["source"] == "measured"
    assert entry["measured_s"] > 0


# ---------------------------------------------------------------------------
# v2 cache-key migration: winners are pinned to the (jax, backend) pair
# they were tuned under — stale entries from another toolchain must not
# silently apply.
# ---------------------------------------------------------------------------

def test_cache_key_pins_jax_version_and_backend(tmp_cache):
    entry = at.autotune(40, 40, blocks=(16,), levels=(0,), measure=False)
    (key,) = at.load_cache()
    backend, jaxseg, dtype, kind, shape = key.split("/")
    assert backend == jax.default_backend()
    assert jaxseg == f"jax-{jax.__version__}"
    assert (dtype, kind, shape) == ("float32", "ata", "64x64")
    assert entry["jax"] == jax.__version__
    assert entry["backend"] == jax.default_backend()


def test_v1_cache_is_ignored_wholesale(tmp_cache):
    """Migration: a pre-v2 file (keys without the jax segment) is a set
    of potentially-stale winners — load_cache drops it entirely and a
    fresh autotune repopulates under the new key format."""
    stale_key = f"{jax.default_backend()}/float32/ata/64x64"
    tmp_cache.write_text(json.dumps({
        "version": 1,
        "entries": {stale_key: {"mode": "fused", "levels": 2,
                                "variant": "strassen", "bm": 512,
                                "bk": 512, "bn": 512,
                                "source": "measured",
                                "measured_s": 1e-9}}}))
    assert at.load_cache() == {}
    assert at.lookup(40, 40) is None       # the stale winner never applies
    entry = at.autotune(40, 40, blocks=(16,), levels=(0,), measure=False)
    assert entry["bk"] == 16               # freshly tuned, not the stale 512
    raw = json.loads(tmp_cache.read_text())
    assert raw["version"] == 2
    assert all("/jax-" in k for k in raw["entries"])


def test_other_jax_version_entry_never_matches(tmp_cache):
    """A v2 file written under a different jax: the key segment differs,
    so lookup misses (no silent stale winner) while same-version entries
    still hit."""
    other_key = (f"{jax.default_backend()}/jax-0.0.0-other/float32/ata/"
                 "64x64")
    tmp_cache.write_text(json.dumps({
        "version": 2,
        "entries": {other_key: {"mode": "fused", "levels": 2,
                                "variant": "strassen", "bm": 512,
                                "bk": 512, "bn": 512}}}))
    assert at.lookup(40, 40) is None
    at.autotune(40, 40, blocks=(16,), levels=(0,), measure=False)
    assert at.lookup(40, 40)["bk"] == 16


# ---------------------------------------------------------------------------
# New IR kinds: aat (row gram) and rank_k (accumulating update) tune
# through the same machinery and the same IR-driven traffic core.
# ---------------------------------------------------------------------------

def test_autotune_aat_kind(tmp_cache):
    entry = at.autotune(64, 32, kind="aat", blocks=(16, 32), levels=(0, 1),
                        measure=False)
    assert entry["mode"] == "fused"
    assert at.lookup(64, 32, kind="aat") == entry
    assert at.lookup(64, 32, kind="ata") is None   # kinds are separate
    # ops-level defaults consult the aat winner
    resolved = ops._resolve_blocks("aat", 64, 32, jnp.float32,
                                   bm=None, bk=None)
    assert resolved == {"bm": entry["bm"], "bk": entry["bk"]}


def test_autotune_rank_k_kind_scores_vs_streamed_baseline(tmp_cache):
    """rank_k fused candidates are scored against the status-quo
    streamed-update baseline (delta stack + gather-add): the fused score
    must beat the baseline at the same config — that traffic saving is
    the point of the accumulating kernel."""
    entry = at.autotune(128, 64, kind="rank_k", blocks=(16, 32),
                        levels=(0, 1), measure=False)
    assert entry["mode"] == "fused"
    fused_s = at.model_score(128, 64, entry, kind="rank_k")
    base_s = at.model_score(128, 64, {**entry, "mode": "reference"},
                            kind="rank_k")
    assert fused_s < base_s


def test_autotune_rank_k_measured(tmp_cache):
    entry = at.autotune(32, 32, kind="rank_k", blocks=(16,), levels=(0,),
                        measure=True, top_k=1, interpret=True)
    assert entry["source"] == "measured"
    assert entry["measured_s"] > 0


# ---------------------------------------------------------------------------
# Cache lifecycle counters (obs.metrics): hit / miss / persist /
# invalidate / stale_dropped are observable through the registry
# ---------------------------------------------------------------------------

def test_cache_event_counters_track_lifecycle(tmp_cache):
    from repro.obs import metrics as obs_metrics
    obs_metrics.reset()
    try:
        c = obs_metrics.counter("gram_autotune_cache_total")
        assert at.lookup(40, 40) is None
        assert c.value(outcome="miss") == 1
        at.autotune(40, 40, blocks=(16,), levels=(0,), measure=False)
        assert c.value(outcome="persist") == 1
        assert at.lookup(40, 40) is not None
        assert c.value(outcome="hit") == 1
        assert at.invalidate(40, 40)
        assert c.value(outcome="invalidate") == 1
        assert not at.invalidate(40, 40)     # nothing left to drop
        assert c.value(outcome="invalidate") == 1
        # a pre-v2 file is dropped wholesale, counted per stale entry
        tmp_cache.write_text(json.dumps(
            {"version": 1, "entries": {"k1": {}, "k2": {}}}))
        assert at.load_cache() == {}
        assert c.value(outcome="stale_dropped") == 2
    finally:
        obs_metrics.reset()


def test_cache_counters_survive_registry_reset(tmp_cache):
    """The counter handle is resolved per event from the live registry:
    a metrics.reset() between events must not orphan the instrument."""
    from repro.obs import metrics as obs_metrics
    obs_metrics.reset()
    at.lookup(40, 40)
    obs_metrics.reset()                      # drop every instrument
    at.lookup(40, 40)                        # must land in the NEW registry
    c = obs_metrics.counter("gram_autotune_cache_total")
    assert c.value(outcome="miss") == 1
    obs_metrics.reset()
