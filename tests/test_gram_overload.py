"""Overload + fault chaos combined (DESIGN.md §15): the degradation
ladder and the shedder running at the same time.

The CI chaos job re-runs this file with an ``REPRO_FAULTS`` exec_delay
overload profile armed in the environment; the assertions here hold
with or without it — every admitted request must reach a terminal
status and the queue must drain, whatever mix of stalls, crashes and
poisoned outputs is in effect.
"""
import threading
import time

import numpy as np
import pytest

from repro.gram import GramEngine, Overloaded
from repro.runtime import faults
from repro.runtime.faults import FaultSpec

TERMINAL = {"ok", "failed", "shed", "cancelled"}


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


def _flood(eng, rng, n, **kw):
    return [eng.submit(rng.standard_normal((20, 10)).astype(np.float32),
                       **kw) for _ in range(n)]


def test_overload_profile_queue_drains_every_request_terminal():
    """exec_delay overload (every batch stalls) + a bounded queue: the
    ladder keeps serving, admission keeps shedding, and at the end the
    queue is empty with every request in a terminal state."""
    rng = np.random.default_rng(0)
    eng = GramEngine(slots=2, levels=0, min_bucket=16,
                     max_queue=8, backoff_s=0.0).start()
    try:
        with faults.inject(FaultSpec("exec_delay", delay=0.02,
                                     site="gram.engine.exec.*")):
            futs = _flood(eng, rng, 40, deadline_s=30.0)
            assert eng.drain(timeout=60), "queue did not drain"
    finally:
        eng.shutdown()
    assert all(f.done() for f in futs)
    statuses = [f.request.status for f in futs]
    assert set(statuses) <= TERMINAL
    s = eng.stats()
    assert s["queue_depth"] == 0 and s["inflight"] == 0
    assert s["queue_peak"] <= 8
    assert s["served"] + s["failed"] + s["shed"] + s["cancelled"] == 40
    assert s["served"] > 0, "overload served nothing at all"
    # sheds failed FAST (admission time), not after queueing
    for f in futs:
        if f.request.status == "shed":
            with pytest.raises(Overloaded):
                f.result()


def test_overload_plus_crash_and_poison_chaos_still_terminates():
    """The full drill: stalls + crashes + NaN poison while submitters
    race the scheduler.  Nothing may hang; the ladder absorbs faults
    for admitted requests, the shedder bounds the queue."""
    rng = np.random.default_rng(1)
    eng = GramEngine(slots=2, levels=0, min_bucket=16, verify="finite",
                     max_retries=4, max_queue=12,
                     tenant_quota=8).start()
    futs, lock = [], threading.Lock()

    def submitter(tenant, n):
        local_rng = np.random.default_rng(hash(tenant) % 2**32)
        for _ in range(n):
            f = eng.submit(
                local_rng.standard_normal((20, 10)).astype(np.float32),
                tenant=tenant, deadline_s=30.0)
            with lock:
                futs.append(f)
            time.sleep(0.001)

    try:
        with faults.inject(
                FaultSpec("exec_delay", rate=0.5, delay=0.01,
                          site="gram.engine.exec.*"),
                FaultSpec("exec_fail", rate=0.1,
                          site="gram.engine.exec*"),
                FaultSpec("poison_output", rate=0.05),
                seed=3):
            threads = [threading.Thread(target=submitter,
                                        args=(f"t{i}", 15))
                       for i in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert eng.drain(timeout=120), "queue did not drain"
    finally:
        eng.shutdown()
    assert len(futs) == 45
    assert all(f.done() for f in futs), "a future never became terminal"
    assert {f.request.status for f in futs} <= TERMINAL
    s = eng.stats()
    assert s["queue_depth"] == 0 and s["inflight"] == 0
    assert s["served"] > 0
    # per-tenant accounting adds up
    for name, ts in s["tenants"].items():
        assert ts["served"] + ts["failed"] + ts["shed"] \
            + ts["cancelled"] == ts["submitted"], (name, ts)


def test_env_profile_composes_with_overload_assertions():
    """Sanity for the CI chaos job: whatever ``REPRO_FAULTS`` is armed
    in the environment composes with a bounded engine — drain + all
    terminal (this is what the chaos job's overload profile step
    exercises under `exec_delay:site=gram.engine.exec.*`)."""
    rng = np.random.default_rng(2)
    eng = GramEngine(slots=2, levels=0, min_bucket=16, max_queue=16,
                     max_retries=4).start()
    try:
        futs = _flood(eng, rng, 24)
        assert eng.drain(timeout=120)
    finally:
        eng.shutdown()
    assert all(f.done() for f in futs)
    assert {f.request.status for f in futs} <= TERMINAL
    assert eng.stats()["queue_depth"] == 0
