"""Data pipeline determinism/resumability + checkpoint manager semantics."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticStream, get_batch
from repro.checkpoint.manager import (CheckpointManager, save_pytree,
                                      load_pytree)


def _dc(**kw):
    base = dict(vocab_size=64, seq_len=16, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_batches_deterministic_and_distinct():
    dc = _dc()
    a = get_batch(dc, 3)
    b = get_batch(dc, 3)
    c = get_batch(dc, 4)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    assert not np.array_equal(a["inputs"], c["inputs"])
    assert a["inputs"].shape == (4, 16)
    assert a["inputs"].min() >= 0 and a["inputs"].max() < 64


def test_markov_structure_learnable():
    """labels must be mostly the affine successor of inputs (low noise)."""
    dc = _dc(noise=0.0, vocab_size=97)
    b = get_batch(dc, 0)
    # consecutive positions follow x_{t+1} = (a x_t + c) % V per sequence:
    # check labels == inputs shifted by one (construction invariant)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_stream_resume_exact():
    dc = _dc()
    s1 = SyntheticStream(dc)
    batches = [next(s1) for _ in range(5)]
    s2 = SyntheticStream(dc).restore(3)
    np.testing.assert_array_equal(next(s2)["inputs"], batches[3]["inputs"])


def test_enc_inputs_emitted():
    dc = _dc(enc_seq=10, enc_dim=8)
    b = get_batch(dc, 0)
    assert b["enc_inputs"].shape == (4, 10, 8)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(x=1.0):
    return {
        "step": np.int32(5),
        "params": {"w": np.full((4, 4), x, np.float32),
                   "b16": jnp.full((3,), x, jnp.bfloat16),
                   "blocks": [{"k": np.arange(6).reshape(2, 3)},
                              {"k": np.arange(6).reshape(2, 3) + 1}]},
    }


def test_pytree_roundtrip(tmp_path):
    f = str(tmp_path / "s.npz")
    save_pytree(_state(2.5), f)
    got = load_pytree(f)
    assert got["params"]["b16"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got["params"]["b16"], np.float32),
                               2.5)
    np.testing.assert_array_equal(got["params"]["blocks"][1]["k"],
                                  np.arange(6).reshape(2, 3) + 1)
    assert int(got["step"]) == 5


def test_manager_save_restore_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _state(float(step)))
    assert mgr.all_steps() == [20, 30]           # keep-K gc
    state, meta = mgr.restore()
    assert meta["step"] == 30
    np.testing.assert_allclose(state["params"]["w"], 30.0)
    state, meta = mgr.restore(20)
    np.testing.assert_allclose(state["params"]["w"], 20.0)


def test_manager_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _state(1.0))
    mgr.wait()
    # no .tmp dirs left behind (atomic rename committed)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    state, meta = mgr.restore()
    assert meta["step"] == 1


def test_manager_restore_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state, meta = mgr.restore()
    assert state is None and meta is None
