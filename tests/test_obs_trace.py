"""obs.trace: span nesting/ids, ring bound, Chrome-trace export, the
<2% disabled-path overhead bound, and the chaos acceptance trace
(DESIGN.md §14)."""
import json
import threading
import time

import numpy as np
import pytest

from repro.gram import GramEngine
from repro.obs import trace
from repro.obs.trace import Tracer
from repro.runtime import faults
from repro.runtime.faults import FaultSpec


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


def _mixed_trace(rng, requests, lo=5, hi=60):
    shapes = [(int(rng.integers(lo, hi)), int(rng.integers(lo, hi // 2 + 2)))
              for _ in range(requests)]
    return [rng.standard_normal(s).astype(np.float32) for s in shapes]


# ---------------------------------------------------------------------------
# Span mechanics
# ---------------------------------------------------------------------------

def test_span_nesting_parent_ids_and_trace_id_inheritance():
    t = Tracer(enabled=True)
    with t.span("outer", trace_id=7) as outer:
        with t.span("inner") as inner:
            t.instant("tick", note="x")
        with t.span("sibling", trace_id=9) as sib:
            pass
    evs = {e.name: e for e in t.events()}
    assert set(evs) == {"outer", "inner", "sibling", "tick"}
    # children close before the parent: completion order inner < outer
    names = [e.name for e in t.events()]
    assert names.index("inner") < names.index("outer")
    assert evs["inner"].parent_id == outer.span_id
    assert evs["sibling"].parent_id == outer.span_id
    assert evs["outer"].parent_id is None
    # trace_id flows down unless overridden; instants inherit too
    assert evs["inner"].trace_id == 7
    assert evs["sibling"].trace_id == 9
    assert evs["tick"].trace_id == 7
    assert evs["tick"].parent_id == inner.span_id
    # ids unique
    ids = [e.span_id for e in t.events()]
    assert len(set(ids)) == len(ids)


def test_span_annotate_and_exception_capture():
    t = Tracer(enabled=True)
    with pytest.raises(ValueError):
        with t.span("work") as s:
            s.annotate(bucket="64x64")
            raise ValueError("boom")
    (ev,) = t.events()
    assert ev.attrs["bucket"] == "64x64"
    assert ev.attrs["error"].startswith("ValueError")
    assert ev.duration_s >= 0


def test_retroactive_add_span_carries_explicit_endpoints():
    t = Tracer(enabled=True)
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    t.add_span("queue_wait", t0, t1, trace_id=3, bucket="32x32")
    (ev,) = t.events()
    assert ev.ph == "X" and ev.t0 == t0 and ev.t1 == t1
    assert ev.trace_id == 3
    # reversed endpoints clamp to zero duration, never negative
    t.add_span("oops", t1, t0)
    assert t.events()[-1].duration_s == 0.0


def test_ring_buffer_bounds_and_counts_dropped():
    t = Tracer(enabled=True, capacity=8)
    for i in range(20):
        t.instant(f"e{i}")
    assert len(t) == 8
    assert t.dropped == 12
    # the ring keeps the *recent* past
    assert [e.name for e in t.events()] == [f"e{i}" for i in range(12, 20)]
    t.clear()
    assert len(t) == 0 and t.dropped == 0


def test_disabled_tracer_records_nothing_and_shares_null_span():
    trace.set_tracer(None)              # fresh disabled tracer
    s1 = trace.span("a", trace_id=1, big="attr")
    s2 = trace.span("b")
    assert s1 is s2                     # no allocation on the disabled path
    with s1 as s:
        assert s.annotate(x=1) is s
    trace.instant("i")
    trace.add_span("r", 0.0, 1.0)
    assert len(trace.get_tracer().events()) == 0
    assert not trace.tracing_enabled()


def test_threads_get_independent_span_stacks():
    t = Tracer(enabled=True)
    errs = []

    def worker(wid):
        try:
            with t.span("w", trace_id=wid) as s:
                time.sleep(0.002)
                t.instant("inside")
                assert t._stack()[-1] is s
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    with t.span("main"):
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errs
    spans = [e for e in t.events() if e.name == "w"]
    assert len(spans) == 8
    # worker spans parented in their own thread, not under "main"
    assert all(e.parent_id is None for e in spans)
    insts = [e for e in t.events() if e.name == "inside"]
    assert sorted(e.trace_id for e in insts) == list(range(8))


# ---------------------------------------------------------------------------
# Export formats
# ---------------------------------------------------------------------------

def _chrome_roundtrip(t):
    return json.loads(json.dumps(t.chrome_trace()))


def test_chrome_trace_roundtrips_and_ts_monotonic_per_thread():
    t = Tracer(enabled=True)
    with t.span("outer", trace_id=1):
        with t.span("inner"):
            t.instant("fault:exec_fail", site="gram.engine.exec")
    doc = _chrome_roundtrip(t)
    evs = doc["traceEvents"]
    assert len(evs) == 3
    for rec in evs:
        assert rec["pid"] == 1 and isinstance(rec["tid"], int)
        assert rec["ph"] in ("X", "i")
        if rec["ph"] == "X":
            assert rec["dur"] > 0
        else:
            assert rec["s"] == "t"
    # sorted by ts; per-tid monotonic (single thread here, the chaos test
    # re-checks across threads)
    ts = [rec["ts"] for rec in evs]
    assert ts == sorted(ts)
    # the outer span sorts FIRST despite completing last (export is
    # start-ordered, not completion-ordered)
    assert evs[0]["name"] == "outer"
    assert evs[0]["args"]["trace_id"] == 1
    assert doc["otherData"]["dropped_events"] == 0


def test_jsonl_export_one_valid_object_per_event():
    t = Tracer(enabled=True)
    with t.span("a", trace_id=5, arr=np.float32(2.0)):
        t.instant("b")
    lines = [ln for ln in t.to_jsonl().splitlines() if ln]
    assert len(lines) == 2
    objs = [json.loads(ln) for ln in lines]
    assert objs[0]["name"] == "a" and objs[1]["name"] == "b"
    assert objs[1]["parent_id"] == objs[0]["span_id"]
    # non-JSON attrs stringified, never a serialization error
    assert isinstance(objs[0]["attrs"]["arr"], str)


# ---------------------------------------------------------------------------
# Acceptance: disabled fast path <2% on a 64-request mixed trace
# ---------------------------------------------------------------------------

def test_disabled_overhead_under_2pct_on_64_request_trace():
    """The derived bound: (events per request when tracing) x (measured
    per-disabled-hook cost) over the per-request wall.  The disabled
    path IS the production baseline, so the overhead it adds cannot be
    A/B-measured directly — it is priced from its unit cost."""
    rng = np.random.default_rng(11)
    arrays = _mixed_trace(rng, 64)

    # pass 1 (tracing on): count events a request generates
    tracer = trace.set_tracer(Tracer(enabled=True))
    eng = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16)
    for a in arrays:
        eng.submit(a)
    finished = eng.run_to_completion()
    assert len(finished) == 64
    n_events = len(tracer.events()) + tracer.dropped
    events_per_req = n_events / 64
    assert events_per_req >= 4          # chain is actually instrumented

    # pass 2 (tracing off): the production wall the bound is relative to
    trace.set_tracer(None)
    eng2 = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16)
    for a in arrays:
        eng2.submit(a)
    t0 = time.perf_counter()
    assert len(eng2.run_to_completion()) == 64
    wall = time.perf_counter() - t0

    hook_s = trace.disabled_hook_cost()
    overhead = (events_per_req * hook_s) / (wall / 64)
    assert overhead < 0.02, (
        f"disabled tracer hooks cost {overhead:.2%} of the per-request "
        f"wall ({events_per_req:.1f} events/req x {hook_s * 1e9:.0f}ns "
        f"over {wall / 64 * 1e3:.2f}ms)")


# ---------------------------------------------------------------------------
# Acceptance: the chaos trace — complete request chains + fault firings
# + rung transitions on ONE timeline
# ---------------------------------------------------------------------------

def test_chaos_trace_has_complete_chains_faults_and_rung_transitions():
    rng = np.random.default_rng(1)
    arrays = _mixed_trace(rng, 24)
    tracer = trace.set_tracer(Tracer(enabled=True))
    eng = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16,
                     verify=2, max_retries=6, breaker_threshold=2,
                     verify_seed=5)
    uids = [eng.submit(a).uid for a in arrays]
    specs = [
        FaultSpec("poison_output", rate=0.10),
        FaultSpec("poison_output", rate=0.10, value=2.5),
        FaultSpec("exec_fail", rate=0.10, site="gram.engine.exec*"),
    ]
    with faults.inject(*specs, seed=7) as reg:
        finished = eng.run_to_completion()
    assert len(finished) == 24
    assert len(reg.events) > 0, "chaos trace injected nothing"

    # deterministic breaker trip on the same timeline: a 2-failure
    # budget meets breaker_threshold=2 exactly, so the bucket escalates
    # to rung 1 and the request still completes there
    a = rng.standard_normal((40, 20)).astype(np.float32)
    uids.append(eng.submit(a).uid)
    with faults.inject(FaultSpec("exec_fail", times=2,
                                 site="gram.engine.exec*")):
        (r2,) = eng.step()
    assert r2.status == "ok"

    evs = tracer.events()
    by_name = {}
    for e in evs:
        by_name.setdefault(e.name, []).append(e)

    # every request has the full submit -> queue_wait -> execute ->
    # verify -> done chain plus the retroactive request span, all
    # correlated by trace_id == uid
    for name in ("submit", "queue_wait", "execute", "verify", "done",
                 "request"):
        have = {e.trace_id for e in by_name.get(name, [])}
        assert set(uids) <= have, (name, sorted(set(uids) - have))

    # injected faults and the ladder's reaction are instants on the SAME
    # timeline (same tracer buffer, same clock)
    fault_names = [n for n in by_name if n.startswith("fault:")]
    assert fault_names, "no fault instants recorded"
    assert "rung_transition" in by_name, "breaker never escalated a rung"
    assert "retry" in by_name
    rung_ev = by_name["rung_transition"][0]
    t_lo = min(e.t0 for e in evs)
    t_hi = max(e.t1 for e in evs)
    assert t_lo <= rung_ev.t0 <= t_hi
    for n in fault_names:
        assert all(t_lo <= e.t0 <= t_hi for e in by_name[n])

    # and the export round-trips with per-thread monotonic timestamps
    doc = _chrome_roundtrip(tracer)
    last_by_tid = {}
    for rec in doc["traceEvents"]:
        prev = last_by_tid.get(rec["tid"], -float("inf"))
        assert rec["ts"] >= prev, "ts went backwards within a thread"
        last_by_tid[rec["tid"]] = rec["ts"]
    names = {rec["name"] for rec in doc["traceEvents"]}
    assert "rung_transition" in names
    assert any(n.startswith("fault:") for n in names)
