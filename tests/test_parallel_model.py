"""Distributed model semantics == single-device reference, on the
conftest ``@pytest.mark.multidevice`` harness (8 forced-host devices in a
child pytest; the main process keeps 1 device).

1. MoE train forward under EP shard_map (experts sharded over 'model')
   == single-device reference.
2. MoE decode under the STATIONARY expert layout == reference decode.
3. compressed_psum (int8 error-feedback) over a 2-group axis ~= exact mean.
"""
import dataclasses

import numpy as np
import pytest


@pytest.mark.multidevice(8)
def test_parallel_model_matches_reference(multidevice_count):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.registry import reduced_arch
    from repro.models import init_params, forward, init_cache, decode_step
    from repro.parallel.act import (ActivationSharding,
                                    use_activation_sharding)
    from repro.parallel.sharding import param_specs, cache_specs, to_named
    from repro.launch.mesh import make_mesh

    assert len(jax.devices()) >= multidevice_count
    cfg = reduced_arch("arctic-480b", num_layers=2)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s = 4, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    # single-device reference (no policy installed)
    ref_logits = np.asarray(jax.jit(
        lambda p, t: forward(cfg, p, t, mode="train")[0])(params, toks),
        np.float32)

    mesh = make_mesh((2, 4), ("data", "model"))
    pshard = to_named(param_specs(params, mesh), mesh)
    params_sh = jax.device_put(params, pshard)
    toks_sh = jax.device_put(toks, NamedSharding(mesh, P(("data",), None)))

    # 1) EP train forward
    policy = ActivationSharding.for_training(mesh, sp=True)
    with use_activation_sharding(policy):
        got = jax.jit(lambda p, t: forward(cfg, p, t, mode="train")[0])(
            params_sh, toks_sh)
    got = np.asarray(jax.device_get(got), np.float32)
    err = np.abs(got - ref_logits).max() / (np.abs(ref_logits).max() + 1e-9)
    assert err < 3e-2, f"EP train forward mismatch: {err}"

    # 2) stationary-expert decode
    cache = init_cache(cfg, b, s)
    last_ref, _cache_ref = jax.jit(
        lambda p, t, c: decode_step(cfg, p, t, c))(params, toks[:, :1],
                                                   cache)
    pshard_dec = to_named(param_specs(params, mesh, moe_stationary=True),
                          mesh)
    params_dec = jax.device_put(params, pshard_dec)
    cshard = to_named(cache_specs(cache, mesh), mesh)
    cache_sh = jax.device_put(cache, cshard)
    dec_policy = ActivationSharding.for_decode(mesh)
    with use_activation_sharding(dec_policy):
        last, _ = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))(
            params_dec, jax.device_put(
                toks[:, :1], NamedSharding(mesh, P(("data",), None))),
            cache_sh)
    a = np.asarray(jax.device_get(last), np.float32)
    r = np.asarray(jax.device_get(last_ref), np.float32)
    err = np.abs(a - r).max() / (np.abs(r).max() + 1e-9)
    # bf16 compute: observed up to ~3.2e-2 across jax/XLA:CPU versions
    assert err < 4e-2, f"stationary decode mismatch: {err}"

    # 3) compressed psum over a 2-group axis
    from repro.core.distributed import shard_map_compat
    shard_map, unchecked = shard_map_compat()
    from repro.optim.grad_compress import compressed_psum, ErrorFeedback
    g = jax.random.normal(key, (2, 64), jnp.float32)  # row per "pod"

    def body(gl):
        grads = {"w": gl[0]}
        ef = ErrorFeedback.init(grads)
        red, ef = compressed_psum(grads, "data", ef)
        return red["w"][None], ef.residual["w"][None]

    red, _resid = shard_map(
        body, mesh=mesh, in_specs=P(("data",), None),
        out_specs=(P(("data",), None), P(("data",), None)),
        **unchecked)(g)
    exact = np.asarray(g, np.float32).mean(0)
    got = np.asarray(jax.device_get(red), np.float32)[0]
    # int8 quantization error bound: scale/2 per participant
    tol = float(np.abs(np.asarray(g)).max()) / 127.0 + 1e-6
    assert np.abs(got - exact).max() <= tol, (np.abs(got - exact).max(), tol)
