"""Distributed model semantics == single-device reference (8-device
subprocess; the main pytest process keeps 1 device)."""
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def test_parallel_model_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(HERE / "_parallel_model_check.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "ALL_OK" in out.stdout
