"""Sharding-rule unit tests (no multi-device needed: rules are pure)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_arch, reduced_arch
from repro.models import init_params, init_cache
from repro.parallel.sharding import param_specs, cache_specs, _axis_size
from repro.parallel.act import _fit_spec


class FakeMesh:
    """Shape-only stand-in (rules never touch devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _abstract_params(arch, reduced=False):
    cfg = reduced_arch(arch) if reduced else get_arch(arch)
    return cfg, jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v3-671b", "arctic-480b",
                                  "mamba2-2.7b", "whisper-small"])
def test_all_big_2d_weights_sharded(arch):
    """>=99% of param bytes sharded at least 16-way; known divisibility
    fallbacks (whisper's odd 51865 vocab can never shard; mamba2's packed
    in_proj dim 10576 % 16 != 0 only shards on d) cap the fully-256-way
    fraction below 100% for those archs — asserted with per-arch bounds."""
    cfg, params = _abstract_params(arch)
    specs = param_specs(params, MESH)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    total = under256 = under16 = 0
    for p, s in zip(flat_p, flat_s):
        b = p.size * p.dtype.itemsize
        nsh = 1
        for part in s:
            if part is not None:
                nsh *= _axis_size(MESH, part)
        total += b
        if nsh < 256:
            under256 += b
        if nsh < 16:
            under16 += b
    limit256 = {"whisper-small": 0.30, "mamba2-2.7b": 0.10}.get(arch, 0.01)
    assert under256 / total < limit256, f"{under256/total:.2%} <256-way"
    limit16 = {"whisper-small": 0.17}.get(arch, 0.01)  # odd vocab embed
    assert under16 / total < limit16, f"{under16/total:.2%} <16-way"


def test_moe_expert_sharding():
    cfg, params = _abstract_params("deepseek-v3-671b")
    specs = param_specs(params, MESH)
    wg = specs["mla_moe"]["moe"]["w_gate"]
    assert wg == P(None, "model", "data", None)    # (L, E, d, f)
    wd = specs["mla_moe"]["moe"]["w_down"]
    assert wd == P(None, "model", None, "data")


def test_multipod_fsdp_axes():
    cfg, params = _abstract_params("yi-9b")
    specs = param_specs(params, MESH3, fsdp_axes=("pod", "data"))
    wq = specs["blocks"]["attn"]["wq"]
    assert wq == P(None, ("pod", "data"), "model")


def test_indivisible_falls_back_replicated():
    cfg, params = _abstract_params("qwen2.5-3b", reduced=True)
    specs = param_specs(params, MESH)
    # tiny dims (256) still divide 16 -> sharded; but a 6-dim would not.
    from repro.parallel.sharding import _check
    assert _check(["data", None], (10, 4), MESH) == P(None, None)
    assert _check(["data", "model"], (32, 6), MESH) == P("data", None)


def test_cache_specs_decode():
    cfg = get_arch("command-r-plus-104b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = cache_specs(cache, MESH)
    # (L, B, S, Hkv=8, D): B->dp(16), S->model (8 kv heads !% 16)
    assert specs["blocks"]["k"] == P(None, ("data",), "model", None, None) \
        or specs["blocks"]["k"] == P(None, ("data",), None, "model", None)


def test_cache_specs_batch1_long_context():
    cfg = get_arch("zamba2-2.7b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    specs = cache_specs(cache, MESH)
    kspec = specs["shared_attn"]["k"]
    # B=1 cannot shard -> seq takes the dp axes; heads (32) -> model
    assert kspec[2] in ("data", ("data",))
    assert kspec[3] == "model"


def test_fit_spec_divisibility():
    assert _fit_spec(P(("data",), "model"), (32, 51865), MESH) \
        == P(("data",), None)
    assert _fit_spec(P(("data",), None), (1, 1), MESH) == P(None, None)
