"""System-level checks: dry-run artifacts well-formed, HLO cost analyzer
trip-count correctness (multi-device subprocess), end-to-end mini train via
the launch CLI."""
import json
import glob
import os
import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent
ART = REPO / "artifacts" / "dryrun"


def test_dryrun_artifacts_wellformed():
    files = glob.glob(str(ART / "*.json"))
    if not files:
        pytest.skip("no dry-run artifacts yet (run repro.launch.dryrun)")
    for f in files:
        with open(f) as fh:
            a = json.load(fh)
        if a.get("status") != "ok":
            continue
        assert a["memory"]["temp_size_in_bytes"] >= 0
        if a.get("kind") != "gram":
            assert a["cost_corrected"]["flops"] > 0, a["cell"]
            assert a["cost_corrected"]["unknown_trip_loops"] == 0, a["cell"]
        assert "wire_bytes_total" in (a.get("collectives_corrected")
                                      or a["collectives"])


def test_dryrun_covers_assigned_grid():
    """32 runnable cells (40-cell grid minus 8 mandated long_500k skips)
    x both meshes must be present and ok once the sweep has run."""
    files = glob.glob(str(ART / "*__pod2x16x16.json"))
    if len(files) < 10:
        pytest.skip("multi-pod sweep incomplete")
    from repro.configs.registry import all_cells
    for arch, shape in all_cells():
        for mesh in ("pod16x16", "pod2x16x16"):
            p = ART / f"{arch}__{shape}__{mesh}.json"
            assert p.exists(), f"missing dry-run cell {p.name}"
            with open(p) as fh:
                assert json.load(fh)["status"] == "ok", p.name


def test_hlo_cost_trip_count_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(HERE / "_hlo_cost_check.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout


def test_train_cli_end_to_end(tmp_path):
    from repro.launch.train import main
    hist = main(["--arch", "qwen2.5-3b", "--reduced", "--steps", "4",
                 "--batch", "2", "--seq", "16",
                 "--workdir", str(tmp_path)])
    assert len(hist) == 4


def test_serve_cli_end_to_end():
    from repro.launch.serve import main
    finished = main(["--arch", "qwen2.5-3b", "--requests", "2",
                     "--slots", "2", "--max-seq", "64", "--max-new", "4"])
    assert len(finished) == 2
