"""System-level checks: dry-run artifacts well-formed, HLO cost analyzer
trip-count correctness (conftest multidevice harness), end-to-end mini
train via the launch CLI."""
import json
import glob
import pathlib

import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent
ART = REPO / "artifacts" / "dryrun"


def test_dryrun_artifacts_wellformed():
    files = glob.glob(str(ART / "*.json"))
    if not files:
        pytest.skip("no dry-run artifacts yet (run repro.launch.dryrun)")
    for f in files:
        with open(f) as fh:
            a = json.load(fh)
        if a.get("status") != "ok":
            continue
        assert a["memory"]["temp_size_in_bytes"] >= 0
        if a.get("kind") != "gram":
            assert a["cost_corrected"]["flops"] > 0, a["cell"]
            assert a["cost_corrected"]["unknown_trip_loops"] == 0, a["cell"]
        assert "wire_bytes_total" in (a.get("collectives_corrected")
                                      or a["collectives"])


def test_dryrun_covers_assigned_grid():
    """32 runnable cells (40-cell grid minus 8 mandated long_500k skips)
    x both meshes must be present and ok once the sweep has run."""
    files = glob.glob(str(ART / "*__pod2x16x16.json"))
    if len(files) < 10:
        pytest.skip("multi-pod sweep incomplete")
    from repro.configs.registry import all_cells
    for arch, shape in all_cells():
        for mesh in ("pod16x16", "pod2x16x16"):
            p = ART / f"{arch}__{shape}__{mesh}.json"
            assert p.exists(), f"missing dry-run cell {p.name}"
            with open(p) as fh:
                assert json.load(fh)["status"] == "ok", p.name


@pytest.mark.multidevice(8)
def test_hlo_cost_trip_count(multidevice_count):
    """The trip-count-aware HLO analyzer against a known scan program on
    an 8-device host platform (conftest multidevice harness)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.roofline.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_mesh

    L, B, D = 48, 64, 128

    def f(xs, w):
        def body(c, _):
            c = jnp.tanh(c @ w)
            return c, ()
        c, _ = jax.lax.scan(body, xs, None, length=L)
        return jnp.sum(c)

    mesh = make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    c = jax.jit(f, in_shardings=(sh, None),
                out_shardings=NamedSharding(mesh, P())).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())

    dot_flops = L * 2 * (B // 8) * D * D           # per-device
    assert 0.95 * dot_flops < r["flops"] < 1.3 * dot_flops, (
        r["flops"], dot_flops)
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):   # jax 0.4.x returns [dict]
        xla_cost = xla_cost[0]
    assert xla_cost["flops"] < dot_flops / 10, "xla undercounts (expected)"
    # bytes: per iteration ~ w (D*D*4) + 3x carry; x L
    per_iter = D * D * 4 + 3 * (B // 8) * D * 4
    assert r["bytes"] > 0.8 * L * per_iter * 0.5, (r["bytes"],
                                                   L * per_iter)
    assert r["unknown_trip_loops"] == 0
    # collective: the final psum of a scalar
    assert r["collectives"]["by_kind"].get("all-reduce", {}).get("count",
                                                                 0) >= 1


def test_train_cli_end_to_end(tmp_path):
    from repro.launch.train import main
    hist = main(["--arch", "qwen2.5-3b", "--reduced", "--steps", "4",
                 "--batch", "2", "--seq", "16",
                 "--workdir", str(tmp_path)])
    assert len(hist) == 4


def test_serve_cli_end_to_end():
    from repro.launch.serve import main
    finished = main(["--arch", "qwen2.5-3b", "--requests", "2",
                     "--slots", "2", "--max-seq", "64", "--max-new", "4"])
    assert len(finished) == 2
