"""Child script for the sharded streaming-Gram test.  The parent test runs
it in a subprocess so the main pytest process keeps the default 1-device
CPU platform (XLA_FLAGS must not be set globally)."""
import os

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.distributed import shard_map_compat  # noqa: E402
from repro.gram import sharded_init, update_sharded  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    P_DEV, m, n = 8, 128, 64
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
    want = np.asarray(a, np.float64).T @ np.asarray(a, np.float64)

    mesh = jax.make_mesh((P_DEV,), ("data",))
    shard_map, unchecked = shard_map_compat()

    def stream(chunks):
        # per-device: fold row-sharded chunks into the block-row shard of C
        c = sharded_init(n, P_DEV)
        for chunk in chunks:
            c = update_sharded(c, chunk, "data", levels=1, leaf=8)
        return c

    chunk_bounds = [(0, 48), (48, 128)]   # ragged: 48 and 80 rows
    chunks = tuple(a[lo:hi] for lo, hi in chunk_bounds)
    got = shard_map(
        stream, mesh=mesh,
        in_specs=(P("data", None),),     # pytree prefix: every chunk by rows
        out_specs=P("data", None), **unchecked,
    )(chunks)
    got = np.asarray(jax.device_get(got), np.float64)
    assert got.shape == (n, n)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, err
    print(f"OK sharded-stream rel_err={err:.2e}")
    print("ALL_OK")


if __name__ == "__main__":
    main()
