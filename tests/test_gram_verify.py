"""gram.verify: the Freivalds-style output guards for served Grams."""
import numpy as np
import pytest

from repro.core.symmetry import pack_tril
from repro.gram import verify
from repro.gram.verify import (VerificationError, check_packed_state,
                               freivalds_gram, verify_gram)


@pytest.fixture
def a():
    return np.random.default_rng(0).standard_normal((40, 24)) \
        .astype(np.float32)


def _gram(a):
    a64 = a.astype(np.float64)
    return a64.T @ a64


def test_correct_gram_passes(a):
    v = verify_gram(a, _gram(a), probes=4)
    assert v.ok and v.finite and v.diag_ok and v.freivalds_ok
    assert v.probes == 4
    assert v.reason() == "ok"


def test_tril_only_gram_passes(a):
    v = verify_gram(a, np.tril(_gram(a)), probes=4, full=False)
    assert v.ok


def test_rows_gram_identity(a):
    a64 = a.astype(np.float64)
    assert verify_gram(a, a64 @ a64.T, probes=4, gram_of="rows").ok


def test_nan_caught_and_skips_probes(a):
    c = _gram(a)
    c[3, 5] = np.nan
    v = verify_gram(a, c, probes=4)
    assert not v.ok and not v.finite
    assert v.probes == 0, "probes must not run over NaN data"
    assert "non-finite" in v.reason()


def test_negative_diagonal_caught(a):
    c = _gram(a)
    c[2, 2] = -abs(c).max()
    v = verify_gram(a, c, probes=0)
    assert not v.ok and v.finite and not v.diag_ok
    assert "diagonal" in v.reason()


def test_freivalds_catches_finite_silent_corruption(a):
    """A single corrupted entry — finite, plausible magnitude, symmetric,
    invisible to the NaN scan — is caught by the identity probe."""
    c = _gram(a)
    c[7, 3] += 0.5 * abs(c).max()
    c[3, 7] = c[7, 3]                     # keep it symmetric: hard mode
    passed, err = freivalds_gram(a, c, probes=4)
    assert not passed and err > 1e-3
    v = verify_gram(a, c, probes=4)
    assert not v.ok and "freivalds" in v.reason()


def test_freivalds_probabilistic_bound(a):
    """One Rademacher probe misses a rank-one corruption with probability
    <= 1/2; across many seeded trials the detection rate must clear it."""
    c = _gram(a)
    c[5, 9] += abs(c).max()
    c[9, 5] = c[5, 9]
    hits = sum(
        not freivalds_gram(a, c, probes=1,
                           rng=np.random.default_rng(t))[0]
        for t in range(64))
    assert hits >= 32, f"detected {hits}/64 < the 1/2 Freivalds bound"


def test_zero_matrix_passes():
    a = np.zeros((8, 6), np.float32)
    assert verify_gram(a, np.zeros((6, 6)), probes=2).ok


def test_shape_mismatch_rejected(a):
    with pytest.raises(ValueError):
        freivalds_gram(a, np.zeros((5, 5)))


def test_default_rtol_by_dtype():
    assert verify.default_rtol(np.float32) == pytest.approx(1e-4)
    assert verify.default_rtol(np.float64) == pytest.approx(1e-10)
    assert verify.default_rtol("bfloat16") == pytest.approx(5e-2)
    assert verify.default_rtol(np.float16) == pytest.approx(5e-2)


def test_check_packed_state_ok_and_corrupt(a):
    packed = np.asarray(pack_tril(_gram(a)))
    check_packed_state(packed, 24)         # clean state passes

    bad = packed.copy()
    bad[10] = np.inf
    with pytest.raises(VerificationError, match="non-finite"):
        check_packed_state(bad, 24)

    # corrupt exactly one *diagonal* packed entry (row r at r(r+3)/2)
    r = 5
    bad2 = packed.copy()
    bad2[r * (r + 3) // 2] = -1e6
    with pytest.raises(VerificationError, match="negative diagonal"):
        check_packed_state(bad2, 24)
    # the same magnitude off-diagonal is legal
    ok = packed.copy()
    ok[r * (r + 3) // 2 - 1] = -1e6
    check_packed_state(ok, 24)
