"""The leaf-program IR (core/leaf_ir.py): algebra registry, compiler
counts vs the cost-model closed forms, the numpy interpreter vs dense
oracles, and the fused executor parity of the two NEW capabilities the IR
bought — the aat (A A^t) row gram and the accumulating rank-k update —
including the PR acceptance bounds (512^2 fp32 <= 1e-5; bf16 levels 0-3).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ata
from repro.core.cost_model import (aat_mults_exact, ata_mults_exact,
                                   ir_leaf_count, ir_max_terms)
from repro.core.leaf_ir import (PROGRAM_KINDS, compile_program,
                                get_algebra, interpret_program,
                                register_algebra, registered_algebras)
from repro.gram import stream
from repro.kernels import ops
from repro.kernels.strassen_fused import (
    aat_traffic_model, fused_aat, fused_aat_packed, fused_ata_packed,
    fused_rank_k_update, rank_k_traffic_model,
)


def _rand(shape, dtype=jnp.float32, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_ships_three_algebras():
    assert set(registered_algebras()) >= {"strassen", "winograd",
                                          "classical"}
    assert len(get_algebra("strassen")) == 7
    assert len(get_algebra("classical")) == 8
    with pytest.raises(ValueError):
        get_algebra("nope")
    with pytest.raises(ValueError):
        register_algebra("strassen", get_algebra("strassen"))  # duplicate
    with pytest.raises(ValueError):
        register_algebra("bad", ((((0, 0, 2),), ((0, 0, 1),),
                                  ((0, 0, 1),)),))              # bad sign


def test_registering_a_new_algebra_compiles_and_evaluates():
    """A new variant is one register_algebra call: the 2x2 classical
    table under a fresh name compiles every kind and matches the oracle
    through the interpreter — variants are data, not code."""
    name = "classical-copy-test"
    if name not in registered_algebras():
        register_algebra(name, get_algebra("classical"))
    rng = np.random.RandomState(0)
    a = rng.randn(8, 4)
    got = interpret_program(compile_program("ata", 2, name), a)
    np.testing.assert_allclose(got, np.tril(a.T @ a), atol=1e-9)
    got = interpret_program(compile_program("aat", 1, name), a)
    np.testing.assert_allclose(got, np.tril(a @ a.T), atol=1e-9)


def test_fused_matmul_both_trans_forward_and_grads():
    """C = a^t b^t with BOTH transposes folded into the index maps, and
    its fused VJP (regression: the two-flag case routed through the
    single-flag branch and returned wrong gradients)."""
    from repro.kernels.strassen_fused import fused_matmul
    a = _rand((40, 16), seed=31)          # stored (k, m)
    b = _rand((24, 40), seed=32)          # stored (n, k)
    out = fused_matmul(a, b, levels=1, bm=8, bk=8, bn=8, trans_a=True,
                       trans_b=True, interpret=True)
    want = np.asarray(a, np.float64).T @ np.asarray(b, np.float64).T
    assert np.abs(np.asarray(out, np.float64) - want).max() < 1e-4
    da, db = jax.grad(
        lambda p, q: fused_matmul(p, q, levels=1, bm=8, bk=8, bn=8,
                                  trans_a=True, trans_b=True,
                                  interpret=True).sum(),
        argnums=(0, 1))(a, b)
    g = np.ones((16, 24))
    wa = np.asarray(b, np.float64).T @ g.T       # dA = B^t g^t, (k, m)
    wb = g.T @ np.asarray(a, np.float64).T       # dB = g^t A^t, (n, k)
    np.testing.assert_allclose(np.asarray(da), wa, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), wb, rtol=1e-4, atol=1e-4)


def test_reregistration_invalidates_executor_tables():
    """register_algebra(overwrite=True) must clear the executor's lowered
    scalar-prefetch tables, not just the program cache — a stale table
    would make the kernel silently run the OLD algebra."""
    from repro.kernels.strassen_fused import _program_tables, fused_matmul
    a = _rand((8, 8), seed=33)
    _ = fused_matmul(a, a, levels=1, bm=8, bk=8, bn=8, interpret=True)
    assert _program_tables.cache_info().currsize > 0
    register_algebra("strassen", get_algebra("strassen"), overwrite=True)
    assert _program_tables.cache_info().currsize == 0


def test_unknown_kind_and_bad_trans_rejected():
    with pytest.raises(ValueError):
        compile_program("gemm", 1)
    with pytest.raises(ValueError):
        compile_program("ata", 1, trans_a=True)
    with pytest.raises(ValueError):
        compile_program("matmul", -1)


# ---------------------------------------------------------------------------
# Counts + interpreter vs closed forms / oracles.  The exhaustive sweep
# runs unconditionally; the hypothesis property (random leaf shapes over
# the same space) adds fuzzed coverage where hypothesis is installed.
# ---------------------------------------------------------------------------

def _check_counts_and_interpreter(kind, variant, levels, mb, nb):
    """Compiled LeafProgram leaf/term counts == cost-model closed forms;
    numpy interpreter == dense oracle."""
    prog = compile_program(kind, levels, variant)
    assert len(prog.ops) == ir_leaf_count(kind, levels, variant)
    assert prog.max_terms == ir_max_terms(kind, levels, variant)
    # gram kinds: mult_count ties to the recursion closed forms too
    # (ata_mults_exact models the paper's 7-product HASA — the 8-product
    # classical table deliberately differs, as in test_fused_ata)
    B = prog.blocks
    if variant in ("strassen", "winograd"):
        if kind in ("ata", "rank_k"):
            assert prog.mult_count(mb, nb) == ata_mults_exact(
                mb * B, nb * B, leaf=0, levels=levels)
        elif kind == "aat":
            assert prog.mult_count(mb, nb) == aat_mults_exact(
                mb * B, nb * B, leaf=0, levels=levels)

    rng = np.random.RandomState(levels * 7 + mb)
    a = rng.randn(B * mb, B * nb)
    if kind in ("ata", "rank_k"):
        c0 = (np.tril(rng.randn(B * nb, B * nb))
              if kind == "rank_k" else None)
        got = interpret_program(prog, a, c0=c0)
        want = np.tril(a.T @ a) + (c0 if c0 is not None else 0.0)
    elif kind == "aat":
        got = interpret_program(prog, a)
        want = np.tril(a @ a.T)
    elif kind == "matmul":
        b = rng.randn(B * nb, B * mb)
        got = interpret_program(prog, a, b)
        want = a @ b
    else:                                   # symm
        s = rng.randn(B * nb, B * nb)
        got = interpret_program(prog, a, s)
        want = a @ (np.tril(s) + np.tril(s, -1).T)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("variant", ["strassen", "winograd", "classical"])
@pytest.mark.parametrize("kind", PROGRAM_KINDS)
def test_program_counts_and_interpreter_match(kind, variant):
    """Every registered algebra x kind x levels 0-3 (the satellite's
    exhaustive grid at fixed leaf shape)."""
    for levels in range(4):
        _check_counts_and_interpreter(kind, variant, levels, 3, 2)


def test_gram_programs_cover_lower_triangle_exactly():
    """Every gram-kind destination satisfies di >= dj and the programs
    cover each lower-triangular leaf destination."""
    for variant in ("strassen", "winograd", "classical"):
        for levels in range(4):
            for kind in ("ata", "aat", "rank_k"):
                prog = compile_program(kind, levels, variant)
                B = prog.blocks
                for p in prog.ops:
                    for di, dj, _s in p.dests:
                        assert di >= dj, (kind,
                                          "upper-triangular destination")
                assert set(prog.by_dest()) == {
                    (i, j) for i in range(B) for j in range(i + 1)}


try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    _HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    SET = dict(deadline=None, max_examples=40,
               suppress_health_check=[HealthCheck.too_slow])

    @given(st.sampled_from(PROGRAM_KINDS),
           st.sampled_from(["strassen", "winograd", "classical"]),
           st.integers(0, 3), st.integers(1, 3), st.integers(1, 3))
    @settings(**SET)
    def test_program_counts_and_interpreter_property(kind, variant,
                                                     levels, mb, nb):
        """Fuzzed leaf shapes over the same algebra x kind x levels
        space (the satellite's hypothesis property)."""
        _check_counts_and_interpreter(kind, variant, levels, mb, nb)


# ---------------------------------------------------------------------------
# Fused executor parity: aat
# ---------------------------------------------------------------------------

def _aat_oracle(a):
    af = np.asarray(a, np.float64)
    return np.tril(af @ af.T)


@pytest.mark.parametrize("m,n", [(16, 16), (32, 24), (24, 40), (57, 31)])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_aat_matches_oracle(m, n, levels):
    a = _rand((m, n), seed=levels + 1)
    got = fused_aat(a, levels=levels, bm=8, bk=8, interpret=True)
    want = _aat_oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5
    assert np.abs(np.triu(np.asarray(got), 1)).max() == 0.0


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_aat_bf16(levels):
    a = _rand((48, 40), jnp.bfloat16, seed=levels)
    got = np.asarray(fused_aat(a, levels=levels, bm=8, bk=8,
                               interpret=True), np.float64)
    want = _aat_oracle(a.astype(jnp.float32))
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2     # bf16 operand noise


def test_fused_aat_packed_layout_and_gram_of_api():
    a = _rand((40, 24), seed=3)
    packed, m_pad = fused_aat_packed(a, levels=1, bm=8, bk=8,
                                     interpret=True)
    t = m_pad // 8
    assert packed.shape == (t * (t + 1) // 2 * 8, 8)
    # the public surface: ata(x, gram_of="rows") in both modes
    got_f = ata(a, gram_of="rows", levels=1, mode="fused", block=8,
                interpret=True)
    got_r = ata(a, gram_of="rows", levels=1, leaf=8, mode="reference")
    want = _aat_oracle(a)
    assert np.abs(np.asarray(got_f, np.float64) - want).max() < 1e-4
    assert np.abs(np.asarray(got_r, np.float64) - want).max() < 1e-4


def test_fused_aat_grad_matches_dense():
    a = _rand((24, 16), seed=5)
    g = jax.grad(lambda x: fused_aat(x, levels=1, bm=8, bk=8,
                                     interpret=True).sum())(a)
    # dA = (S + S^t) A with S = tril(ones)
    s = np.tril(np.ones((24, 24)))
    want = (s + s.T) @ np.asarray(a, np.float64)
    np.testing.assert_allclose(np.asarray(g, np.float64), want,
                               rtol=1e-5, atol=1e-5)


def test_acceptance_aat_512_parity():
    """PR acceptance: fused-vs-dense parity <= 1e-5 at 512^2 fp32 for the
    row gram."""
    a = _rand((512, 512), seed=21)
    got = fused_aat(a, levels=2, bm=128, bk=128, interpret=True)
    want = _aat_oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


# ---------------------------------------------------------------------------
# Fused executor parity: rank_k (accumulating update)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_rank_k_chunked_equals_one_shot(levels):
    a = _rand((96, 64), seed=levels)
    stack, _ = fused_ata_packed(a[:40], levels=levels, bk=8, bn=8,
                                interpret=True)
    for chunk in (a[40:41], a[41:96]):
        stack = fused_rank_k_update(stack, chunk, levels=levels, bk=8,
                                    interpret=True)
    one, _ = fused_ata_packed(a, levels=levels, bk=8, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(stack), np.asarray(one),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_rank_k_bf16_chunks(levels):
    a = _rand((64, 32), jnp.bfloat16, seed=levels + 9)
    st_ = stream.stack_init(32, block=8)
    for chunk in (a[:30], a[30:]):
        st_ = stream.stack_update(st_, chunk, levels=levels, block=8,
                                  interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 32, symmetrize=False),
                     np.float64)
    a64 = np.asarray(a.astype(jnp.float32), np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2


def test_acceptance_rank_k_512_parity():
    """PR acceptance: the accumulating update at 512^2 fp32 within 1e-5
    of the dense oracle (two chunks through the packed state)."""
    a = _rand((512, 512), seed=22)
    st_ = stream.stack_init(512, block=128)
    st_ = stream.stack_update(st_, a[:256], levels=2, block=128,
                              interpret=True)
    st_ = stream.stack_update(st_, a[256:], levels=2, block=128,
                              interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 512, symmetrize=False),
                     np.float64)
    a64 = np.asarray(a, np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-5
    assert int(st_.rows) == 512


def test_rank_k_ragged_chunk_and_level_clamp():
    """Chunks narrower than the stack span are zero-padded (exact) and
    levels clamp to depths the fixed stack layout divides."""
    st_ = stream.stack_init(24, block=8)          # T = 3 tiles
    a = _rand((20, 24), seed=7)
    # T=3 is not divisible by 2^levels for levels>0 -> clamps to 0
    st_ = stream.stack_update(st_, a[:11], levels=2, block=8,
                              interpret=True)
    st_ = stream.stack_update(st_, a[11:], levels=2, block=8,
                              interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 24, symmetrize=False))
    a64 = np.asarray(a, np.float64)
    np.testing.assert_allclose(got, np.tril(a64.T @ a64),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        stream.stack_update(st_, _rand((4, 40), seed=1), block=8)


def test_rank_k_streamed_grad_is_dense_free_capable():
    """jax.grad flows through a stacked streamed update (packed
    cotangent pass-through + symm backward)."""
    a = _rand((24, 16), seed=11)

    def loss(x):
        st_ = stream.stack_init(16, block=8)
        st_ = stream.stack_update(st_, x, levels=1, block=8,
                                  interpret=True)
        return st_.stack.sum()

    g = np.asarray(jax.grad(loss)(a), np.float64)
    # oracle: d sum(stack)/dA — stack holds tril blocks with FULL
    # diagonal tiles, so the cotangent S is block-lower with full diags
    a64 = np.asarray(a, np.float64)
    s = np.zeros((16, 16))
    for i in range(2):
        for j in range(i + 1):
            s[i * 8:(i + 1) * 8, j * 8:(j + 1) * 8] = 1.0
    want = a64 @ (s + s.T)
    np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# IR-driven traffic models for the new kinds
# ---------------------------------------------------------------------------

def test_aat_traffic_model_is_real():
    prog = compile_program("aat", 2, "strassen")
    t = aat_traffic_model(512, 512, levels=2, bm=128, bk=128)
    n_tri = 4 * 5 // 2
    assert t["write_bytes"] == n_tri * 128 * 128 * 4
    assert t["grid_steps"] == n_tri * prog.max_contributions * 1
    assert t["read_bytes"] == (t["grid_steps"] * 2 * prog.max_terms
                               * 128 * 128 * 4)
    assert t["intermediate_bytes"] == 0
    mis = aat_traffic_model(257, 511, levels=2, bm=64, bk=64)
    assert mis["padded_shape"] == (512, 512)
    assert mis["intermediate_bytes"] == 512 * 512 * 4


def test_rank_k_traffic_beats_streamed_baseline():
    """The accumulating kernel reads the state once and writes it once;
    the status-quo streamed update additionally materializes, re-reads
    and re-writes the delta stack — the model must show the saving."""
    t = rank_k_traffic_model(4096, 1024, levels=2, bk=256, bn=256)
    fused = t["read_bytes"] + t["write_bytes"] + t["intermediate_bytes"]
    base = (t["baseline"]["read_bytes"] + t["baseline"]["write_bytes"]
            + t["baseline"]["intermediate_bytes"])
    assert base > fused
    assert t["baseline"]["intermediate_bytes"] >= t["state_bytes"]
    assert t["intermediate_bytes"] == 0     # aligned shape, no pad copy


# ---------------------------------------------------------------------------
# ops-level consumers
# ---------------------------------------------------------------------------

def test_ops_rank_k_update_jit_donation_roundtrip():
    a = _rand((32, 16), seed=13)
    t = 2
    stack = jnp.zeros((t * (t + 1) // 2 * 8, 8), jnp.float32)
    out = ops.rank_k_update(stack, a, levels=1, bk=8, interpret=True)
    one, _ = fused_ata_packed(a, levels=1, bk=8, bn=8,
                              out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(one),
                               rtol=1e-5, atol=1e-5)


def test_ops_aat_fused_entry_points():
    a = _rand((40, 24), seed=14)
    want = _aat_oracle(a)
    got = np.asarray(ops.aat_fused(a, levels=1, bm=8, bk=8,
                                   interpret=True), np.float64)
    assert np.abs(got - want).max() < 1e-4
    packed = ops.aat_fused_packed(a, levels=1, bm=8, bk=8, interpret=True)
    assert packed.ndim == 2 and packed.shape[1] == 8
