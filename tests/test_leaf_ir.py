"""The leaf-program IR (core/leaf_ir.py): algebra registry, compiler
counts vs the cost-model closed forms, the numpy interpreter vs dense
oracles, and the fused executor parity of the two NEW capabilities the IR
bought — the aat (A A^t) row gram and the accumulating rank-k update —
including the PR acceptance bounds (512^2 fp32 <= 1e-5; bf16 levels 0-3).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import ata
from repro.core.cost_model import (aat_mults_exact, ata_mults_exact,
                                   ir_leaf_count, ir_max_terms,
                                   symm_leaf_count)
from repro.core.leaf_ir import (PROGRAM_KINDS, algebra_dims,
                                compile_program, get_algebra,
                                get_gram_algebra, interpret_program,
                                register_algebra, register_gram_algebra,
                                registered_algebras,
                                registered_gram_algebras)
from repro.gram import stream
from repro.kernels import ops
from repro.kernels.strassen_fused import (
    aat_traffic_model, fused_aat, fused_aat_packed, fused_ata_packed,
    fused_rank_k_update, rank_k_traffic_model,
)


def _rand(shape, dtype=jnp.float32, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_ships_three_algebras():
    assert set(registered_algebras()) >= {"strassen", "winograd",
                                          "classical"}
    assert len(get_algebra("strassen")) == 7
    assert len(get_algebra("classical")) == 8
    with pytest.raises(ValueError):
        get_algebra("nope")
    with pytest.raises(ValueError):
        register_algebra("strassen", get_algebra("strassen"))  # duplicate
    with pytest.raises(ValueError):
        register_algebra("bad", ((((0, 0, 2),), ((0, 0, 1),),
                                  ((0, 0, 1),)),))              # bad sign


def test_registering_a_new_algebra_compiles_and_evaluates():
    """A new variant is one register_algebra call: the 2x2 classical
    table under a fresh name compiles every kind and matches the oracle
    through the interpreter — variants are data, not code."""
    name = "classical-copy-test"
    if name not in registered_algebras():
        register_algebra(name, get_algebra("classical"))
    rng = np.random.RandomState(0)
    a = rng.randn(8, 4)
    got = interpret_program(compile_program("ata", 2, name), a)
    np.testing.assert_allclose(got, np.tril(a.T @ a), atol=1e-9)
    got = interpret_program(compile_program("aat", 1, name), a)
    np.testing.assert_allclose(got, np.tril(a @ a.T), atol=1e-9)


def test_fused_matmul_both_trans_forward_and_grads():
    """C = a^t b^t with BOTH transposes folded into the index maps, and
    its fused VJP (regression: the two-flag case routed through the
    single-flag branch and returned wrong gradients)."""
    from repro.kernels.strassen_fused import fused_matmul
    a = _rand((40, 16), seed=31)          # stored (k, m)
    b = _rand((24, 40), seed=32)          # stored (n, k)
    out = fused_matmul(a, b, levels=1, bm=8, bk=8, bn=8, trans_a=True,
                       trans_b=True, interpret=True)
    want = np.asarray(a, np.float64).T @ np.asarray(b, np.float64).T
    assert np.abs(np.asarray(out, np.float64) - want).max() < 1e-4
    da, db = jax.grad(
        lambda p, q: fused_matmul(p, q, levels=1, bm=8, bk=8, bn=8,
                                  trans_a=True, trans_b=True,
                                  interpret=True).sum(),
        argnums=(0, 1))(a, b)
    g = np.ones((16, 24))
    wa = np.asarray(b, np.float64).T @ g.T       # dA = B^t g^t, (k, m)
    wb = g.T @ np.asarray(a, np.float64).T       # dB = g^t A^t, (n, k)
    np.testing.assert_allclose(np.asarray(da), wa, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), wb, rtol=1e-4, atol=1e-4)


def test_reregistration_invalidates_executor_tables():
    """register_algebra(overwrite=True) must clear the executor's lowered
    scalar-prefetch tables, not just the program cache — a stale table
    would make the kernel silently run the OLD algebra."""
    from repro.kernels.strassen_fused import _program_tables, fused_matmul
    a = _rand((8, 8), seed=33)
    _ = fused_matmul(a, a, levels=1, bm=8, bk=8, bn=8, interpret=True)
    assert _program_tables.cache_info().currsize > 0
    register_algebra("strassen", get_algebra("strassen"), overwrite=True)
    assert _program_tables.cache_info().currsize == 0


def test_unknown_kind_and_bad_trans_rejected():
    with pytest.raises(ValueError):
        compile_program("gemm", 1)
    with pytest.raises(ValueError):
        compile_program("ata", 1, trans_a=True)
    with pytest.raises(ValueError):
        compile_program("matmul", -1)


# ---------------------------------------------------------------------------
# Counts + interpreter vs closed forms / oracles.  The exhaustive sweep
# runs unconditionally; the hypothesis property (random leaf shapes over
# the same space) adds fuzzed coverage where hypothesis is installed.
# ---------------------------------------------------------------------------

def _check_counts_and_interpreter(kind, variant, levels, mb, nb,
                                  gram="strassen"):
    """Compiled LeafProgram leaf/term counts == cost-model closed forms;
    numpy interpreter == dense oracle."""
    prog = compile_program(kind, levels, variant, gram=gram)
    assert len(prog.ops) == ir_leaf_count(kind, levels, variant, gram=gram)
    assert prog.max_terms == ir_max_terms(kind, levels, variant, gram=gram)
    Bm, Bk, Bn = prog.blocks_m, prog.blocks_k, prog.blocks_n
    # gram kinds: mult_count ties to the recursion closed forms too
    # (ata_mults_exact models the paper's 7-product HASA — the 8-product
    # classical table and the dps gram recursion deliberately differ)
    if variant in ("strassen", "winograd") and gram == "strassen":
        if kind in ("ata", "rank_k"):
            assert prog.mult_count(mb, nb) == ata_mults_exact(
                mb * Bm, nb * Bn, leaf=0, levels=levels)
        elif kind == "aat":
            assert prog.mult_count(mb, nb) == aat_mults_exact(
                mb * Bm, nb * Bn, leaf=0, levels=levels)

    rng = np.random.RandomState(levels * 7 + mb)
    if kind in ("ata", "rank_k"):
        a = rng.randn(Bm * mb, Bn * nb)
        c0 = (np.tril(rng.randn(Bn * nb, Bn * nb))
              if kind == "rank_k" else None)
        got = interpret_program(prog, a, c0=c0)
        want = np.tril(a.T @ a) + (c0 if c0 is not None else 0.0)
    elif kind == "aat":
        a = rng.randn(Bm * mb, Bn * nb)
        got = interpret_program(prog, a)
        want = np.tril(a @ a.T)
    elif kind == "matmul":
        a = rng.randn(Bm * mb, Bk * nb)
        b = rng.randn(Bk * nb, Bn * mb)
        got = interpret_program(prog, a, b)
        want = a @ b
    else:                                   # symm
        a = rng.randn(Bm * mb, Bn * nb)
        s = rng.randn(Bn * nb, Bn * nb)
        got = interpret_program(prog, a, s)
        want = a @ (np.tril(s) + np.tril(s, -1).T)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def _kind_variant_grid():
    """(kind, variant, gram) combos the compiler accepts, enumerated
    from the LIVE registries — a newly registered algebra or gram table
    is automatically swept (the satellite's dynamic parametrization)."""
    out = []
    for kind in PROGRAM_KINDS:
        for v in registered_algebras():
            dm, dk, dn = algebra_dims(v)
            if kind in ("ata", "aat", "rank_k"):
                if (dm, dk, dn) != (2, 2, 2):
                    continue            # gram table expansion needs 2x2x2
                out.extend((kind, v, g)
                           for g in registered_gram_algebras())
            elif kind == "symm":
                if dk != dn:
                    continue            # Sym operand splits k like n
                out.append((kind, v, "strassen"))
            else:
                out.append((kind, v, "strassen"))
    return out


@pytest.mark.parametrize("kind,variant,gram", _kind_variant_grid())
def test_program_counts_and_interpreter_match(kind, variant, gram):
    """Every registered algebra/gram x kind x levels 0-3 (the
    satellite's exhaustive grid at fixed leaf shape)."""
    # rect tables fan out fast (bb422 symm @ 4 = 14^4 ops) — depth 3 is
    # plenty for them
    depth = 4 if max(algebra_dims(variant)) == 2 else 3
    for levels in range(depth):
        _check_counts_and_interpreter(kind, variant, levels, 3, 2,
                                      gram=gram)


def test_gram_programs_cover_lower_triangle_exactly():
    """Every gram-kind destination satisfies di >= dj and the programs
    cover each lower-triangular leaf destination — for every registered
    square variant x gram algebra."""
    variants = [v for v in registered_algebras()
                if algebra_dims(v) == (2, 2, 2)]
    for variant in variants:
        for gram in registered_gram_algebras():
            for levels in range(4):
                for kind in ("ata", "aat", "rank_k"):
                    prog = compile_program(kind, levels, variant,
                                           gram=gram)
                    B = prog.blocks
                    for p in prog.ops:
                        for di, dj, *_ in p.dests:
                            assert di >= dj, (kind,
                                              "upper-triangular "
                                              "destination")
                    assert set(prog.by_dest()) == {
                        (i, j) for i in range(B) for j in range(i + 1)}


try:
    from hypothesis import given, settings, strategies as st, HealthCheck
    _HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    SET = dict(deadline=None, max_examples=40,
               suppress_health_check=[HealthCheck.too_slow])

    @given(st.sampled_from(_kind_variant_grid()),
           st.integers(0, 3), st.integers(1, 3), st.integers(1, 3))
    @settings(**SET)
    def test_program_counts_and_interpreter_property(kvg, levels, mb, nb):
        """Fuzzed leaf shapes over the same algebra x kind x levels
        space (the satellite's hypothesis property)."""
        kind, variant, gram = kvg
        _check_counts_and_interpreter(kind, variant, levels, mb, nb,
                                      gram=gram)


# ---------------------------------------------------------------------------
# Fused executor parity: aat
# ---------------------------------------------------------------------------

def _aat_oracle(a):
    af = np.asarray(a, np.float64)
    return np.tril(af @ af.T)


@pytest.mark.parametrize("m,n", [(16, 16), (32, 24), (24, 40), (57, 31)])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_aat_matches_oracle(m, n, levels):
    a = _rand((m, n), seed=levels + 1)
    got = fused_aat(a, levels=levels, bm=8, bk=8, interpret=True)
    want = _aat_oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5
    assert np.abs(np.triu(np.asarray(got), 1)).max() == 0.0


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_aat_bf16(levels):
    a = _rand((48, 40), jnp.bfloat16, seed=levels)
    got = np.asarray(fused_aat(a, levels=levels, bm=8, bk=8,
                               interpret=True), np.float64)
    want = _aat_oracle(a.astype(jnp.float32))
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2     # bf16 operand noise


def test_fused_aat_packed_layout_and_gram_of_api():
    a = _rand((40, 24), seed=3)
    packed, m_pad = fused_aat_packed(a, levels=1, bm=8, bk=8,
                                     interpret=True)
    t = m_pad // 8
    assert packed.shape == (t * (t + 1) // 2 * 8, 8)
    # the public surface: ata(x, gram_of="rows") in both modes
    got_f = ata(a, gram_of="rows", levels=1, mode="fused", block=8,
                interpret=True)
    got_r = ata(a, gram_of="rows", levels=1, leaf=8, mode="reference")
    want = _aat_oracle(a)
    assert np.abs(np.asarray(got_f, np.float64) - want).max() < 1e-4
    assert np.abs(np.asarray(got_r, np.float64) - want).max() < 1e-4


def test_fused_aat_grad_matches_dense():
    a = _rand((24, 16), seed=5)
    g = jax.grad(lambda x: fused_aat(x, levels=1, bm=8, bk=8,
                                     interpret=True).sum())(a)
    # dA = (S + S^t) A with S = tril(ones)
    s = np.tril(np.ones((24, 24)))
    want = (s + s.T) @ np.asarray(a, np.float64)
    np.testing.assert_allclose(np.asarray(g, np.float64), want,
                               rtol=1e-5, atol=1e-5)


def test_acceptance_aat_512_parity():
    """PR acceptance: fused-vs-dense parity <= 1e-5 at 512^2 fp32 for the
    row gram."""
    a = _rand((512, 512), seed=21)
    got = fused_aat(a, levels=2, bm=128, bk=128, interpret=True)
    want = _aat_oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


# ---------------------------------------------------------------------------
# Fused executor parity: rank_k (accumulating update)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_rank_k_chunked_equals_one_shot(levels):
    a = _rand((96, 64), seed=levels)
    stack, _ = fused_ata_packed(a[:40], levels=levels, bk=8, bn=8,
                                interpret=True)
    for chunk in (a[40:41], a[41:96]):
        stack = fused_rank_k_update(stack, chunk, levels=levels, bk=8,
                                    interpret=True)
    one, _ = fused_ata_packed(a, levels=levels, bk=8, bn=8, interpret=True)
    np.testing.assert_allclose(np.asarray(stack), np.asarray(one),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_rank_k_bf16_chunks(levels):
    a = _rand((64, 32), jnp.bfloat16, seed=levels + 9)
    st_ = stream.stack_init(32, block=8)
    for chunk in (a[:30], a[30:]):
        st_ = stream.stack_update(st_, chunk, levels=levels, block=8,
                                  interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 32, symmetrize=False),
                     np.float64)
    a64 = np.asarray(a.astype(jnp.float32), np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2


def test_acceptance_rank_k_512_parity():
    """PR acceptance: the accumulating update at 512^2 fp32 within 1e-5
    of the dense oracle (two chunks through the packed state)."""
    a = _rand((512, 512), seed=22)
    st_ = stream.stack_init(512, block=128)
    st_ = stream.stack_update(st_, a[:256], levels=2, block=128,
                              interpret=True)
    st_ = stream.stack_update(st_, a[256:], levels=2, block=128,
                              interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 512, symmetrize=False),
                     np.float64)
    a64 = np.asarray(a, np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-5
    assert int(st_.rows) == 512


def test_rank_k_ragged_chunk_and_level_clamp():
    """Chunks narrower than the stack span are zero-padded (exact) and
    levels clamp to depths the fixed stack layout divides."""
    st_ = stream.stack_init(24, block=8)          # T = 3 tiles
    a = _rand((20, 24), seed=7)
    # T=3 is not divisible by 2^levels for levels>0 -> clamps to 0
    st_ = stream.stack_update(st_, a[:11], levels=2, block=8,
                              interpret=True)
    st_ = stream.stack_update(st_, a[11:], levels=2, block=8,
                              interpret=True)
    got = np.asarray(stream.stack_finalize(st_, 24, symmetrize=False))
    a64 = np.asarray(a, np.float64)
    np.testing.assert_allclose(got, np.tril(a64.T @ a64),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        stream.stack_update(st_, _rand((4, 40), seed=1), block=8)


def test_rank_k_streamed_grad_is_dense_free_capable():
    """jax.grad flows through a stacked streamed update (packed
    cotangent pass-through + symm backward)."""
    a = _rand((24, 16), seed=11)

    def loss(x):
        st_ = stream.stack_init(16, block=8)
        st_ = stream.stack_update(st_, x, levels=1, block=8,
                                  interpret=True)
        return st_.stack.sum()

    g = np.asarray(jax.grad(loss)(a), np.float64)
    # oracle: d sum(stack)/dA — stack holds tril blocks with FULL
    # diagonal tiles, so the cotangent S is block-lower with full diags
    a64 = np.asarray(a, np.float64)
    s = np.zeros((16, 16))
    for i in range(2):
        for j in range(i + 1):
            s[i * 8:(i + 1) * 8, j * 8:(j + 1) * 8] = 1.0
    want = a64 @ (s + s.T)
    np.testing.assert_allclose(g, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# IR-driven traffic models for the new kinds
# ---------------------------------------------------------------------------

def test_aat_traffic_model_is_real():
    prog = compile_program("aat", 2, "strassen")
    t = aat_traffic_model(512, 512, levels=2, bm=128, bk=128)
    n_tri = 4 * 5 // 2
    assert t["write_bytes"] == n_tri * 128 * 128 * 4
    assert t["grid_steps"] == n_tri * prog.max_contributions * 1
    assert t["read_bytes"] == (t["grid_steps"] * 2 * prog.max_terms
                               * 128 * 128 * 4)
    assert t["intermediate_bytes"] == 0
    mis = aat_traffic_model(257, 511, levels=2, bm=64, bk=64)
    assert mis["padded_shape"] == (512, 512)
    assert mis["intermediate_bytes"] == 512 * 512 * 4


def test_rank_k_traffic_beats_streamed_baseline():
    """The accumulating kernel reads the state once and writes it once;
    the status-quo streamed update additionally materializes, re-reads
    and re-writes the delta stack — the model must show the saving."""
    t = rank_k_traffic_model(4096, 1024, levels=2, bk=256, bn=256)
    fused = t["read_bytes"] + t["write_bytes"] + t["intermediate_bytes"]
    base = (t["baseline"]["read_bytes"] + t["baseline"]["write_bytes"]
            + t["baseline"]["intermediate_bytes"])
    assert base > fused
    assert t["baseline"]["intermediate_bytes"] >= t["state_bytes"]
    assert t["intermediate_bytes"] == 0     # aligned shape, no pad copy


# ---------------------------------------------------------------------------
# ops-level consumers
# ---------------------------------------------------------------------------

def test_ops_rank_k_update_jit_donation_roundtrip():
    a = _rand((32, 16), seed=13)
    t = 2
    stack = jnp.zeros((t * (t + 1) // 2 * 8, 8), jnp.float32)
    out = ops.rank_k_update(stack, a, levels=1, bk=8, interpret=True)
    one, _ = fused_ata_packed(a, levels=1, bk=8, bn=8,
                              out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(one),
                               rtol=1e-5, atol=1e-5)


def test_ops_aat_fused_entry_points():
    a = _rand((40, 24), seed=14)
    want = _aat_oracle(a)
    got = np.asarray(ops.aat_fused(a, levels=1, bm=8, bk=8,
                                   interpret=True), np.float64)
    assert np.abs(got - want).max() < 1e-4
    packed = ops.aat_fused_packed(a, levels=1, bm=8, bk=8, interpret=True)
    assert packed.ndim == 2 and packed.shape[1] == 8


# ---------------------------------------------------------------------------
# The DPS gram algebra: counts below strassen-gram, fused parity
# ---------------------------------------------------------------------------

def test_dps_leaf_counts_beat_strassen_gram():
    """G(l) = 2 G(l-1) + 3 t^(l-1) vs the paper's 4 G(l-1) + 2 t^(l-1):
    the dps scheme does strictly fewer leaf products at every level > 0,
    and the compiled programs realize exactly the closed forms."""
    dps_want = (1, 5, 31, 209)
    str_want = (1, 6, 38, 250)
    for lv in range(4):
        dps = ir_leaf_count("ata", lv, "strassen", gram="dps")
        base = ir_leaf_count("ata", lv, "strassen", gram="strassen")
        assert dps == dps_want[lv]
        assert base == str_want[lv]
        if lv > 0:
            assert dps < base
        assert len(compile_program("ata", lv, gram="dps").ops) == dps


def test_dps_interpreter_and_mult_count():
    """The dps program is exact (rational coefficients survive the IR)
    and its scalar mult count undercuts the strassen gram's at equal
    levels and leaf shape."""
    rng = np.random.RandomState(3)
    a = rng.randn(12, 8)
    prog = compile_program("ata", 2, gram="dps")
    np.testing.assert_allclose(interpret_program(prog, a),
                               np.tril(a.T @ a), rtol=1e-9, atol=1e-9)
    base = compile_program("ata", 2, gram="strassen")
    assert prog.mult_count(3, 2) < base.mult_count(3, 2)


def test_acceptance_dps_ata_512_parity():
    """PR acceptance: a registered DPS gram algebra through the fused
    executor — parity <= 1e-5 at 512^2 fp32."""
    a = _rand((512, 512), seed=23)
    got = ops.ata_fused(a, levels=2, gram="dps", bk=128, bn=128,
                        interpret=True)
    a64 = np.asarray(a, np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_acceptance_dps_bf16_levels(levels):
    """PR acceptance: dps gram at bf16, levels 0-3 (level 3's 16-term
    operands exceed MAX_OPERAND_TERMS and clamp with a warning — the
    result must still be correct)."""
    a = _rand((64, 64), jnp.bfloat16, seed=levels + 40)
    got = np.asarray(ops.ata_fused(a, levels=levels, gram="dps", bk=8,
                                   bn=8, interpret=True), np.float64)
    a64 = np.asarray(a.astype(jnp.float32), np.float64)
    want = np.tril(a64.T @ a64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2


def test_dps_aat_and_rank_k_parity():
    """The same gram table drives the row gram and the accumulating
    update."""
    a = _rand((48, 32), seed=24)
    got = fused_aat(a, levels=2, variant="strassen", gram="dps", bm=8,
                    bk=8, interpret=True)
    assert np.abs(np.asarray(got, np.float64)
                  - _aat_oracle(a)).max() < 1e-4
    stack, _ = fused_ata_packed(a[:20], levels=1, gram="dps", bk=8, bn=8,
                                interpret=True)
    stack = fused_rank_k_update(stack, a[20:], levels=1, gram="dps", bk=8,
                                interpret=True)
    one, _ = fused_ata_packed(a, levels=1, gram="dps", bk=8, bn=8,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(stack), np.asarray(one),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Rectangular base cases through the fused matmul executor
# ---------------------------------------------------------------------------

def _matmul_oracle(a, b):
    return np.asarray(a, np.float64) @ np.asarray(b, np.float64)


def test_acceptance_bb322_matmul_512_parity():
    """PR acceptance: a <3, 2, 2>-style rectangular base case through
    compile_program AND the fused executor — parity <= 1e-5 at 512^2
    fp32."""
    from repro.kernels.strassen_fused import fused_matmul
    prog = compile_program("matmul", 2, "bb322")
    assert (prog.blocks_m, prog.blocks_k, prog.blocks_n) == (9, 4, 4)
    a = _rand((512, 512), seed=25)
    b = _rand((512, 512), seed=26)
    got = fused_matmul(a, b, levels=2, variant="bb322", bm=64, bk=64,
                       bn=64, interpret=True)
    want = _matmul_oracle(a, b)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(np.asarray(got, np.float64) - want).max() / scale < 1e-5


@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_bb322_matmul_bf16_levels(levels):
    from repro.kernels.strassen_fused import fused_matmul
    a = _rand((54, 16), jnp.bfloat16, seed=levels + 50)
    b = _rand((16, 16), jnp.bfloat16, seed=levels + 60)
    got = np.asarray(fused_matmul(a, b, levels=levels, variant="bb322",
                                  bm=2, bk=2, bn=2, interpret=True),
                     np.float64)
    want = _matmul_oracle(a.astype(jnp.float32), b.astype(jnp.float32))
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 2e-2


def test_bb422_matmul_parity_and_trans():
    from repro.kernels.strassen_fused import fused_matmul
    a = _rand((64, 32), seed=27)
    b = _rand((32, 16), seed=28)
    got = fused_matmul(a, b, levels=1, variant="bb422", bm=8, bk=8, bn=8,
                       interpret=True)
    assert np.abs(np.asarray(got, np.float64)
                  - _matmul_oracle(a, b)).max() < 1e-4
    # rect split + folded transpose compose
    got_t = fused_matmul(jnp.asarray(np.asarray(a).T), b, levels=1,
                         variant="bb422", bm=8, bk=8, bn=8, trans_a=True,
                         interpret=True)
    assert np.abs(np.asarray(got_t, np.float64)
                  - _matmul_oracle(a, b)).max() < 1e-4


# ---------------------------------------------------------------------------
# Satellite regressions: cost-model derivation, registration validation,
# per-instance caches
# ---------------------------------------------------------------------------

def test_symm_leaf_count_derived_from_registered_table():
    """symm_leaf_count must be t**levels of the ACTUAL registered table,
    not a hardcoded (8 if classical else 7)**levels — regression via a
    toy 6-product <6, 1, 1> classical split."""
    name = "toy-611-test"
    if name not in registered_algebras():
        # C[i, 0] = A[i, 0] * B[0, 0]: six scalar products, one per
        # output row — trivially correct, deliberately not 7 or 8 wide
        register_algebra(
            name,
            tuple((((i, 0, 1),), ((0, 0, 1),), ((i, 0, 1),))
                  for i in range(6)),
            dims=(6, 1, 1))
    for lv in range(3):
        want = 6 ** lv
        assert symm_leaf_count(lv, name) == want
        assert want not in (7 ** lv, 8 ** lv) or lv == 0
        # dk == dn == 1, so the symm kind compiles: the closed form must
        # match the program the executor would actually run
        assert len(compile_program("symm", lv, name).ops) == want
    assert symm_leaf_count(2, "classical") == 64
    assert symm_leaf_count(2, "strassen") == 49


def test_register_algebra_rejects_malformed_tables():
    """Empty tables/quad lists and malformed rows must fail with clear
    ValueErrors at registration, not crash mid-compile on tuple
    unpacking."""
    with pytest.raises(ValueError, match="non-empty"):
        register_algebra("bad-empty-test", ())
    with pytest.raises(ValueError, match="empty a_quads"):
        register_algebra("bad-equad-test",
                         (((), ((0, 0, 1),), ((0, 0, 1),)),))
    with pytest.raises(ValueError, match=r"\(a, b, dest\) triple"):
        register_algebra("bad-arity-test", ((((0, 0, 1),), ((0, 0, 1),)),))
    with pytest.raises(ValueError, match=r"\(row, col, coeff\)"):
        register_algebra("bad-quad-test",
                         ((((0, 0),), ((0, 0, 1),), ((0, 0, 1),)),))
    with pytest.raises(ValueError, match="nonzero finite real"):
        register_algebra("bad-coeff-test",
                         ((((0, 0, 0),), ((0, 0, 1),), ((0, 0, 1),)),))
    # structurally fine but algebraically wrong: the levels=1 numeric
    # identity smoke-check catches it at registration time
    with pytest.raises(ValueError, match="identity"):
        register_algebra(
            "bad-algebra-test",
            tuple((((i, j, 1),), ((j, kq, 1),), ((i, kq, 2),))
                  for i in range(2) for j in range(2) for kq in range(2)))
    for n in ("bad-empty-test", "bad-equad-test", "bad-arity-test",
              "bad-quad-test", "bad-coeff-test", "bad-algebra-test"):
        assert n not in registered_algebras()


def test_register_gram_algebra_validation():
    base = get_gram_algebra("strassen")
    with pytest.raises(ValueError, match="already registered"):
        register_gram_algebra("strassen", **base)
    with pytest.raises(ValueError, match="empty term list"):
        register_gram_algebra("bad-gram-test",
                              sym=(((), ((0, 0, 1, 0),)),), mm=base["mm"])
    with pytest.raises(ValueError, match=r"\(g, o, coeff\)"):
        register_gram_algebra("bad-gram-test",
                              sym=((((0, 0),), ((0, 0, 1, 0),)),),
                              mm=base["mm"])
    with pytest.raises(ValueError, match=r"\(di, dj, coeff, trans\)"):
        register_gram_algebra("bad-gram-test",
                              sym=((((0, 0, 1),), ((0, 0, 1),)),),
                              mm=base["mm"])
    with pytest.raises(ValueError, match="lower triangle"):
        register_gram_algebra("bad-gram-test",
                              sym=((((0, 0, 1),), ((0, 1, 1, 0),)),),
                              mm=base["mm"])
    with pytest.raises(ValueError, match="sym dest"):
        register_gram_algebra("bad-gram-test",
                              sym=((((0, 0, 1),), ((1, 0, 1, 1),)),),
                              mm=base["mm"])
    with pytest.raises(ValueError, match="at least one mm"):
        register_gram_algebra("bad-gram-test", sym=base["sym"], mm=())
    with pytest.raises(ValueError, match="at least one sym"):
        register_gram_algebra("bad-gram-test", sym=(), mm=base["mm"])
    # structurally valid, numerically wrong (C11 doubled)
    wrong_sym = ((((0, 0, 2),), ((0, 0, 1, 0),)),) + base["sym"][1:]
    with pytest.raises(ValueError, match="identity"):
        register_gram_algebra("bad-gram-test", sym=wrong_sym,
                              mm=base["mm"])
    assert "bad-gram-test" not in registered_gram_algebras()


def test_program_caches_die_with_program():
    """contributions()/by_dest() memoize per instance — a module-level
    lru_cache keyed on the program would pin every program ever compiled
    for process lifetime (regression: autotune sweeps compile many)."""
    import dataclasses
    import gc
    import weakref
    # dataclasses.replace with a fresh _cache gives an instance the
    # compile_program lru_cache does NOT hold
    prog = dataclasses.replace(compile_program("ata", 2), _cache={})
    assert prog.contributions() and prog.by_dest()
    assert "contributions" in prog._cache and "by_dest" in prog._cache
    ref = weakref.ref(prog)
    del prog
    gc.collect()
    assert ref() is None, "program (and its memoized tables) leaked"
