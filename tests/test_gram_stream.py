"""Streaming Gram accumulator: any chunking == one-shot ata_full.

The hypothesis-driven any-chunking property lives in test_properties.py
(gated on hypothesis availability); here the same invariant is pinned by
deterministic parametrized cases — fp32/bf16, ragged final chunk,
levels 0-2 — plus the sharded streaming variant via an 8-device
subprocess (same pattern as test_distributed.py).
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gram
from repro.core.ata import ata_full

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def _oracle(a):
    a64 = np.asarray(a, np.float64)
    return a64.T @ a64


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("levels", [0, 1, 2])
@pytest.mark.parametrize("chunks", [
    [(0, 96)],                       # one shot through the stream
    [(0, 32), (32, 64), (64, 96)],   # even chunks
    [(0, 40), (40, 89), (89, 96)],   # ragged, incl. a 7-row tail
    [(0, 1), (1, 2), (2, 96)],       # degenerate 1-row chunks
])
def test_stream_matches_one_shot(dtype, tol, levels, chunks):
    m, n = 96, 24
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n)).astype(dtype)
    st = gram.stream_init(n)
    for lo, hi in chunks:
        st = gram.stream_update(st, a[lo:hi], levels=levels, leaf=8)
    got = np.asarray(gram.stream_finalize(st), np.float64)
    want = _oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < tol
    assert int(st.rows) == m


def test_stream_matches_ata_full_fused_interpret():
    """The fused Pallas path (interpret mode) agrees with streaming too."""
    a = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    st = gram.stream_init(32)
    for lo, hi in [(0, 48), (48, 64)]:
        st = gram.stream_update(st, a[lo:hi], levels=1, mode="fused",
                                block=16, interpret=True)
    got = np.asarray(gram.stream_finalize(st), np.float64)
    want = _oracle(a)
    assert np.abs(got - want).max() / np.abs(want).max() < 5e-5


def test_stream_finalize_tril_only():
    a = jax.random.normal(jax.random.PRNGKey(2), (20, 10), jnp.float32)
    st = gram.stream_update(gram.stream_init(10), a, levels=1, leaf=4)
    low = np.asarray(gram.stream_finalize(st, symmetrize=False))
    assert np.abs(np.triu(low, 1)).max() == 0.0
    full = np.asarray(gram.stream_finalize(st))
    np.testing.assert_allclose(full, full.T, rtol=1e-6)


def test_stream_state_is_packed():
    """The accumulator holds n(n+1)/2 words — the paper's storage bound —
    not a dense n^2 buffer."""
    st = gram.stream_init(64)
    assert st.packed.shape == (64 * 65 // 2,)
    assert st.n == 64


def test_stream_rejects_mismatched_chunk():
    st = gram.stream_init(8)
    with pytest.raises(ValueError):
        gram.stream_update(st, jnp.zeros((4, 9)))


def test_normalized_second_moment():
    """C / rows is the running second moment — the typical consumer
    reading (preconditioners, whitening)."""
    a = jax.random.normal(jax.random.PRNGKey(3), (200, 12), jnp.float32)
    st = gram.stream_init(12)
    for lo in range(0, 200, 50):
        st = gram.stream_update(st, a[lo:lo + 50], levels=1, leaf=4)
    c = np.asarray(gram.stream_finalize(st)) / int(st.rows)
    want = _oracle(a) / 200
    np.testing.assert_allclose(c, want, rtol=1e-4, atol=1e-5)


def test_sharded_streaming_subprocess():
    """Row-sharded streaming (reduce-scatter state) == sequential, on 8
    forced-host devices in a child process (main process keeps 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(HERE / "_gram_stream_check.py")],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL_OK" in out.stdout
