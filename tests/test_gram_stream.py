"""Streaming Gram accumulator: any chunking == one-shot ata_full.

The hypothesis-driven any-chunking property lives in test_properties.py
(gated on hypothesis availability); here the same invariant is pinned by
deterministic parametrized cases — fp32/bf16, ragged final chunk,
levels 0-2 — plus the sharded/distributed streaming variants on 8
forced-host devices via the ``multidevice`` marker (tests/conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import gram
from repro.core.ata import ata_full


def _oracle(a):
    a64 = np.asarray(a, np.float64)
    return a64.T @ a64


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-5),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("levels", [0, 1, 2])
@pytest.mark.parametrize("chunks", [
    [(0, 96)],                       # one shot through the stream
    [(0, 32), (32, 64), (64, 96)],   # even chunks
    [(0, 40), (40, 89), (89, 96)],   # ragged, incl. a 7-row tail
    [(0, 1), (1, 2), (2, 96)],       # degenerate 1-row chunks
])
def test_stream_matches_one_shot(dtype, tol, levels, chunks):
    m, n = 96, 24
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n)).astype(dtype)
    st = gram.stream_init(n)
    for lo, hi in chunks:
        st = gram.stream_update(st, a[lo:hi], levels=levels, leaf=8)
    got = np.asarray(gram.stream_finalize(st), np.float64)
    want = _oracle(a)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < tol
    assert int(st.rows) == m


def test_stream_matches_ata_full_fused_interpret():
    """The fused Pallas path (interpret mode) agrees with streaming too."""
    a = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    st = gram.stream_init(32)
    for lo, hi in [(0, 48), (48, 64)]:
        st = gram.stream_update(st, a[lo:hi], levels=1, mode="fused",
                                block=16, interpret=True)
    got = np.asarray(gram.stream_finalize(st), np.float64)
    want = _oracle(a)
    assert np.abs(got - want).max() / np.abs(want).max() < 5e-5


def test_stream_finalize_tril_only():
    a = jax.random.normal(jax.random.PRNGKey(2), (20, 10), jnp.float32)
    st = gram.stream_update(gram.stream_init(10), a, levels=1, leaf=4)
    low = np.asarray(gram.stream_finalize(st, symmetrize=False))
    assert np.abs(np.triu(low, 1)).max() == 0.0
    full = np.asarray(gram.stream_finalize(st))
    np.testing.assert_allclose(full, full.T, rtol=1e-6)


def test_stream_state_is_packed():
    """The accumulator holds n(n+1)/2 words — the paper's storage bound —
    not a dense n^2 buffer."""
    st = gram.stream_init(64)
    assert st.packed.shape == (64 * 65 // 2,)
    assert st.n == 64


def test_stream_rejects_mismatched_chunk():
    st = gram.stream_init(8)
    with pytest.raises(ValueError):
        gram.stream_update(st, jnp.zeros((4, 9)))


def test_normalized_second_moment():
    """C / rows is the running second moment — the typical consumer
    reading (preconditioners, whitening)."""
    a = jax.random.normal(jax.random.PRNGKey(3), (200, 12), jnp.float32)
    st = gram.stream_init(12)
    for lo in range(0, 200, 50):
        st = gram.stream_update(st, a[lo:lo + 50], levels=1, leaf=4)
    c = np.asarray(gram.stream_finalize(st)) / int(st.rows)
    want = _oracle(a) / 200
    np.testing.assert_allclose(c, want, rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice(8)
def test_sharded_streaming_8dev(multidevice_count):
    """Row-sharded streaming (reduce-scatter state) == sequential, on 8
    forced-host devices (ported from the old ad-hoc subprocess script to
    the ``multidevice`` marker)."""
    from jax.sharding import PartitionSpec as P
    from repro.core.distributed import shard_map_compat

    P_DEV, m, n = 8, 128, 64
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
    want = _oracle(a)

    mesh = jax.make_mesh((P_DEV,), ("data",))
    shard_map, unchecked = shard_map_compat()

    def stream(chunks):
        # per-device: fold row-sharded chunks into the block-row shard of C
        c = gram.sharded_init(n, P_DEV)
        for chunk in chunks:
            c = gram.update_sharded(c, chunk, "data", levels=1, leaf=8)
        return c

    chunk_bounds = [(0, 48), (48, 128)]   # ragged: 48 and 80 rows
    chunks = tuple(a[lo:hi] for lo, hi in chunk_bounds)
    got = shard_map(
        stream, mesh=mesh,
        in_specs=(P("data", None),),     # pytree prefix: every chunk by rows
        out_specs=P("data", None), **unchecked,
    )(chunks)
    got = np.asarray(jax.device_get(got), np.float64)
    assert got.shape == (n, n)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, err


@pytest.mark.multidevice(8)
@pytest.mark.parametrize("scheme", ["reducescatter", "ring", "bfs25d"])
def test_distributed_streaming_composes_with_schemes(scheme,
                                                     multidevice_count):
    """pjit-level distributed streaming: any chunking through
    distributed_init/update/finalize == one-shot oracle, for the
    reduce-scatter state AND the half-ring/2.5D circulant stack states."""
    from jax.sharding import Mesh

    m, n = 96, 48
    a = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.float32)
    want = _oracle(a)

    if scheme == "reducescatter":
        mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))
        kw = dict(row_axis="data", col_axis=None)
    elif scheme == "ring":
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        kw = dict(row_axis="data", col_axis="model")
    else:
        mesh = Mesh(np.array(jax.devices()).reshape(2, 1, 4),
                    ("rep", "data", "model"))
        kw = dict(row_axis="data", col_axis="model", rep_axis="rep")

    state = gram.distributed_init(
        n, mesh, scheme=scheme,
        **{k: v for k, v in kw.items() if k != "rep_axis"})
    for lo, hi in [(0, 32), (32, 96)]:   # ragged chunks, rows divide axes
        state = gram.distributed_update(state, a[lo:hi], mesh,
                                        scheme=scheme, levels=1, leaf=8,
                                        **kw)
    got = np.asarray(jax.device_get(gram.distributed_finalize(
        state, mesh, scheme=scheme,
        col_axis=kw.get("col_axis"))), np.float64)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, (scheme, err)
