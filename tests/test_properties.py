"""Hypothesis property tests on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.ata import ata, ata_full
from repro.core.distributed import (assemble_ring_gram, ring_layout_coords,
                                    ring_stack_len)
from repro.core.schedule import plan_symm
from repro.core.strassen import strassen_matmul
from repro.core.symmetry import (pack_tril, unpack_tril, tri_index,
                                 tri_coords, tri_count)
from repro.core.cost_model import (ata_mults_exact, strassen_mults_exact,
                                   symm_leaf_count, symm_mults_exact,
                                   npl, lmax, latency_messages)
from repro.core.leaf_ir import algebra_dims, registered_algebras
from repro.data.pipeline import DataConfig, get_batch
from repro.optim.grad_compress import int8_quantize, int8_dequantize

SET = dict(deadline=None, max_examples=15,
           suppress_health_check=[HealthCheck.too_slow])


def _rand(key, m, n):
    return jax.random.normal(jax.random.PRNGKey(key), (m, n), jnp.float32)


@given(st.integers(0, 2**31 - 1), st.integers(3, 80), st.integers(2, 60),
       st.integers(0, 3))
@settings(**SET)
def test_ata_matches_tril_oracle(key, m, n, levels):
    a = _rand(key, m, n)
    got = np.asarray(ata(a, levels=levels, leaf=8), np.float64)
    want = np.tril(np.asarray(a, np.float64).T @ np.asarray(a, np.float64))
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 5e-5
    # strictly-upper part is exactly zero (never computed)
    assert np.abs(np.triu(got, 1)).max() == 0.0


@given(st.integers(0, 2**31 - 1), st.integers(2, 48), st.integers(2, 48))
@settings(**SET)
def test_gram_symmetric_and_psd(key, m, n):
    a = _rand(key, m, n)
    c = np.asarray(ata_full(a, levels=2, leaf=8), np.float64)
    assert np.abs(c - c.T).max() < 1e-5 * max(np.abs(c).max(), 1.0)
    w = np.linalg.eigvalsh(c + 1e-4 * np.eye(n))
    assert w.min() > -1e-3


@given(st.integers(0, 2**31 - 1), st.integers(2, 40), st.integers(2, 40),
       st.integers(2, 40), st.sampled_from(["strassen", "winograd"]),
       st.integers(0, 3))
@settings(**SET)
def test_strassen_matches_matmul(key, m, k, n, variant, levels):
    a = _rand(key, m, k)
    b = _rand(key + 1, k, n)
    got = np.asarray(strassen_matmul(a, b, levels=levels, leaf=4,
                                     variant=variant), np.float64)
    want = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-4


@given(st.integers(1, 64))
@settings(**SET)
def test_pack_unpack_roundtrip(n):
    c = np.tril(np.arange(n * n, dtype=np.float32).reshape(n, n))
    sym = c + np.tril(c, -1).T
    packed = pack_tril(jnp.asarray(sym))
    assert packed.shape == (n * (n + 1) // 2,)
    back = np.asarray(unpack_tril(packed, n, symmetrize=True))
    assert np.array_equal(back, sym)


@given(st.integers(1, 40))
@settings(**SET)
def test_tri_index_bijective(t):
    coords = tri_coords(t)
    assert len(coords) == tri_count(t)
    for lin, (i, j) in enumerate(coords):
        assert tri_index(int(i), int(j)) == lin


@given(st.integers(2, 2000), st.integers(2, 2000))
@settings(**SET)
def test_mult_counts_monotone_and_below_classical(m, n):
    e = ata_mults_exact(m, n, leaf=32)
    assert e <= m * n * (n + 1) // 2 + 1       # never worse than classical
    assert e > 0
    s = strassen_mults_exact(n, m, n, leaf=32)
    assert s <= m * n * n


# every registered algebra whose split keeps the Sym operand square
# (dk == dn) — the LIVE registry, not a hardcoded variant list
_SYMM_VARIANTS = [v for v in registered_algebras()
                  if algebra_dims(v)[1] == algebra_dims(v)[2]]


@given(st.integers(0, 4), st.sampled_from(_SYMM_VARIANTS),
       st.integers(1, 8), st.integers(1, 8))
@settings(**SET)
def test_plan_symm_counts_match_cost_model(levels, variant, mb, nb):
    """The flattened X @ Sym schedule (the fused Gram backward) has
    exactly the leaf/multiplication counts of the cost model's closed
    forms at every depth <= 4 — and never references the upper triangle
    of the packed operand."""
    if max(algebra_dims(variant)) > 2:
        levels = min(levels, 3)       # bb422 @ 4 is 14^4 = 38k ops
    plan = plan_symm(levels, variant)
    assert plan.kind == "symm"
    assert len(plan.products) == symm_leaf_count(levels, variant)
    Bm, Bn = plan.blocks_m, plan.blocks_n
    assert plan.mult_count(mb, nb) == symm_mults_exact(
        mb * Bm, nb * Bn, levels, variant)
    for p in plan.products:
        for r, c, _s, _t in p.right:
            assert r >= c, "symm plan referenced the upper triangle"


@given(st.integers(1, 5000))
@settings(**SET)
def test_process_tree_invariants(p):
    level = lmax(p)
    assert npl(level) <= p
    if level < 6:
        assert npl(level + 1) > p
    # paper §5: L(n,P) = max(4(lmax-1), 3 lmax) and lmax < log_7 P bound
    assert latency_messages(p) == max(4 * max(level - 1, 0), 3 * level)


@given(st.integers(1, 64))
@settings(**SET)
def test_ring_layout_covers_lower_triangle_exactly_once(t):
    """The half-ring ownership map assigns every lower-triangle block
    coordinate of a T x T block grid to exactly one (device, step) slot,
    for arbitrary odd/even T — no gaps, no antipodal double-counting."""
    coords = ring_layout_coords(t)
    covered = [(i, j) for (_, _, i, j) in coords]
    assert len(covered) == len(set(covered)), "duplicate block ownership"
    assert set(covered) == {(i, j) for i in range(t) for j in range(i + 1)}
    # slots are within the stack and each (device, step) appears once
    slots = [(dev, s) for (dev, s, _, _) in coords]
    assert len(slots) == len(set(slots))
    assert all(0 <= s < ring_stack_len(t) and 0 <= dev < t
               for dev, s in slots)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 6),
       st.integers(1, 3))
@settings(**SET)
def test_assemble_ring_gram_roundtrips_half_ring_layout(key, t, n_loc,
                                                        m_mult):
    """assemble_ring_gram rebuilds the dense oracle from a half-ring
    block-stack laid out per the gram_ring contract (entry s, device d =
    C[d, (d-s) % T], antipodal duplicates zeroed) — the single-device
    simulation of the multi-device layout, for arbitrary odd/even T."""
    n = t * n_loc
    m = m_mult * 4
    a = _rand(key, m, n)
    a64 = np.asarray(a, np.float64)
    want = a64.T @ a64
    owned = {(dev, s) for (dev, s, _, _) in ring_layout_coords(t)}
    half = t // 2
    stacks = np.zeros((half + 1, n_loc, n), np.float64)
    for dev in range(t):
        for s in range(half + 1):
            if (dev, s) not in owned:
                continue                     # masked antipodal duplicate
            j = (dev - s) % t
            stacks[s][:, dev * n_loc:(dev + 1) * n_loc] = (
                a64[:, dev * n_loc:(dev + 1) * n_loc].T
                @ a64[:, j * n_loc:(j + 1) * n_loc])
    got = np.asarray(
        assemble_ring_gram(jnp.asarray(stacks, jnp.float32), t, n),
        np.float64)
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() / scale < 1e-5


@given(st.integers(0, 2**31 - 1), st.integers(0, 10_000))
@settings(**SET)
def test_pipeline_pure_function_of_step(seed, step):
    dc = DataConfig(vocab_size=97, seq_len=8, global_batch=2, seed=seed)
    a = get_batch(dc, step)
    b = get_batch(dc, step)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["inputs"].min() >= 0 and a["inputs"].max() < 97


@given(st.integers(0, 2**31 - 1), st.floats(1e-6, 1e4))
@settings(**SET)
def test_int8_quantization_error_bound(key, scale_mag):
    x = _rand(key, 4, 16).reshape(-1) * scale_mag
    q, s = int8_quantize(x)
    err = np.abs(np.asarray(int8_dequantize(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6 * scale_mag


@given(st.integers(0, 2**31 - 1), st.integers(1, 50), st.integers(2, 24),
       st.data(), st.sampled_from([jnp.float32, jnp.bfloat16]),
       st.integers(0, 2))
@settings(**SET)
def test_stream_any_chunking_matches_one_shot(key, m, n, data, dtype,
                                              levels):
    """ANY row chunking of A through gram.stream — including ragged final
    chunks — reproduces the one-shot ata_full(A) within dtype tolerance."""
    from repro import gram

    a = _rand(key, m, n).astype(dtype)
    n_cuts = data.draw(st.integers(0, min(m - 1, 4)))
    cuts = sorted(data.draw(
        st.lists(st.integers(1, max(m - 1, 1)), min_size=n_cuts,
                 max_size=n_cuts, unique=True))) if m > 1 else []
    bounds = [0, *cuts, m]
    st_state = gram.stream_init(n)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        st_state = gram.stream_update(st_state, a[lo:hi], levels=levels,
                                      leaf=8)
    got = np.asarray(gram.stream_finalize(st_state), np.float64)
    a64 = np.asarray(a, np.float64)
    want = a64.T @ a64
    scale = max(np.abs(want).max(), 1.0)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    assert np.abs(got - want).max() / scale < tol
    assert int(st_state.rows) == m


@given(st.integers(0, 2**31 - 1))
@settings(**SET)
def test_stochastic_round_bf16_deterministic_under_fixed_key(key):
    """SR is a pure function of (x, key): bit-identical replay under the
    same threefry key, different under a different one."""
    from repro.kernels.strassen_fused import stochastic_round_bf16

    x = _rand(key, 16, 16) * 3.0
    k1, k2 = jax.random.PRNGKey(key), jax.random.PRNGKey(key ^ 0x5bd1e995)
    r1 = np.asarray(stochastic_round_bf16(x, k1).astype(jnp.float32))
    r2 = np.asarray(stochastic_round_bf16(x, k1).astype(jnp.float32))
    assert np.array_equal(r1, r2)
    r3 = np.asarray(stochastic_round_bf16(x, k2).astype(jnp.float32))
    assert not np.array_equal(r1, r3)
    # every output is exactly a bf16 value (round went DOWN or UP, never
    # anywhere else)
    assert np.array_equal(
        r1, np.asarray(jnp.asarray(r1).astype(jnp.bfloat16)
                       .astype(jnp.float32)))


def test_stochastic_round_bf16_mean_unbiased():
    """E[SR(x)] == x: a value 1/8 of the way between two bf16 neighbours
    must round up ~12.5% of the time, so the sample mean over 2^14
    independent draws sits far closer to x than either neighbour."""
    from repro.kernels.strassen_fused import stochastic_round_bf16

    val = 1.0 + 2.0 ** -10          # bf16 ulp at 1.0 is 2^-7
    xs = jnp.full((1 << 14,), val, jnp.float32)
    r = np.asarray(stochastic_round_bf16(
        xs, jax.random.PRNGKey(0)).astype(np.float32), np.float64)
    lo, hi = 1.0, 1.0 + 2.0 ** -7
    assert set(np.unique(r)) == {lo, hi}
    # p(up) = 1/8; std of the mean ~ ulp * sqrt(p(1-p)) / 2^7 ~ 2e-5, so
    # 1e-4 leaves ~5 sigma while nearest-rounding (always down) would
    # miss by the full 2^-10 ~ 9.8e-4
    assert abs(r.mean() - val) < 1e-4


def test_ata_fused_sr_seed_deterministic():
    """sr_seed pins the SR key: two calls with the same seed are
    bit-identical, a different seed is not (at bf16 output)."""
    from repro.kernels import ops

    a = _rand(9, 96, 64)
    kw = dict(levels=1, bk=32, bn=32, out_dtype=jnp.bfloat16)
    o1 = np.asarray(ops.ata_fused(a, sr_seed=7, **kw).astype(jnp.float32))
    o2 = np.asarray(ops.ata_fused(a, sr_seed=7, **kw).astype(jnp.float32))
    o3 = np.asarray(ops.ata_fused(a, sr_seed=8, **kw).astype(jnp.float32))
    assert np.array_equal(o1, o2)
    assert not np.array_equal(o1, o3)
