"""Gradient parity for the fused Pallas paths' custom VJPs
(kernels/strassen_fused.py): the closed-form backward passes
(dA = A (S + S^t) for the tril gram; the standard matmul VJP) against
jax.grad through the reference recursion — fp32 and bf16, square and
rectangular 257x511 (prime-ish, exercises the padding path).  Runs in
interpret mode off-TPU like the forward-parity suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ata import ata
from repro.core.strassen import strassen_matmul


def _rel(got, want):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-9)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("shape,block", [((64, 64), 16),
                                         ((257, 511), 128)])
def test_fused_ata_grad_matches_reference(dtype, tol, shape, block):
    m, n = shape
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)

    def loss(x, mode):
        c = ata(x, levels=1, leaf=16, mode=mode, block=block,
                interpret=True, out_dtype=jnp.float32)
        return jnp.vdot(w, c)

    g_fused = jax.grad(lambda x: loss(x, "fused"))(a)
    g_ref = jax.grad(lambda x: loss(x, "reference"))(a)
    assert g_fused.shape == a.shape and g_fused.dtype == a.dtype
    assert _rel(g_fused, g_ref) < tol


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("mkn,block", [((64, 64, 64), 16),
                                       ((257, 64, 511), 128)])
def test_fused_matmul_grads_match_reference(dtype, tol, mkn, block):
    m, k, n = mkn
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(3), (k, n)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(4), (m, n), jnp.float32)

    def loss(x, y, mode):
        c = strassen_matmul(x, y, levels=1, leaf=16, mode=mode,
                            block=block, interpret=True,
                            out_dtype=jnp.float32)
        return jnp.vdot(w, c)

    gaf, gbf = jax.grad(lambda x, y: loss(x, y, "fused"), (0, 1))(a, b)
    gar, gbr = jax.grad(lambda x, y: loss(x, y, "reference"), (0, 1))(a, b)
    assert gaf.dtype == a.dtype and gbf.dtype == b.dtype
    assert _rel(gaf, gar) < tol
    assert _rel(gbf, gbr) < tol


def test_fused_ata_grad_diagonal_factor():
    """The VJP's S + S^t doubles the tril cotangent's diagonal — exactly
    the quadratic form's derivative; pin it against the dense oracle
    d/dA vdot(W, tril(A^tA)) computed by autodiff of the jnp expression."""
    a = jax.random.normal(jax.random.PRNGKey(5), (24, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 16), jnp.float32)

    g_fused = jax.grad(lambda x: jnp.vdot(w, ata(
        x, levels=1, leaf=8, mode="fused", block=8, interpret=True,
        out_dtype=jnp.float32)))(a)
    g_oracle = jax.grad(lambda x: jnp.vdot(w, jnp.tril(x.T @ x)))(a)
    assert _rel(g_fused, g_oracle) < 1e-4
