"""Gradient parity for the fused Pallas paths' custom VJPs
(kernels/strassen_fused.py).

The fused backward is itself a leaf-task schedule now (DESIGN.md §11):
``dA = A (S + S^t)`` runs ``plan_symm`` through ``fused_symm_matmul``
(packed cotangent, mirrored upper-triangle reads), and the matmul VJP runs
both products through the schedule executor with the transposes folded
into the index maps.  Everything here checks those kernels against
``jax.grad`` of the reference recursion / dense oracles — fp32 and bf16,
square and rectangular 257x511 (prime-ish, exercises the padding path),
levels 0-3, plus the dense / packed / streamed entry points at the
512x512 <= 1e-5 acceptance bar and the backward HBM-traffic acceptance.
Runs in interpret mode off-TPU like the forward-parity suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ata import ata
from repro.core.schedule import plan_symm, evaluate_symm_plan
from repro.core.strassen import strassen_matmul
from repro.core.symmetry import pack_tril_blocks
from repro.kernels.strassen_fused import (
    ata_bwd_traffic_model, fused_ata_packed, fused_symm_matmul,
)


def _rel(got, want):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-9)


# ---------------------------------------------------------------------------
# The symm executor itself (the backward engine), against dense oracles.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [0, 1, 2])
@pytest.mark.parametrize("m,n,bs", [(32, 32, 8), (24, 48, 8), (16, 16, 16)])
def test_fused_symm_matmul_matches_dense(levels, m, n, bs):
    """X @ Sym from packed-lower-only storage: upper tiles are mirrored
    (j, i) reads with the transpose folded into the index maps."""
    rng = np.random.RandomState(levels + m)
    x = jnp.asarray(rng.randn(m, n), jnp.float32)
    s = rng.randn(n, n)
    sym = np.tril(s) + np.tril(s, -1).T
    stack = pack_tril_blocks(jnp.asarray(sym, jnp.float32), bs)
    got = fused_symm_matmul(x, stack, levels=levels, bm=8, interpret=True)
    assert _rel(np.asarray(got)[:, :n], np.asarray(x, np.float64) @ sym) \
        < 1e-5


@pytest.mark.parametrize("levels", [0, 1, 2])
def test_fused_symm_matmul_diag_sym(levels):
    """diag_sym=True computes X @ (S + S^t) — the Gram-VJP operand — with
    the diagonal tiles doubled symmetrically in VMEM."""
    rng = np.random.RandomState(7 + levels)
    x = jnp.asarray(rng.randn(40, 32), jnp.float32)
    s = np.tril(rng.randn(32, 32))
    stack = pack_tril_blocks(jnp.asarray(s, jnp.float32), 8)
    got = fused_symm_matmul(x, stack, levels=levels, bm=8, diag_sym=True,
                            interpret=True)
    assert _rel(got, np.asarray(x, np.float64) @ (s + s.T)) < 1e-5


@pytest.mark.parametrize("variant", ["strassen", "winograd", "classical"])
def test_symm_plan_dense_evaluation(variant):
    """plan_symm evaluated densely in numpy reproduces X @ Sym reading
    only the lower triangle — correct independent of the executor."""
    rng = np.random.RandomState(3)
    for levels in (1, 2):
        B = 1 << levels
        x = rng.randn(B * 3, B * 2)
        s = rng.randn(B * 2, B * 2)
        sym = np.tril(s) + np.tril(s, -1).T
        np.testing.assert_allclose(
            evaluate_symm_plan(plan_symm(levels, variant), x, np.tril(s)),
            x @ sym, rtol=1e-9, atol=1e-9)


def test_fused_symm_bf16_accumulates_fp32():
    rng = np.random.RandomState(11)
    x = jnp.asarray(rng.randn(32, 32)).astype(jnp.bfloat16)
    s = rng.randn(32, 32)
    sym = np.tril(s) + np.tril(s, -1).T
    stack = pack_tril_blocks(jnp.asarray(sym), 8).astype(jnp.bfloat16)
    got = fused_symm_matmul(x, stack, levels=1, bm=8, interpret=True)
    assert got.dtype == jnp.float32          # promoted accumulation dtype
    want = np.asarray(x.astype(jnp.float32), np.float64) \
        @ np.asarray(jnp.asarray(sym).astype(jnp.bfloat16).astype(
            jnp.float32), np.float64)
    assert _rel(got, want) < 5e-2


# ---------------------------------------------------------------------------
# Dense-entry grad parity vs the reference recursion: dtypes x shapes x
# levels 0-3 (levels swept at the small square; the rectangular padded
# case at the depths the shape supports).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("levels", [0, 1, 2, 3])
def test_fused_ata_grad_matches_reference(dtype, tol, levels):
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 64)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.float32)

    def loss(x, mode):
        c = ata(x, levels=levels, leaf=8, mode=mode, block=8,
                interpret=True, out_dtype=jnp.float32)
        return jnp.vdot(w, c)

    g_fused = jax.grad(lambda x: loss(x, "fused"))(a)
    g_ref = jax.grad(lambda x: loss(x, "reference"))(a)
    assert g_fused.shape == a.shape and g_fused.dtype == a.dtype
    assert _rel(g_fused, g_ref) < tol


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("levels", [1, 2])
def test_fused_ata_grad_rectangular(dtype, tol, levels):
    """257x511: prime-ish shape exercises the pad path of forward AND
    backward (the packed cotangent spans the padded 512 grid)."""
    a = jax.random.normal(jax.random.PRNGKey(2), (257, 511)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (511, 511), jnp.float32)

    def loss(x, mode):
        c = ata(x, levels=levels, leaf=32, mode=mode, block=64,
                interpret=True, out_dtype=jnp.float32)
        return jnp.vdot(w, c)

    g_fused = jax.grad(lambda x: loss(x, "fused"))(a)
    g_ref = jax.grad(lambda x: loss(x, "reference"))(a)
    assert _rel(g_fused, g_ref) < tol


def test_fused_vs_dense_bwd_engines_agree():
    """bwd="fused" (symm schedule) and bwd="dense" (dense-dot baseline)
    are the same math; benchmarks rely on both staying selectable."""
    a = jax.random.normal(jax.random.PRNGKey(4), (96, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 64), jnp.float32)

    def g(bwd):
        return jax.grad(lambda x: jnp.vdot(w, ata(
            x, levels=2, mode="fused", bwd=bwd, block=16,
            interpret=True)))(a)

    assert _rel(g("fused"), g("dense")) < 1e-5


# ---------------------------------------------------------------------------
# Packed-cotangent path: fused_ata_packed's custom VJP consumes the packed
# stack directly (no dense unpack anywhere in the backward).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_packed_cotangent_grad(dtype, tol):
    a = jax.random.normal(jax.random.PRNGKey(6), (48, 32)).astype(dtype)
    bn = 8

    def loss_packed(x):
        p, _ = fused_ata_packed(x, levels=1, bk=bn, bn=bn,
                                out_dtype=jnp.float32, interpret=True)
        return (p * p).sum()

    # dense oracle for the same loss: the packed stack is the block-lower
    # triangle with FULL diagonal tiles
    n = 32
    t = n // bn
    mask = np.zeros((n, n), np.float32)
    for i in range(t):
        mask[i * bn:(i + 1) * bn, :(i + 1) * bn] = 1.0

    def loss_dense(x):
        xf = x.astype(jnp.float32)
        c = jnp.dot(xf.T, xf, preferred_element_type=jnp.float32) * mask
        return (c * c).sum()

    gp = jax.grad(loss_packed)(a)
    gd = jax.grad(loss_dense)(a)
    assert gp.dtype == a.dtype
    assert _rel(gp, gd) < tol


def test_packed_grad_traces_no_dense_cotangent():
    """The packed VJP must not build any dense (n, n) buffer beyond dA
    itself: the cotangent flows packed-stack -> symm kernel -> dA.  The
    dense-dot baseline, by contrast, scatters/unpacks/symmetrizes at n^2
    repeatedly.  (Asserted on the jaxpr — an HLO census of the interpret
    lowering would measure the Pallas emulation, not the kernel.)"""
    n, bn = 256, 32
    a = jnp.ones((n, n), jnp.float32)

    def make_loss(bwd):
        def loss(x):
            p, _ = fused_ata_packed(x, levels=1, bk=bn, bn=bn,
                                    interpret=True, bwd=bwd)
            return (p * p).sum()
        return loss

    def dense_outputs(bwd):
        jaxpr = jax.make_jaxpr(jax.grad(make_loss(bwd)))(a)
        return sum(1 for eqn in jaxpr.jaxpr.eqns for v in eqn.outvars
                   if getattr(v.aval, "shape", None) == (n, n))

    assert dense_outputs("fused") <= 1        # dA, nothing else
    assert dense_outputs("dense") >= 4        # unpack + S + S^t + dot ...


# ---------------------------------------------------------------------------
# Streamed entry point: gram.stream updates differentiate through the
# fused packed kernel (stack -> packed-vector gather keeps it dense-free).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fused", "reference"])
def test_stream_update_differentiable(mode):
    from repro import gram

    n = 32
    a = jax.random.normal(jax.random.PRNGKey(8), (40, n), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(9), (n * (n + 1) // 2,),
                          jnp.float32)

    def loss(x):
        st = gram.stream_init(n)
        st = gram.stream_update(st, x, levels=1, leaf=8, mode=mode,
                                block=8, interpret=True)
        return jnp.vdot(w, st.packed)

    g = jax.grad(loss)(a)
    # oracle: vdot(w, pack_tril(tril(x^t x)))
    wd = np.zeros((n, n), np.float32)
    wd[np.tril_indices(n)] = np.asarray(w)
    g_oracle = jax.grad(
        lambda x: jnp.vdot(jnp.asarray(wd), jnp.tril(x.T @ x)))(a)
    assert _rel(g, g_oracle) < 1e-4


# ---------------------------------------------------------------------------
# Matmul VJP through the schedule executor (transposes folded into the
# index maps — no a^t / b^t copies).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("mkn,block,levels", [
    ((64, 64, 64), 16, 1), ((257, 64, 511), 128, 1),
    ((33, 17, 9), 8, 2), ((24, 40, 32), 8, 0),
])
def test_fused_matmul_grads_match_reference(dtype, tol, mkn, block, levels):
    m, k, n = mkn
    a = jax.random.normal(jax.random.PRNGKey(10), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(11), (k, n)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(12), (m, n), jnp.float32)

    def loss(x, y, mode):
        c = strassen_matmul(x, y, levels=levels, leaf=16, mode=mode,
                            block=block, interpret=True,
                            out_dtype=jnp.float32)
        return jnp.vdot(w, c)

    gaf, gbf = jax.grad(lambda x, y: loss(x, y, "fused"), (0, 1))(a, b)
    gar, gbr = jax.grad(lambda x, y: loss(x, y, "reference"), (0, 1))(a, b)
    assert gaf.dtype == a.dtype and gbf.dtype == b.dtype
    assert _rel(gaf, gar) < tol
    assert _rel(gbf, gbr) < tol


def test_fused_ata_grad_diagonal_factor():
    """The VJP's S + S^t doubles the tril cotangent's diagonal — exactly
    the quadratic form's derivative; pin it against the dense oracle
    d/dA vdot(W, tril(A^tA)) computed by autodiff of the jnp expression."""
    a = jax.random.normal(jax.random.PRNGKey(5), (24, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 16), jnp.float32)

    g_fused = jax.grad(lambda x: jnp.vdot(w, ata(
        x, levels=1, leaf=8, mode="fused", block=8, interpret=True,
        out_dtype=jnp.float32)))(a)
    g_oracle = jax.grad(lambda x: jnp.vdot(w, jnp.tril(x.T @ x)))(a)
    assert _rel(g_fused, g_oracle) < 1e-4


# ---------------------------------------------------------------------------
# Acceptance: 512x512 fp32 grad parity <= 1e-5 for the dense, packed and
# streamed entry points; backward HBM model >= 2x under the dense baseline
# at 4096^2 with no dense n^2 cotangent buffer.
# ---------------------------------------------------------------------------

def test_acceptance_512_grad_parity_all_entry_points():
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(20), (n, n), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(21), (n, n), jnp.float32)

    # dense entry
    g_fused = jax.grad(lambda x: jnp.vdot(w, ata(
        x, levels=2, mode="fused", block=128, interpret=True)))(a)
    g_ref = jax.grad(lambda x: jnp.vdot(w, ata(
        x, levels=2, leaf=64, mode="reference")))(a)
    assert _rel(g_fused, g_ref) < 1e-5

    # packed entry: same cotangent expressed on the packed stack
    wp = pack_tril_blocks(jnp.tril(w), 128)

    def loss_packed(x):
        p, _ = fused_ata_packed(x, levels=2, bk=128, bn=128,
                                interpret=True)
        return jnp.vdot(wp, p)

    g_packed = jax.grad(loss_packed)(a)
    assert _rel(g_packed, g_ref) < 1e-5

    # streamed entry
    from repro import gram
    wv = jnp.asarray(np.asarray(w)[np.tril_indices(n)])

    def loss_stream(x):
        st = gram.stream_init(n)
        st = gram.stream_update(st, x, levels=2, leaf=64, mode="fused",
                                block=128, interpret=True)
        return jnp.vdot(wv, st.packed)

    g_stream = jax.grad(loss_stream)(a)
    wd = np.zeros((n, n), np.float32)
    wd[np.tril_indices(n)] = np.asarray(wv)
    g_stream_ref = jax.grad(lambda x: jnp.vdot(
        jnp.asarray(wd), ata(x, levels=2, leaf=64, mode="reference")))(a)
    assert _rel(g_stream, g_stream_ref) < 1e-5


def test_acceptance_bwd_traffic_4096():
    """The backward of a 4096^2 Gram: the fused symm kernel moves >= 2x
    less HBM-materialized intermediate than the dense-dot baseline, and
    the packed path has NO dense n^2 cotangent buffer at all."""
    model = ata_bwd_traffic_model(4096, 4096, levels=2, bk=256, bn=256,
                                  cotangent="dense")
    fused_b = model["intermediate_bytes"]
    dense_b = model["dense_baseline"]["intermediate_bytes"]
    assert dense_b >= 2 * fused_b > 0, (dense_b, fused_b)
    # the only fused temporary is the packed stack — strictly below one
    # dense square
    assert fused_b <= model["packed_stack_bytes"] < 4096 * 4096 * 4
    # packed-cotangent entry: zero intermediates (shape is tile-aligned)
    packed = ata_bwd_traffic_model(4096, 4096, levels=2, bk=256, bn=256,
                                   cotangent="packed")
    assert packed["intermediate_bytes"] == 0
    assert packed["intermediate_ratio_dense_over_fused"] is None
    # the model is a real model: write term is exactly dA, grid covers
    # the padded contribution sweep
    assert model["write_bytes"] == 4096 * 4096 * 4
    from repro.core.schedule import plan_symm as _ps
    plan = _ps(model["levels"], "strassen")
    T = 4096 // 256
    q = T // plan.blocks
    grid = (4096 // 256) * T * plan.max_contributions * q
    assert model["grid_steps"] == grid
