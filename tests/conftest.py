"""Suite-wide fixtures.

* ``_isolated_autotune_cache`` — point the gram autotune cache at a
  per-session tmp file so tests neither read a developer's tuned winners
  under ``artifacts/autotune/`` nor write into the repo.

* ``@pytest.mark.multidevice(n)`` — run the marked test in a CHILD pytest
  process with ``XLA_FLAGS=--xla_force_host_platform_device_count=n``.
  The main pytest process must keep the default 1-device CPU platform
  (XLA_FLAGS is consumed at first jax init and must not be set globally),
  so multi-device tests re-execute their own node id in a subprocess: the
  parent replaces the test body with the subprocess launch, and inside
  the child (marked by ``REPRO_MULTIDEVICE_CHILD``) the body runs
  normally against the forced n-device platform.  Write the test as an
  ordinary pytest function — asserts, parametrize and fixtures all work;
  just keep per-test work small, each marked test pays one interpreter
  start.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_CHILD_ENV = "REPRO_MULTIDEVICE_CHILD"


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path_factory, monkeypatch):
    path = tmp_path_factory.getbasetemp() / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))


@pytest.fixture(autouse=True)
def _reset_obs():
    """Each test starts with a fresh (disabled) tracer; a test that
    enabled tracing cannot leak events into the next one."""
    yield
    from repro.obs import trace
    trace.set_tracer(None)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice(n=8, timeout=600): re-run this test in a child pytest "
        "with XLA_FLAGS=--xla_force_host_platform_device_count=n (the main "
        "process keeps the default 1-device platform)")


def _multidevice_runner(nodeid: str, n: int, timeout: float):
    def run(**_fixtures):
        env = dict(os.environ)
        env[_CHILD_ENV] = str(n)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        env["PYTHONPATH"] = str(REPO / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        out = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q", "--no-header",
             "-p", "no:cacheprovider", nodeid],
            env=env, cwd=REPO, capture_output=True, text=True,
            timeout=timeout)
        if out.returncode != 0:
            pytest.fail(
                f"multidevice({n}) child failed for {nodeid}\n"
                f"--- stdout ---\n{out.stdout}\n--- stderr ---\n{out.stderr}",
                pytrace=False)
    return run


def pytest_collection_modifyitems(config, items):
    if os.environ.get(_CHILD_ENV):
        return                      # child: run the real test bodies
    for item in items:
        mark = item.get_closest_marker("multidevice")
        if mark is None:
            continue
        n = mark.args[0] if mark.args else mark.kwargs.get("n", 8)
        timeout = mark.kwargs.get("timeout", 600)
        item.obj = _multidevice_runner(item.nodeid, int(n), timeout)


@pytest.fixture
def multidevice_count(request):
    """Device count the surrounding ``multidevice`` mark asked for (child
    side); asserts the forced platform actually materialized."""
    mark = request.node.get_closest_marker("multidevice")
    n = int(mark.args[0] if mark and mark.args
            else (mark.kwargs.get("n", 8) if mark else 1))
    if os.environ.get(_CHILD_ENV):
        import jax
        assert len(jax.devices()) >= n, \
            f"expected >= {n} devices, got {jax.devices()}"
    return n
