"""Suite-wide isolation: point the gram autotune cache at a per-session
tmp file so tests neither read a developer's tuned winners under
``artifacts/autotune/`` nor write into the repo."""
import pytest


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path_factory, monkeypatch):
    path = tmp_path_factory.getbasetemp() / "gram_autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
