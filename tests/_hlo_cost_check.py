"""Child: validate the trip-count-aware HLO analyzer against a known scan
program on an 8-device host platform."""
import os

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.roofline.hlo_cost import analyze_hlo  # noqa: E402


def main():
    L, B, D = 48, 64, 128

    def f(xs, w):
        def body(c, _):
            c = jnp.tanh(c @ w)
            return c, ()
        c, _ = jax.lax.scan(body, xs, None, length=L)
        return jnp.sum(c)

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    c = jax.jit(f, in_shardings=(sh, None),
                out_shardings=NamedSharding(mesh, P())).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())

    dot_flops = L * 2 * (B // 8) * D * D           # per-device
    assert 0.95 * dot_flops < r["flops"] < 1.3 * dot_flops, (
        r["flops"], dot_flops)
    xla_cost = c.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):   # jax 0.4.x returns [dict]
        xla_cost = xla_cost[0]
    xla_flops = xla_cost["flops"]
    assert xla_flops < dot_flops / 10, "xla undercounts (expected)"
    # bytes: per iteration ~ w (D*D*4) + 3x carry; x L
    per_iter = D * D * 4 + 3 * (B // 8) * D * 4
    assert r["bytes"] > 0.8 * L * per_iter * 0.5, (r["bytes"],
                                                   L * per_iter)
    assert r["unknown_trip_loops"] == 0
    # collective: the final psum of a scalar
    assert r["collectives"]["by_kind"].get("all-reduce", {}).get("count", 0) \
        >= 1
    print("ALL_OK")


if __name__ == "__main__":
    main()
