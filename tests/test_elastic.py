"""Elastic restart: checkpoint written on an 8-device (4,2) mesh restores
onto a 4-device (2,2) mesh (reshard-on-load) with identical model output.
Two subprocesses — jax locks the device count per process."""
import os
import pathlib
import subprocess
import sys

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def _run(script, workdir):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(HERE / script), str(workdir)],
        env=env, capture_output=True, text=True, timeout=900)


def test_elastic_reshard_across_device_counts(tmp_path):
    out = _run("_elastic_save.py", tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "SAVE_OK" in out.stdout
    out = _run("_elastic_restore.py", tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "RESTORE_OK" in out.stdout
