"""Elastic restart: checkpoint written on an 8-device (4,2) mesh restores
onto a 4-device (2,2) mesh (reshard-on-load) with identical model output.

Runs on the conftest ``@pytest.mark.multidevice`` harness — jax locks the
device count per process, so the two halves are two marked tests with
different forced device counts, sharing a workdir through an env var the
parent process pins at collection time (children inherit it, so both
child pytests see the same directory).
"""
import os
import pathlib

import numpy as np
import pytest

_WORKDIR_ENV = "REPRO_ELASTIC_WORKDIR"


@pytest.fixture(scope="module")
def elastic_workdir(tmp_path_factory):
    """The workdir shared by the save/restore pair.

    In the parent process this allocates a pytest-managed tmp dir (so it
    is cleaned up by tmp-path retention, not leaked) and pins it in the
    environment; the multidevice children inherit the env var and reuse
    the same directory, so the 4-device restore sees the 8-device save's
    checkpoint."""
    if _WORKDIR_ENV in os.environ:          # multidevice child: reuse
        return pathlib.Path(os.environ[_WORKDIR_ENV])
    path = tmp_path_factory.mktemp("elastic")
    os.environ[_WORKDIR_ENV] = str(path)
    return path


def _reduced_cfg():
    from repro.configs.registry import reduced_arch
    return reduced_arch("yi-9b", num_layers=2, d_model=128, num_heads=4,
                        num_kv_heads=4, d_ff=256, vocab_size=512,
                        head_dim=32)


@pytest.mark.multidevice(8)
def test_elastic_save_on_8_devices(multidevice_count, elastic_workdir):
    """Train 3 steps on an 8-device (4,2) mesh, checkpoint, dump a logit
    fingerprint for the restore half."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, get_batch
    from repro.models import init_params, forward, loss_fn
    from repro.optim import adamw, apply_updates
    from repro.checkpoint.manager import CheckpointManager
    from repro.parallel.sharding import param_specs, to_named
    from repro.launch.mesh import make_mesh

    assert len(jax.devices()) >= 8
    cfg = _reduced_cfg()
    mesh = make_mesh((4, 2), ("data", "model"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    pshard = to_named(param_specs(params, mesh), mesh)
    params = jax.device_put(params, pshard)
    opt = adamw(1e-3)
    state = {"step": jnp.zeros((), jnp.int32), "params": params,
             "opt_state": opt.init(params)}
    dc = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3)

    @jax.jit
    def step(state, batch):
        (_, m), g = jax.value_and_grad(
            lambda p, b: loss_fn(cfg, p, b), has_aux=True)(
            state["params"], batch)
        u, os_, _ = opt.update(g, state["opt_state"], state["params"],
                               state["step"])
        return {"step": state["step"] + 1,
                "params": apply_updates(state["params"], u),
                "opt_state": os_}

    for i in range(3):
        state = step(state, get_batch(dc, i))
    elastic_workdir.mkdir(parents=True, exist_ok=True)
    mgr = CheckpointManager(str(elastic_workdir), async_save=False)
    mgr.save(3, state)

    logits = forward(cfg, state["params"],
                     jnp.asarray(get_batch(dc, 99)["inputs"]),
                     mode="train")[0]
    np.save(elastic_workdir / "fingerprint.npy",
            np.asarray(logits, np.float32))
    assert (elastic_workdir / "fingerprint.npy").exists()


@pytest.mark.multidevice(4)
def test_elastic_restore_on_4_devices(multidevice_count, elastic_workdir):
    """Restore the 8-device checkpoint on HALF the devices (2,2 mesh)
    with resharding-on-load; logits must match the fingerprint."""
    if not (elastic_workdir / "fingerprint.npy").exists():
        pytest.skip("save half did not run (run the full elastic pair)")
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, get_batch
    from repro.models import forward
    from repro.checkpoint.manager import CheckpointManager
    from repro.parallel.sharding import param_specs, to_named
    from repro.launch.mesh import make_mesh

    assert len(jax.devices()) >= 4
    cfg = _reduced_cfg()
    mesh = make_mesh((2, 2), ("data", "model"))     # HALF the devices
    mgr = CheckpointManager(str(elastic_workdir))
    raw, meta = mgr.restore()
    assert meta["step"] == 3
    # reshard-on-load: place the host arrays with the NEW mesh's shardings
    pshard = to_named(param_specs(raw["params"], mesh), mesh)
    params = jax.device_put(raw["params"], pshard)
    dc = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3)
    logits = forward(cfg, params,
                     jnp.asarray(get_batch(dc, 99)["inputs"]),
                     mode="train")[0]
    want = np.load(elastic_workdir / "fingerprint.npy")
    got = np.asarray(logits, np.float32)
    err = np.abs(got - want).max()
    # bf16 matmul partial sums regroup on a different topology: tolerance
    # is bf16 noise, NOT an exactness bound (the restored *values* are
    # bit-identical; only reduction order differs).
    assert err < 5e-2, f"elastic restore mismatch: {err}"
