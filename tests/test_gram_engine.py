"""GramEngine: correctness over mixed traces + bounded-recompile acceptance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gram import GramEngine, bucket_shape


def _mixed_trace(rng, requests, min_dim=5, max_dim=200):
    shapes = [(int(rng.integers(min_dim, max_dim)),
               int(rng.integers(min_dim, max_dim // 2)))
              for _ in range(requests)]
    return [(s, rng.standard_normal(s).astype(np.float32)) for s in shapes]


def test_engine_serves_mixed_trace_correctly():
    rng = np.random.default_rng(0)
    eng = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16)
    trace = _mixed_trace(rng, 20, max_dim=100)
    uid_to_a = {eng.submit(a).uid: a for _, a in trace}
    finished = eng.run_to_completion()
    assert len(finished) == 20
    for r in finished:
        a = uid_to_a[r.uid].astype(np.float64)
        want = a.T @ a
        err = np.abs(r.result - want).max() / max(np.abs(want).max(), 1.0)
        assert err < 1e-5, (r.uid, r.shape, err)
        np.testing.assert_allclose(r.result, r.result.T, rtol=1e-6)


def test_engine_64_request_trace_bounded_recompiles():
    """Acceptance: a 64-request mixed-shape trace compiles at most once per
    distinct shape bucket."""
    rng = np.random.default_rng(1)
    eng = GramEngine(slots=4, levels=1, leaf=8, min_bucket=16)
    trace = _mixed_trace(rng, 64)
    buckets = {eng._bucket_key(a.shape, a.dtype) for _, a in trace}
    for _, a in trace:
        eng.submit(a)
    finished = eng.run_to_completion()
    assert len(finished) == 64
    assert eng.compile_count <= len(buckets), (
        f"{eng.compile_count} compiles for {len(buckets)} buckets")
    # and the engine really batched: fewer ticks than requests
    assert eng.ticks < 64
    stats = eng.stats()
    assert stats["p50_latency_s"] is not None
    assert stats["p99_latency_s"] >= stats["p50_latency_s"]


def test_engine_partial_batch_padding():
    """Fewer waiting requests than slots: the batch is padded with zero
    matrices and results are still exact (zero rows add nothing)."""
    rng = np.random.default_rng(2)
    eng = GramEngine(slots=8, levels=0, min_bucket=16)
    a = rng.standard_normal((30, 12)).astype(np.float32)
    eng.submit(a)
    (r,) = eng.run_to_completion()
    want = a.astype(np.float64).T @ a.astype(np.float64)
    assert np.abs(r.result - want).max() / np.abs(want).max() < 1e-5
    assert eng.compile_count == 1


def test_engine_tril_only_result():
    rng = np.random.default_rng(3)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    a = rng.standard_normal((20, 10)).astype(np.float32)
    eng.submit(a, full=False)
    (r,) = eng.run_to_completion()
    assert np.abs(np.triu(r.result, 1)).max() == 0.0


def test_engine_fused_interpret_mode():
    """Explicit fused Pallas path (interpret) through the engine batcher."""
    rng = np.random.default_rng(4)
    eng = GramEngine(slots=2, levels=1, mode="fused", block=16,
                     interpret=True, min_bucket=32)
    arrays = [rng.standard_normal((40, 24)).astype(np.float32)
              for _ in range(2)]
    uids = [eng.submit(a).uid for a in arrays]
    finished = {r.uid: r for r in eng.run_to_completion()}
    for uid, a in zip(uids, arrays):
        want = a.astype(np.float64).T @ a.astype(np.float64)
        err = np.abs(finished[uid].result - want).max() / np.abs(want).max()
        assert err < 1e-4
    assert eng.compile_count == 1


def test_engine_same_bucket_rejoins_executable():
    """Requests arriving after the bucket's executable exists reuse it."""
    rng = np.random.default_rng(5)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    for _ in range(3):
        eng.submit(rng.standard_normal((16, 16)).astype(np.float32))
        eng.run_to_completion()
    assert eng.compile_count == 1
    assert eng.served == 3


def test_engine_oldest_head_served_before_longer_queue():
    """No cross-bucket starvation: with no full batch available, the
    bucket whose head request arrived first is served, even when another
    bucket has a longer queue."""
    rng = np.random.default_rng(7)
    eng = GramEngine(slots=4, levels=0, min_bucket=16)
    rare = eng.submit(rng.standard_normal((100, 50)).astype(np.float32)).uid
    for _ in range(3):
        eng.submit(rng.standard_normal((16, 16)).astype(np.float32))
    first_tick = eng.step()
    assert [r.uid for r in first_tick] == [rare]
    # a full batch, though, takes priority over an older partial one
    eng2 = GramEngine(slots=2, levels=0, min_bucket=16)
    old = eng2.submit(rng.standard_normal((100, 50)).astype(np.float32)).uid
    full = [eng2.submit(rng.standard_normal((16, 16)).astype(np.float32)).uid
            for _ in range(2)]
    assert {r.uid for r in eng2.step()} == set(full)
    assert [r.uid for r in eng2.step()] == [old]


def test_bucket_shape_pow2_and_floor():
    assert bucket_shape(100, 60) == (128, 64)
    assert bucket_shape(5, 3) == (32, 32)
    assert bucket_shape(128, 128) == (128, 128)
    assert bucket_shape(129, 1, min_side=16) == (256, 16)


def test_engine_rejects_bad_request():
    eng = GramEngine()
    with pytest.raises(ValueError):
        eng.submit(np.zeros((3, 4, 5), np.float32))
    with pytest.raises(ValueError):
        eng.submit(np.zeros((4, 4), np.float32), gram_of="diag")


def test_engine_serves_row_gram_buckets():
    """gram_of="rows" requests serve tril(a @ a.T) — the aat leaf program
    on the fused path — bucketed separately from same-shape column grams
    and batched the same way."""
    rng = np.random.default_rng(9)
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16)
    a = rng.standard_normal((40, 24)).astype(np.float32)
    u_rows = eng.submit(a, gram_of="rows").uid
    u_cols = eng.submit(a).uid
    done = {r.uid: r for r in eng.run_to_completion()}
    a64 = a.astype(np.float64)
    want_rows, want_cols = a64 @ a64.T, a64.T @ a64
    err_r = np.abs(done[u_rows].result - want_rows).max() \
        / np.abs(want_rows).max()
    err_c = np.abs(done[u_cols].result - want_cols).max() \
        / np.abs(want_cols).max()
    assert done[u_rows].result.shape == (40, 40)
    assert done[u_cols].result.shape == (24, 24)
    assert err_r < 1e-5 and err_c < 1e-5, (err_r, err_c)
    # separate buckets -> separate executables (one compile each)
    assert eng.compile_count == 2
    # lower-tri-only row gram
    eng.submit(a, gram_of="rows", full=False)
    (r,) = eng.run_to_completion()[-1:]
    assert np.abs(np.triu(r.result, 1)).max() == 0.0


@pytest.mark.multidevice(8)
def test_engine_routes_large_buckets_to_mesh(multidevice_count):
    """With a mesh configured, buckets at/above dist_threshold serve
    through distributed_gram (scheme="auto" -> comm cost model) and small
    buckets keep the local slot-batched path; both match the oracle."""
    from repro.launch.mesh import make_gram_mesh

    rng = np.random.default_rng(8)
    mesh = make_gram_mesh(8, rep=2, ring=2)      # (rep=2, data=2, model=2)
    eng = GramEngine(slots=2, levels=1, leaf=8, min_bucket=16,
                     mesh=mesh, dist_threshold=128 * 64)
    big = rng.standard_normal((120, 60)).astype(np.float32)    # -> 128x64
    small = rng.standard_normal((20, 12)).astype(np.float32)   # -> 32x32
    u_big, u_small = eng.submit(big).uid, eng.submit(small).uid
    done = {r.uid: r for r in eng.run_to_completion()}
    assert len(done) == 2
    for uid, a in ((u_big, big), (u_small, small)):
        want = a.astype(np.float64).T @ a.astype(np.float64)
        err = np.abs(done[uid].result - want).max() / np.abs(want).max()
        assert err < 1e-4, (uid, err)
        np.testing.assert_allclose(done[uid].result, done[uid].result.T,
                                   rtol=1e-5)
    stats = eng.stats()
    assert stats["dist_served"] == 1
    assert stats["distributed_buckets"] == [(128, 64, "float32", "cols",
                                          "native")]
    # the small bucket stayed on the local vmapped path
    assert (32, 16, "float32", "cols", "native") in stats["buckets"]
    assert (32, 16, "float32", "cols",
            "native") not in stats["distributed_buckets"]


def test_engine_infeasible_dist_scheme_stays_local():
    """A pinned (non-"auto") dist_scheme that does not fit a bucket's
    shape keeps that bucket on the local path instead of compiling a
    shard_map program that would fail mid-step (routing logic only — no
    multi-device platform needed)."""
    from types import SimpleNamespace as NS
    mesh = NS(shape={"data": 2, "model": 3}, axis_names=("data", "model"))
    # bucket N=64 is not divisible by the 3-wide ring axis: ring infeasible
    eng = GramEngine(mesh=mesh, dist_scheme="ring", dist_threshold=1,
                     min_bucket=16)
    assert not eng._is_distributed((64, 64, "float32", "cols"))
    # "auto" falls back to the feasible row-reduction schemes
    eng_auto = GramEngine(mesh=mesh, dist_scheme="auto", dist_threshold=1,
                          min_bucket=16)
    assert eng_auto._is_distributed((64, 64, "float32", "cols"))


def test_engine_no_mesh_never_distributes():
    """Default engine (mesh=None) keeps every bucket local."""
    rng = np.random.default_rng(9)
    eng = GramEngine(slots=2, levels=0, min_bucket=16, dist_threshold=1)
    eng.submit(rng.standard_normal((64, 32)).astype(np.float32))
    eng.run_to_completion()
    assert eng.stats()["dist_served"] == 0
    assert eng.stats()["distributed_buckets"] == []


def test_engine_bf16_requests_bucket_separately():
    """dtype is part of the bucket key: same shape, different dtype ->
    two executables, both correct."""
    rng = np.random.default_rng(6)
    eng = GramEngine(slots=2, levels=0, min_bucket=16)
    a32 = rng.standard_normal((24, 16)).astype(np.float32)
    a16 = jnp.asarray(a32).astype(jnp.bfloat16)
    u32 = eng.submit(a32).uid
    u16 = eng.submit(np.asarray(a16)).uid
    done = {r.uid: r for r in eng.run_to_completion()}
    assert eng.compile_count == 2
    want = a32.astype(np.float64).T @ a32.astype(np.float64)
    assert np.abs(done[u32].result - want).max() / np.abs(want).max() < 1e-5
    # bf16 inputs, fp32 accumulation/output
    assert done[u16].result.dtype == np.float32
    assert np.abs(done[u16].result.astype(np.float64)
                  - want).max() / np.abs(want).max() < 5e-2
