"""Production meshes. Functions only — importing this module never touches
jax device state (device count is locked on first jax init)."""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # 0.4.x: meshes are Auto by default
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis
    crosses DCI — keep only DP-style (per-step, overlappable) collectives
    on it."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
