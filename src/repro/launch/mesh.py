"""Production meshes. Functions only — importing this module never touches
jax device state (device count is locked on first jax init)."""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # 0.4.x: meshes are Auto by default
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis
    crosses DCI — keep only DP-style (per-step, overlappable) collectives
    on it."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_gram_mesh(n_devices=None, *, rep: int = 1, ring=None,
                   devices=None):
    """(rep, data, model) mesh for ``core.distributed.distributed_gram``
    (axis names match ``default_gram_axes``): ``rep`` is the 2.5D
    replication factor (bfs25d), ``ring`` the half-ring/column axis size
    (default: every non-replication device), rows take the rest.  Accepts
    a device subset so odd factors (rep=3, ring=3, ...) work on an
    8-device host platform."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        devs = devs[:n_devices]
    total = len(devs)
    if total % rep:
        raise ValueError(f"{total} devices not divisible by rep={rep}")
    inner = total // rep
    T = inner if ring is None else ring
    if T < 1 or inner % T:
        raise ValueError(f"{inner} devices per group not divisible by "
                         f"ring={T}")
    rows = inner // T
    grid = np.array(devs[:rep * rows * T]).reshape(rep, rows, T)
    return Mesh(grid, ("rep", "data", "model"))
