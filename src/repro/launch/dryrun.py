import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process;
# tests/benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: sharding propagation succeeds, the collective schedule exists,
and ``memory_analysis()`` shows the per-device footprint. Artifacts
(memory, cost_analysis, collective census) land in artifacts/dryrun/ for
the roofline analysis (benchmarks/bench_roofline.py, EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
  python -m repro.launch.dryrun --gram gram_64k            # paper's own op
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, SHAPES
from ..configs.registry import (ARCHS, get_arch, get_shape, input_specs,
                                cell_runnable, all_cells)
from ..configs.paper_ata import GRAM_CELLS
from ..models import init_params, init_cache
from ..models.model import forward, decode_step
from ..optim import adamw
from ..parallel.act import (ActivationSharding, use_activation_sharding,
                            _fit_spec)
from ..parallel.sharding import param_specs, cache_specs, to_named
from ..roofline.hlo_census import collective_census, summarize
from ..roofline.hlo_cost import analyze_hlo
from ..runtime.trainer import make_train_step
from .mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _mem_dict(mem) -> dict:
    return {k: getattr(mem, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}


def flash_kernel_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic GLOBAL FLOPs of the substituted flash-attention kernels
    (the stub carries their HBM interface; FLOPs are added here).
    Causal halves the score work (block skipping); sliding windows cap it;
    train multiplies by 3 for the backward kernel (dq, dk, dv passes)."""
    if cfg.family == "ssm":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    hq = cfg.num_heads
    d = dv = cfg.head_dim_
    if cfg.mla is not None:
        d = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        dv = cfg.mla.v_head_dim

    def att(layers, sq, skv, causal=True, window=None):
        eff = skv / 2 if causal else skv
        if window and window < skv:
            eff = min(eff, window)
        return 2.0 * b * hq * sq * eff * (d + dv) * layers

    total = 0.0
    if cfg.family == "audio":
        total += att(cfg.encoder_layers, cfg.encoder_seq, cfg.encoder_seq,
                     causal=False)
        total += att(cfg.num_layers, s, s)
        total += att(cfg.num_layers, s, cfg.encoder_seq, causal=False)
    elif cfg.family == "hybrid":
        total += att(cfg.num_layers // max(cfg.hybrid_attn_every, 1), s, s)
    elif cfg.alt_local_global and cfg.sliding_window:
        total += att(cfg.num_layers // 2, s, s)
        total += att(cfg.num_layers - cfg.num_layers // 2, s, s,
                     window=cfg.sliding_window)
    else:
        total += att(cfg.num_layers, s, s)
    if shape.kind == "train":
        total *= 3.0
    return total


def _spec_leaf(s):
    return isinstance(s, P)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               fsdp_axes=("data",), sp=True):
    """Returns (fn_to_jit, abstract_args, in_shardings, out_shardings,
    donate, policy)."""
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = jax.eval_shape(lambda k: init_params(cfg, k), key_s)
    pspecs = param_specs(params_s, mesh, fsdp_axes=fsdp_axes,
                         moe_stationary=shape.kind == "decode")
    pshard = to_named(pspecs, mesh)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    specs = input_specs(cfg, shape)

    def nsh(spec: P, shape_):
        """Divisibility-checked NamedSharding (falls back per-dim)."""
        return NamedSharding(mesh, _fit_spec(spec, shape_, mesh))

    def batch_shard(sp):
        return {k: nsh(P(dp, *([None] * (len(v.shape) - 1))), v.shape)
                for k, v in sp.items()}

    if shape.kind == "train":
        # >100B params: bf16 Adam moments (2+2+2+2 B/param with grads) —
        # fp32 moments for 480B/671B cannot fit a v5e pod's aggregate HBM
        # no matter how they are sharded. Recorded in DESIGN.md §memory.
        moment_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 \
            else jnp.float32
        opt = adamw(1e-4, moment_dtype=moment_dtype)
        opt_s = jax.eval_shape(opt.init, params_s)
        # Adam moments mirror the param tree: reuse its specs exactly
        # (ZeRO-1: optimizer state sharded with the FSDP axes for free).
        oshard = {"m": pshard, "v": pshard}
        state_s = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                   "params": params_s, "opt_state": opt_s}
        state_sh = {"step": NamedSharding(mesh, P()), "params": pshard,
                    "opt_state": oshard}
        bshard = batch_shard(specs)
        fn = make_train_step(cfg, opt)
        policy = ActivationSharding.for_training(mesh, sp=sp)
        return (fn, (state_s, specs), (state_sh, bshard),
                (state_sh, None), (0,), policy)

    if shape.kind == "prefill":
        cache_s = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cshard = to_named(cache_specs(cache_s, mesh), mesh)
        bshard = batch_shard(specs)

        def fn(params, inputs, cache):
            logits, cache = forward(cfg, params, inputs["tokens"],
                                    enc_inputs=inputs.get("enc_inputs"),
                                    cache=cache, mode="prefill")
            return logits[:, -1], cache

        policy = ActivationSharding.for_training(mesh, sp=sp)
        lsh = nsh(P(dp, "model"), (shape.global_batch, cfg.vocab_size))
        return (fn, (params_s, specs, cache_s), (pshard, bshard, cshard),
                (lsh, cshard), (2,), policy)

    # decode
    cache_s = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cshard = to_named(cache_specs(cache_s, mesh), mesh)
    tok_sh = {"tokens": nsh(P(dp, None), (shape.global_batch, 1))}

    def fn(params, inputs, cache):
        return decode_step(cfg, params, inputs["tokens"], cache)

    policy = ActivationSharding.for_decode(mesh, fsdp_axes=fsdp_axes)
    lsh = nsh(P(dp, "model"), (shape.global_batch, cfg.vocab_size))
    return (fn, (params_s, specs, cache_s), (pshard, tok_sh, cshard),
            (lsh, cshard), (2,), policy)


def run_cell(arch: str, shape_name: str, *, multi_pod=False,
             fsdp_axes=None, sp=True, out_dir=ARTIFACT_DIR,
             skip_existing=False, tag="", overrides=None) -> dict:
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}{tag}"
    path = os.path.join(out_dir, cell + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip] {cell}")
        with open(path) as f:
            return json.load(f)
    if not cell_runnable(cfg, shape):
        print(f"[n/a ] {cell} (long_500k needs sub-quadratic attention)")
        return {"cell": cell, "status": "skipped_quadratic"}

    if fsdp_axes is None:
        # giant models: shard params/opt over every DP axis (ZeRO across
        # pods) — required for the 400B+ archs to fit; costs cross-pod
        # gathers, recorded honestly in the census.
        big = cfg.param_count() > 3e10
        fsdp_axes = ("pod", "data") if (multi_pod and big) else ("data",)

    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args_s, in_sh, out_sh, donate, policy = build_cell(
        cfg, shape, mesh, fsdp_axes=fsdp_axes, sp=sp)

    t0 = time.perf_counter()
    with use_activation_sharding(policy):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args_s)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(f"[ok  ] {cell}: lower {t_lower:.1f}s compile {t_compile:.1f}s  "
          f"args {mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp {mem.temp_size_in_bytes/2**30:.2f}GiB")
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    census = summarize(collective_census(hlo_text))
    census_ops = census.pop("ops")
    # trip-count-aware analysis (XLA's cost_analysis counts scan bodies once)
    corrected = analyze_hlo(hlo_text)

    artifact = {
        "cell": cell, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "mesh_shape": list(mesh.devices.shape),
        "axes": list(mesh.axis_names), "kind": shape.kind,
        "fsdp_axes": list(fsdp_axes), "sp": sp, "status": "ok",
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": _mem_dict(mem),
        "cost": {k: v for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "cost_corrected": {"flops": corrected["flops"],
                           "bytes": corrected["bytes"],
                           "unknown_trip_loops":
                               corrected["unknown_trip_loops"]},
        "collectives": census,
        "collectives_corrected": corrected["collectives"],
        "collective_op_count": len(census_ops),
    }
    if cfg.attn_impl == "stub":
        artifact["kernel_substitution"] = {
            "kernel": "kernels/flash_attention.py",
            "flops_global": flash_kernel_flops(cfg, shape),
            "note": "HBM interface traffic carried by the stub; FLOPs "
                    "added analytically by roofline/analysis.py",
        }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact


def run_gram_cell(name: str, *, multi_pod=False, out_dir=ARTIFACT_DIR,
                  skip_existing=False) -> dict:
    """Dry-run the paper's own operation: distributed C = A^t A."""
    from ..core.distributed import distributed_gram
    gc = GRAM_CELLS[name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"gram__{name}__{mesh_name}"
    path = os.path.join(out_dir, cell + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=multi_pod)
    row_axis = ("pod", "data") if multi_pod else "data"
    col_axis = "model" if gc.scheme == "ring" else None
    in_spec = P(row_axis, col_axis) if gc.scheme == "ring" \
        else P(row_axis, None)

    def fn(a):
        # production path: ring keeps the sharded circulant block layout
        return distributed_gram(a, mesh, scheme=gc.scheme,
                                row_axis=row_axis, col_axis=col_axis,
                                levels=gc.levels, assemble=False)

    a_s = jax.ShapeDtypeStruct((gc.m, gc.n), jnp.dtype(gc.dtype))
    t0 = time.perf_counter()
    lowered = jax.jit(fn, in_shardings=NamedSharding(mesh, in_spec)).lower(a_s)
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    census = summarize(collective_census(compiled.as_text()))
    census.pop("ops")
    artifact = {
        "cell": cell, "arch": f"gram:{gc.scheme}", "shape": name,
        "mesh": mesh_name, "kind": "gram", "status": "ok",
        "m": gc.m, "n": gc.n, "scheme": gc.scheme, "levels": gc.levels,
        "compile_s": t_compile, "memory": _mem_dict(mem),
        "cost": {k: v for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": census,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"[ok  ] {cell}: compile {t_compile:.1f}s")
    return artifact


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--gram", choices=sorted(GRAM_CELLS), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--all-gram", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--flash-sub", action="store_true",
                    help="flash-kernel substitution variant (attention at "
                         "kernel-interface traffic; tag __flash): the "
                         "optimized roofline table")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()
    overrides = {"attn_impl": "stub"} if args.flash_sub else None
    tag = "__flash" if args.flash_sub else ""

    failures = []
    def _try(fn, *a, **kw):
        try:
            fn(*a, **kw)
        except Exception:
            failures.append((a, kw))
            traceback.print_exc()

    if args.gram:
        run_gram_cell(args.gram, multi_pod=args.multi_pod, out_dir=args.out,
                      skip_existing=args.skip_existing)
    elif args.all_gram:
        for name in GRAM_CELLS:
            for mp in (False, True):
                _try(run_gram_cell, name, multi_pod=mp, out_dir=args.out,
                     skip_existing=args.skip_existing)
    elif args.all:
        for arch, shape in all_cells():
            if args.flash_sub and get_shape(shape).kind == "decode":
                continue          # decode never materializes scores anyway
            _try(run_cell, arch, shape, multi_pod=args.multi_pod,
                 sp=not args.no_sp, out_dir=args.out,
                 skip_existing=args.skip_existing, tag=tag,
                 overrides=overrides)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 sp=not args.no_sp, out_dir=args.out,
                 skip_existing=args.skip_existing, tag=tag,
                 overrides=overrides)
    if failures:
        print(f"{len(failures)} FAILED cells")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
