"""Serving driver: batched KV-cache engine over a reduced-config model.

``python -m repro.launch.serve --arch qwen2.5-3b --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.registry import ARCHS, reduced_arch
from ..models import init_params
from ..runtime.serving import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: requests still waiting "
                         "past it fail fast (status='deadline')")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests round-robin over N synthetic "
                         "tenants (SLO accounting rides the requests)")
    ap.add_argument("--priority-every", type=int, default=0, metavar="K",
                    help="mark every K-th request priority=1 (admitted "
                         "ahead of the FIFO order); 0 disables")
    args = ap.parse_args(argv)

    cfg = reduced_arch(args.arch)
    params = jax.jit(lambda k: init_params(cfg, k))(
        jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=args.max_seq,
                        temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    deadline = None if args.deadline_ms is None else args.deadline_ms / 1e3
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        eng.add_request(prompt, max_new_tokens=args.max_new,
                        deadline_s=deadline,
                        tenant=f"t{i % max(args.tenants, 1)}",
                        priority=1 if (args.priority_every
                                       and i % args.priority_every == 0)
                        else 0)
    t0 = time.perf_counter()
    finished = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in finished)
    expired = sum(1 for r in finished if r.status == "deadline")
    print(f"served {len(finished)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s)"
          + (f", {expired} expired on deadline" if expired else ""))
    for r in finished[:4]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")
    return finished


if __name__ == "__main__":
    main()
