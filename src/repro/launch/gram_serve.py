"""Gram service driver: batched multi-tenant A^tA over a mixed-size trace.

    PYTHONPATH=src python -m repro.launch.gram_serve --requests 64 --slots 4

Generates a heterogeneous request trace (log-uniform shapes), optionally
pre-autotunes each bucket, serves it through ``gram.GramEngine`` and
prints throughput, latency percentiles and the recompile count.

Robustness drills ride the same driver: ``--faults`` arms a
``runtime.faults`` profile (or set ``REPRO_FAULTS`` in the environment),
``--verify`` picks the output-guard level, and the retry/deadline knobs
map straight onto the engine's degradation ladder — e.g.

    ... --faults "poison_output:rate=0.1;exec_fail:rate=0.05" --verify 2

The overload model rides it as well (DESIGN.md §15): ``--async`` serves
through the background scheduler (``submit`` returns futures; the driver
drains them), ``--tenants N`` spreads the trace round-robin over N
synthetic tenants, and the admission knobs (``--max-queue``,
``--admission shed|block``, ``--tenant-quota``, ``--tenant-weights``)
bound the queues — shed requests fail fast with ``Overloaded`` and are
reported separately from served/failed.

The flight recorder rides along too (DESIGN.md §14): ``--trace-out``
enables request-scoped tracing and writes the Chrome trace-event JSON
(open it in Perfetto — every request's submit -> queue-wait -> execute ->
verify -> done chain, with fault firings, guard vetoes and rung
transitions as instants on the same timeline; a ``.jsonl`` sidecar holds
the grep-friendly form), ``--metrics-out`` writes the Prometheus-style
registry snapshot, and ``--drift-theta`` sets the cost-model drift band
(findings print at exit and land in ``stats()["drift"]``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from ..gram import GramEngine, autotune_bucket, bucket_shape
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime import faults


def make_trace(rng, requests: int, min_dim: int, max_dim: int):
    """Log-uniform (m, n) request shapes — small Grams dominate, a few
    big ones stress the bucketing, like real mixed tenant traffic."""
    lo, hi = np.log2(min_dim), np.log2(max_dim)
    shapes = []
    for _ in range(requests):
        m = int(round(2 ** rng.uniform(lo, hi)))
        n = int(round(2 ** rng.uniform(lo, hi)))
        shapes.append((m, n))
    return shapes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--levels", default="1")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "fused", "reference"))
    ap.add_argument("--min-dim", type=int, default=16)
    ap.add_argument("--max-dim", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=32)
    ap.add_argument("--autotune", action="store_true",
                    help="pre-autotune every bucket in the trace "
                         "(measured, persists winners)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default=None, metavar="PROFILE",
                    help="fault-injection profile, e.g. "
                         "'poison_output:rate=0.1;exec_fail:rate=0.05' "
                         "(see repro.runtime.faults)")
    ap.add_argument("--verify", default="finite",
                    help="output guards: 'off', 'finite' (NaN/Inf + "
                         "diagonal scan, default) or an int K (finite "
                         "scan + K Freivalds probes per result)")
    ap.add_argument("--retries", type=int, default=3,
                    help="max executable retries per batch before the "
                         "batch is failed")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (requests past it fail "
                         "fast instead of retrying)")
    ap.add_argument("--backoff-ms", type=float, default=0.0,
                    help="base retry backoff (doubles per attempt)")
    ap.add_argument("--max-backoff-ms", type=float, default=5000.0,
                    help="hard cap on one retry backoff sleep — bounds "
                         "deadline-less requests too")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="serve through the background scheduler loop: "
                         "submit() returns futures, the driver drains "
                         "them (DESIGN.md §15)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread the trace round-robin over N synthetic "
                         "tenants (t0..tN-1) for the weighted-fair "
                         "scheduler")
    ap.add_argument("--tenant-weights", default=None, metavar="SPEC",
                    help="per-tenant WFQ weights, e.g. 't0=3,t1=1' "
                         "(unlisted tenants weigh 1)")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max queued requests per tenant (excess is "
                         "shed with Overloaded)")
    ap.add_argument("--tenant-max-inflight", type=int, default=None,
                    help="max in-flight requests per tenant per batch")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="global admission bound across all buckets")
    ap.add_argument("--max-queue-per-bucket", type=int, default=None,
                    help="admission bound per shape bucket")
    ap.add_argument("--admission", default="shed",
                    choices=("shed", "block"),
                    help="on a full queue: shed fast with Overloaded "
                         "(default) or block the submitter until space "
                         "frees / --block-timeout-ms expires")
    ap.add_argument("--block-timeout-ms", type=float, default=1000.0,
                    help="admission='block' gives up (sheds) after this")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable request-scoped tracing and write the "
                         "Chrome trace-event JSON here (Perfetto-"
                         "loadable; a .jsonl sidecar is written too)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus-style metrics snapshot "
                         "here at exit")
    ap.add_argument("--drift-theta", type=float, default=2.0,
                    help="cost-model drift band: flag buckets whose "
                         "measured/predicted ratio leaves "
                         "[1/theta, theta]")
    args = ap.parse_args(argv)
    levels = args.levels if args.levels == "auto" else int(args.levels)
    verify = args.verify if args.verify in ("off", "finite") \
        else int(args.verify)

    rng = np.random.default_rng(args.seed)
    shapes = make_trace(rng, args.requests, args.min_dim, args.max_dim)

    if args.autotune:
        for M, N in sorted({bucket_shape(m, n, min_side=args.min_bucket)
                            for m, n in shapes}):
            entry = autotune_bucket(M, N, measure=True,
                                    min_side=args.min_bucket)
            print(f"[autotune] {M}x{N}: {entry['mode']} levels="
                  f"{entry['levels']} bk={entry['bk']} ({entry['source']})")

    if args.faults:
        faults.install(faults.parse_profile(args.faults, seed=args.seed))
    if args.trace_out:
        obs_trace.set_tracer(obs_trace.Tracer(enabled=True))

    weights = {}
    if args.tenant_weights:
        for part in args.tenant_weights.split(","):
            name, _, w = part.partition("=")
            weights[name.strip()] = float(w)

    eng = GramEngine(slots=args.slots, levels=levels, mode=args.mode,
                     min_bucket=args.min_bucket, verify=verify,
                     max_retries=args.retries,
                     backoff_s=args.backoff_ms / 1e3,
                     max_backoff_s=args.max_backoff_ms / 1e3,
                     drift_theta=args.drift_theta,
                     max_queue=args.max_queue,
                     max_queue_per_bucket=args.max_queue_per_bucket,
                     admission=args.admission,
                     block_timeout_s=args.block_timeout_ms / 1e3,
                     tenant_weights=weights or None,
                     tenant_quota=args.tenant_quota,
                     tenant_max_inflight=args.tenant_max_inflight)
    deadline = None if args.deadline_ms is None else args.deadline_ms / 1e3
    if args.async_serve:
        eng.start()
    t0 = time.perf_counter()
    futures = []
    n_tenants = max(args.tenants, 1)
    for i, (m, n) in enumerate(shapes):
        futures.append(
            eng.submit(rng.standard_normal((m, n)).astype(np.float32),
                       deadline_s=deadline, tenant=f"t{i % n_tenants}"))
    finished = eng.run_to_completion()
    dt = time.perf_counter() - t0
    if args.async_serve:
        eng.shutdown()
    s = eng.stats()
    terminal = sum(1 for f in futures if f.done())
    print(f"served {len(finished)} gram requests in {dt:.2f}s "
          f"({max(len(finished), 1)/dt:.1f} req/s) over {s['ticks']} ticks"
          + (f" [async scheduler, {terminal}/{len(futures)} futures "
             f"terminal]" if args.async_serve else ""))
    print(f"buckets={len(s['buckets'])} compiles={s['compile_count']} "
          f"p50={s['p50_latency_s']*1e3:.1f}ms "
          f"p99={s['p99_latency_s']*1e3:.1f}ms")
    if s["shed"] or s["deadline_missed"] or s["cancelled"]:
        print(f"shed={s['shed']} deadline_missed={s['deadline_missed']} "
              f"cancelled={s['cancelled']} queue_peak={s['queue_peak']} "
              f"admission={s['admission']['mode']}")
    if args.tenants > 1:
        for name, ts in s["tenants"].items():
            print(f"  tenant {name}: submitted={ts['submitted']} "
                  f"served={ts['served']} shed={ts['shed']} "
                  f"failed={ts['failed']} weight={ts['weight']:g}")
    if args.faults or s["failed"] or s["retries"]:
        print(f"ok={s['served']} failed={s['failed']} "
              f"degraded={s['degraded_served']} retries={s['retries']} "
              f"guard_vetoes={s['guard_failures']} "
              f"injected={faults.active().count('poison_output') + faults.active().count('exec_fail')}")
    for f in s["drift"]:
        print(f"[drift] {f['key']}: measured/predicted ratio "
              f"{f['ratio']:.2f} outside [1/{f['theta']:g}, {f['theta']:g}] "
              f"over {f['n']} samples — autotune winner suspect")
    if args.trace_out:
        tracer = obs_trace.get_tracer()
        out = Path(args.trace_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        tracer.write_chrome_trace(out)
        tracer.write_jsonl(out.with_suffix(".jsonl"))
        print(f"[trace] {len(tracer)} events -> {out} "
              f"(+ {out.with_suffix('.jsonl').name}; "
              f"dropped={tracer.dropped})")
        obs_trace.set_tracer(None)
    if args.metrics_out:
        out = Path(args.metrics_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(obs_metrics.render_prometheus())
        out.with_suffix(".drift.json").write_text(
            json.dumps(eng.drift.snapshot(), indent=1))
        print(f"[metrics] registry snapshot -> {out} "
              f"(+ {out.with_suffix('.drift.json').name})")
    if args.faults:
        faults.reset()
    return s


if __name__ == "__main__":
    main()
