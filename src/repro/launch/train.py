"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On this container it runs reduced configs on CPU end-to-end (the ~100M
example uses it); on real hardware the same entry point runs full configs
over the production mesh (sharding comes from repro.parallel rules applied
in-process by jit when a mesh is configured).
"""
from __future__ import annotations

import argparse
import logging

from ..configs.base import TrainConfig
from ..configs.registry import ARCHS, get_arch, reduced_arch
from ..data.pipeline import DataConfig
from ..runtime.trainer import Trainer, FailureInjector


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adamw", "shampoo"),
                    default="adamw")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a simulated failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = reduced_arch(args.arch) if args.reduced else get_arch(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     optimizer=args.optimizer, microbatch=args.microbatch,
                     checkpoint_every=args.checkpoint_every, seed=args.seed)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed,
                    enc_seq=cfg.encoder_seq if cfg.family == "audio" else 0,
                    enc_dim=cfg.d_model if cfg.family == "audio" else 0)
    trainer = Trainer(cfg, tc, dc, args.workdir,
                      failure=FailureInjector(args.fail_at))
    hist = trainer.run(args.steps)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"steps={len(hist)} loss {first:.4f} -> {last:.4f} "
          f"(stragglers flagged: {len(trainer.watchdog.flagged)})")
    return hist


if __name__ == "__main__":
    main()
