"""Gram service: streaming, batched, autotuned A^tA serving.

The layer between the fused ATA kernel and the world (DESIGN.md §10):

- ``stream``   — online accumulator: C += chunk^t chunk in packed
                 lower-triangular state, plus a reduce-scatter-sharded
                 variant that never replicates C.
- ``engine``   — ``GramEngine``: slot-based continuous batching of
                 heterogeneous Gram requests, power-of-two shape buckets,
                 one cached executable per bucket.
- ``autotune`` — per-(bucket, dtype, backend) search over
                 mode x levels x blocks, persisted to
                 ``artifacts/autotune/gram_autotune.json`` and consulted
                 by ``kernels/ops.py`` for its defaults.
"""
from . import autotune, engine, stream  # noqa: F401
from .autotune import (  # noqa: F401
    autotune as autotune_bucket, bucket_shape, lookup as autotune_lookup,
    resolve_block_defaults,
)
from . import verify  # noqa: F401
from .engine import (  # noqa: F401
    BucketHealth, EngineShutdown, GramEngine, GramFuture, GramRequest,
    GramServeError, Overloaded, TenantState, batched_gram,
)
from .stream import (  # noqa: F401
    GramStream, init as stream_init, update as stream_update,
    finalize as stream_finalize,
    GramStackStream, stack_init, stack_update, stack_finalize,
    sharded_init, update_sharded,
    distributed_init, distributed_update, distributed_finalize,
    CheckpointedGramStream,
)
from .verify import (  # noqa: F401
    GramVerdict, VerificationError, freivalds_gram, verify_gram,
)

__all__ = [
    "autotune", "engine", "stream", "verify",
    "autotune_bucket", "bucket_shape", "autotune_lookup",
    "resolve_block_defaults",
    "GramEngine", "GramRequest", "GramFuture", "BucketHealth",
    "TenantState", "GramServeError", "Overloaded", "EngineShutdown",
    "batched_gram",
    "GramStream", "stream_init", "stream_update", "stream_finalize",
    "GramStackStream", "stack_init", "stack_update", "stack_finalize",
    "sharded_init", "update_sharded",
    "distributed_init", "distributed_update", "distributed_finalize",
    "CheckpointedGramStream",
    "GramVerdict", "VerificationError", "freivalds_gram", "verify_gram",
]
