"""GramEngine: slot-based multi-tenant batched A^tA serving.

The serving analogue of ``runtime/serving.py``'s continuous-batching KV
engine, for the paper's operation instead of token decode:

* **Bucketing.**  Request shapes are rounded up to power-of-two buckets
  (``gram.autotune.bucket_shape``) — exact for Gram, because zero rows of
  A add nothing to A^tA and zero columns only add zero rows/columns to C
  that are sliced away on completion.
* **Slot batching.**  Each tick drains up to ``slots`` same-bucket
  requests, stacks them (padding the batch with zero matrices when fewer
  are waiting) and runs ONE vmapped ATA over the stack — the fused Pallas
  schedule on TPU, the XLA reference recursion elsewhere
  (``core.ata.resolve_mode``).
* **Bounded recompiles.**  Executables are cached per
  ``(bucket_m, bucket_n, dtype)``; because the batch is always padded to
  exactly ``slots`` entries, a mixed trace costs at most one compilation
  per distinct bucket key (``compile_count``; the acceptance test pins
  ``compile_count <= len(buckets)`` on a 64-request trace).
* **Autotuned per-bucket config.**  On first touch of a bucket the
  engine consults the ``gram.autotune`` JSON cache; a hit overrides
  mode / levels / block for that bucket's executable.
* **Mesh-aware distributed routing.**  With ``mesh=`` set, buckets whose
  padded size reaches ``dist_threshold`` elements are served through
  ``core.distributed.distributed_gram`` (``dist_scheme`` — default
  "auto", the communication cost model picks allreduce / reducescatter /
  half-ring / 2.5D bfs25d per shape) instead of the single-device
  vmapped executable; small buckets keep the slot-batched local path.

Failure model (DESIGN.md §13).  Serving "fast when everything works" is
not serving: devices drop, low-precision tiles overflow, a wedged
executable is an outage.  Every batch therefore runs inside a
**degradation ladder**:

* **Output guards** (``gram.verify``): a NaN/Inf scan plus — when
  ``verify`` asks for probes — a randomized Freivalds identity check
  (x^t C x vs ||Ax||^2) and diagonal nonnegativity, on every served
  result.  A guard failure is treated exactly like a crashed executable.
* **Bounded retry with backoff**: a failed attempt (exception, injected
  fault, guard veto) retries up to ``max_retries`` times with
  exponential backoff, always from the clean host copy of the operands.
* **Circuit breaker / health ladder**: per-bucket health counters
  escalate a persistently failing bucket down a config ladder — first
  quarantining its autotune winner, then forcing ``mode="reference"``,
  then ``levels=0`` (classical) — so a poisoned tuned config cannot take
  the bucket down permanently.
* **Distributed scheme fallback**: distributed buckets walk
  ``core.distributed.scheme_fallback_chain`` (bfs25d -> ring ->
  reducescatter -> allreduce -> local single-device) when a scheme's
  executable fails; a **mesh shrink** (lost replica group — injected via
  ``runtime.faults`` in drills, ``apply_mesh`` in production) invalidates
  the distributed executables and rebuilds the chain on the surviving
  sub-mesh.
* **Deadlines**: a request past its ``deadline_s`` is failed fast
  instead of holding its batch hostage.

Requests that exhaust the ladder are marked ``status="failed"`` with the
error preserved — ``step()`` never propagates an executable exception,
so one poisoned bucket cannot wedge ``run_to_completion``.

Overload model (DESIGN.md §15).  ``submit`` returns a thread-safe
:class:`GramFuture` and decides **admission** on the spot: bounded
global / per-bucket / per-tenant queues either accept the request
(operand staged into a donated per-bucket ring buffer — steady-state
serving allocates nothing per request), shed it fast through the future
with :class:`Overloaded`, or (``admission="block"``) apply backpressure
until space frees.  A CoDel-style controller prices queued work in
``core.cost_model`` leaf-product units against a measured
seconds-per-unit EWMA and sheds the requests whose deadlines are
already unmeetable instead of the newest arrivals.  Scheduling extends
full-batch-first with earliest-deadline-first within a bucket and
weighted per-tenant fair queuing across buckets (quotas, in-flight
caps, per-tenant stats) so one tenant's flood degrades only that
tenant.  ``start()`` runs the scheduler on a background thread;
``shutdown()`` fails everything still queued with
:class:`EngineShutdown` — no future is ever left hanging.

Flight recorder (DESIGN.md §14).  The full request lifecycle — submit →
queue-wait → batch → compile → execute (local or ``dist:scheme``) →
verify → retry/backoff → rung transition → done — emits request-scoped
spans and instants through ``obs.trace`` (one Perfetto-loadable
timeline, shared with ``runtime.faults`` firings and guard vetoes), every
serving count lands in the ``obs.metrics`` registry labeled by
(engine, bucket, served_by), and each successful batch feeds an
``obs.drift.DriftDetector`` comparing measured executable wall clock
(and, at compile time, HLO-census traffic) against the
``cost_model``/traffic-model predictions — ``stats()["drift"]`` surfaces
buckets whose autotuned winner has drifted from its model, and
``invalidate_drifted()`` drops those winners from the autotune cache.
"""
from __future__ import annotations

import itertools
import math
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import CancelledError
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ata import ata, ata_full, ata_levels_for
from ..core.cost_model import gram_serve_work
from ..core.distributed import (default_gram_axes, distributed_gram,
                                feasible_schemes, scheme_fallback_chain,
                                shrink_mesh)
from ..core.strassen import AUTO_MAX_LEVELS, resolve_mode
from ..core.symmetry import symmetrize_from_lower
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..obs.drift import DriftDetector
from ..runtime import faults as _faults
from . import autotune as _autotune
from . import verify as _verify

__all__ = ["GramEngine", "GramRequest", "GramFuture", "BucketHealth",
           "TenantState", "GramServeError", "Overloaded", "EngineShutdown",
           "batched_gram"]


class GramServeError(RuntimeError):
    """A request reached a terminal failure: retry ladder exhausted,
    deadline blown, or the engine shut down under it."""


class Overloaded(GramServeError):
    """Admission control refused (or the CoDel-style controller shed)
    this request — the engine is overloaded.  Raised *through the
    future*, never out of ``submit`` itself, so callers handle sheds and
    serve failures the same way: ``future.result()``."""


class EngineShutdown(GramServeError):
    """The engine was shut down while this request was still queued."""


def batched_gram(blocks: jax.Array, *, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Full symmetric Gram of a (K, m, n) stack -> (K, n, n), vmapped
    through the mode-dispatched ATA path (fused kernel on TPU).

    The batched building block of the service layer; also the in-repo
    consumer hook for ``optim/shampoo.py``'s per-block statistics.
    """
    if blocks.ndim != 3:
        raise ValueError(f"batched_gram expects (K, m, n), got {blocks.shape}")
    return jax.vmap(lambda b: ata_full(
        b, levels=levels, leaf=leaf, variant=variant, mode=mode,
        out_dtype=out_dtype, block=block, interpret=interpret))(blocks)


class GramFuture:
    """Thread-safe handle to one submitted Gram request.

    Terminal exactly once: result delivery, ladder failure, shed and
    cancellation all pass through one atomic claim (``_deliver``), so a
    request is delivered-or-cancelled exactly once — never both, never
    dropped.  ``result()`` re-raises the terminal exception
    (``Overloaded`` for sheds, ``EngineShutdown`` on teardown,
    ``GramServeError`` for ladder/deadline failures,
    ``concurrent.futures.CancelledError`` after a successful
    ``cancel()``).  Done-callbacks run on the delivering thread and must
    not block.
    """

    __slots__ = ("_engine", "_request", "_cond", "_done", "_result",
                 "_exception", "_callbacks")

    def __init__(self, engine: "GramEngine", request: "GramRequest"):
        self._engine = engine
        self._request = request
        self._cond = threading.Condition(threading.Lock())
        self._done = False
        self._result: Optional[np.ndarray] = None
        self._exception: Optional[BaseException] = None
        self._callbacks: List[Callable[["GramFuture"], None]] = []

    @property
    def uid(self) -> int:
        return self._request.uid

    @property
    def request(self) -> "GramRequest":
        return self._request

    def done(self) -> bool:
        with self._cond:
            return self._done

    def cancelled(self) -> bool:
        with self._cond:
            return self._done and isinstance(self._exception,
                                             CancelledError)

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns False when the request is
        already in a batch in flight or terminal — an in-flight request
        is *delivered*, not dropped."""
        return self._engine._cancel(self._request)

    def add_done_callback(self, fn: Callable[["GramFuture"], None]) -> None:
        with self._cond:
            if not self._done:
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:
            pass

    def _deliver(self, result=None, exception=None) -> bool:
        """Claim the terminal state; False if someone beat us to it."""
        with self._cond:
            if self._done:
                return False
            self._result, self._exception = result, exception
            self._done = True
            self._cond.notify_all()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            try:
                cb(self)
            except Exception:
                pass
        return True

    def _wait(self, timeout: Optional[float]) -> None:
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(
                    f"gram request {self.uid} not done after {timeout}s")

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        self._wait(timeout)
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None) \
            -> Optional[BaseException]:
        self._wait(timeout)
        return self._exception


class _OperandRing:
    """Donated ring of host staging buffers for one bucket: request
    operands are copied into a recycled ``(M, N)`` buffer at admission,
    so steady-state serving allocates nothing per request.  When the
    ring is exhausted (more than ``depth`` requests of one bucket in
    flight at once) staging falls back to a fresh allocation — counted
    in ``misses``, never an error.  All access is under the engine
    lock."""

    __slots__ = ("bufs", "free", "hits", "misses")

    def __init__(self, depth: int, shape: Tuple[int, int], dtype):
        self.bufs = [np.zeros(shape, dtype) for _ in range(depth)]
        self.free = list(range(depth))
        self.hits = 0
        self.misses = 0

    def acquire(self) -> Optional[int]:
        if self.free:
            self.hits += 1
            return self.free.pop()
        self.misses += 1
        return None

    def release(self, idx: int) -> None:
        self.free.append(idx)


@dataclass
class TenantState:
    """Per-tenant serving accounting + weighted-fair-queuing state.
    ``vtime`` is the tenant's virtual finish time in cost-model work
    units per unit weight — the WFQ currency the scheduler compares
    across buckets."""
    name: str
    weight: float = 1.0
    vtime: float = 0.0
    queued: int = 0
    inflight: int = 0
    submitted: int = 0
    admitted: int = 0
    served: int = 0
    failed: int = 0
    shed: int = 0
    cancelled: int = 0
    deadline_missed: int = 0

    def snapshot(self) -> dict:
        return {"weight": self.weight, "vtime": self.vtime,
                "queued": self.queued, "inflight": self.inflight,
                "submitted": self.submitted, "admitted": self.admitted,
                "served": self.served, "failed": self.failed,
                "shed": self.shed, "cancelled": self.cancelled,
                "deadline_missed": self.deadline_missed}


def _edf_key(r: "GramRequest") -> tuple:
    """Within-bucket scheduling order: priority first, then earliest
    deadline, then FIFO — deadline-less same-priority traffic degrades
    to exactly the old FIFO order."""
    return (-r.priority,
            r.t_deadline if r.t_deadline is not None else math.inf,
            r.t_submit, r.uid)


@dataclass
class GramRequest:
    uid: int
    a: np.ndarray                     # host copy; padded/stacked at batch time
    shape: Tuple[int, int]
    full: bool                        # symmetric result vs lower triangle
    gram_of: str                      # "cols" (A^tA) | "rows" (AA^t)
    t_submit: float
    deadline_s: Optional[float] = None  # fail fast past t_submit + deadline
    t_done: Optional[float] = None
    result: Optional[np.ndarray] = None
    done: bool = False
    status: str = "pending"           # -> "ok"|"failed"|"shed"|"cancelled"
    error: Optional[str] = None
    attempts: int = 0                 # executable attempts spent on it
    degraded: bool = False            # served below the bucket's first rung
    served_by: Optional[str] = None   # "local" | "local:rungK" | "dist:SCHEME"
    verified: Optional[bool] = None   # output guards ran and passed
    tenant: str = "default"
    priority: int = 0                 # higher runs first within a bucket
    t_deadline: Optional[float] = None  # absolute perf_counter deadline
    running: bool = False             # drained into a batch in flight
    future: Optional["GramFuture"] = None
    ring_slot: Optional[tuple] = None  # (bucket key, ring index) staged in
    operand_dtype: str = "native"     # resolved quantization ("native" off)

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclass
class BucketHealth:
    """Per-bucket circuit-breaker state (one per executable family)."""
    rung: int = 0                     # current degradation-ladder rung
    consecutive_failures: int = 0
    failures: int = 0
    successes: int = 0
    quarantined: List[str] = field(default_factory=list)  # rung descriptions


# local ladder: 0 = autotuned config, 1 = autotune winner quarantined,
# 2 = reference (XLA) mode, 3 = reference + classical recursion
_LOCAL_MAX_RUNG = 3


class GramEngine:
    """Multi-tenant batched Gram service (see module docstring)."""

    _ids = itertools.count()   # per-process engine label allocator

    def __init__(self, *, slots: int = 4, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=jnp.float32, min_bucket: int = 32,
                 use_autotune_cache: bool = True,
                 interpret: Optional[bool] = None,
                 mesh=None, dist_scheme: str = "auto",
                 dist_threshold: int = 1 << 21,
                 verify: Union[None, str, int] = "finite",
                 verify_rtol: Optional[float] = None,
                 verify_seed: int = 0,
                 max_retries: int = 3, backoff_s: float = 0.0,
                 max_backoff_s: Optional[float] = 5.0,
                 breaker_threshold: int = 2,
                 history_cap: int = 1024, drift_theta: float = 2.0,
                 drift: Optional[DriftDetector] = None,
                 max_queue: int = 1024,
                 max_queue_per_bucket: Optional[int] = None,
                 admission: str = "shed",
                 block_timeout_s: float = 1.0,
                 deadline_shedding: bool = True,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 tenant_quota: Optional[int] = None,
                 tenant_max_inflight: Optional[int] = None,
                 ring_depth: Optional[int] = None,
                 pipeline_depth: Optional[int] = None,
                 operand_dtype=None):
        self.slots = slots
        self.levels, self.leaf, self.variant = levels, leaf, variant
        self.mode, self.block = mode, block
        self.out_dtype = jnp.dtype(out_dtype)
        self.min_bucket = min_bucket
        self.use_autotune_cache = use_autotune_cache
        self.interpret = interpret
        # §16 perf/precision knobs: pipeline_depth None defers to the
        # measured autotune winner (then the kernel's backend default);
        # operand_dtype quantizes every served operand tile (fp8/bf16,
        # fp32 accumulation) and becomes part of the bucket key so
        # quantized and native traffic never share an executable,
        # a guard tolerance, or a drift history.
        self.pipeline_depth = pipeline_depth
        self.operand_dtype = (None if operand_dtype is None
                              else jnp.dtype(operand_dtype).name)
        # distributed routing: buckets of >= dist_threshold elements go to
        # distributed_gram on `mesh` (axis names per default_gram_axes)
        self.mesh = mesh
        self.dist_scheme = dist_scheme
        self.dist_threshold = dist_threshold
        self.dist_axes = default_gram_axes(mesh) if mesh is not None else {}
        self.dist_served = 0
        # failure model knobs: `verify` is None/"off" (no guards),
        # "finite" (NaN/Inf + diagonal scan — the default) or an int k
        # (finite scan + k Freivalds probes per served result)
        if verify in (None, "off", False, 0):
            self._guard_on, self._probes = False, 0
        elif verify == "finite":
            self._guard_on, self._probes = True, 0
        else:
            self._guard_on, self._probes = True, int(verify)
        self.verify_rtol = verify_rtol
        self._verify_rng = np.random.default_rng(verify_seed)
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        # retry backoff is capped even for deadline-less requests —
        # without this, exponential backoff on a deadline_s=None request
        # sleeps unboundedly across retries
        self.max_backoff_s = max_backoff_s
        self.breaker_threshold = max(1, breaker_threshold)
        # -- overload model (DESIGN.md §15) --------------------------------
        if admission not in ("shed", "block"):
            raise ValueError(f"admission must be 'shed' or 'block', got "
                             f"{admission!r}")
        self.admission = admission
        self.max_queue = max(1, max_queue)
        self.max_queue_per_bucket = max_queue_per_bucket
        self.block_timeout_s = block_timeout_s
        self.deadline_shedding = deadline_shedding
        self.tenant_weights = dict(tenant_weights or {})
        self.tenant_quota = tenant_quota
        self.tenant_max_inflight = tenant_max_inflight
        self.ring_depth = ring_depth if ring_depth is not None \
            else 4 * slots
        # one re-entrant lock guards every queue/tenant/counter mutation;
        # the three conditions share it: _work wakes the scheduler,
        # _space wakes blocked submitters, _idle wakes drain()
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._queued = 0
        self._inflight = 0
        self.queue_peak = 0
        self.shed = 0
        self.cancelled = 0
        self.deadline_missed = 0
        self._tenants: Dict[str, TenantState] = {}
        self._vclock = 0.0               # WFQ system virtual time
        self._rings: Dict[tuple, _OperandRing] = {}
        self._stacks: Dict[tuple, np.ndarray] = {}
        # CoDel-style shedder currency: exact cost-model leaf products
        # per bucket request, and an EWMA of measured seconds per unit
        self._work_cache: Dict[tuple, float] = {}
        self._sec_per_unit: Optional[float] = None
        self._batch_s: Dict[tuple, float] = {}
        self._uid = itertools.count()
        # bucket key -> FIFO of waiting requests (insertion-ordered so
        # tick scheduling is deterministic)
        self.waiting: "OrderedDict[tuple, List[GramRequest]]" = OrderedDict()
        # finished history is CAPPED: the flight-recorder discipline —
        # stats() reads the metrics histograms, not this buffer, so a
        # long-running service neither grows without bound nor re-sorts
        # its whole past on every scrape
        self.history_cap = max(1, history_cap)
        self.finished: "deque[GramRequest]" = deque(maxlen=self.history_cap)
        self._executables: Dict[tuple, object] = {}
        self._health: Dict[tuple, BucketHealth] = {}
        self._dist_chains: Dict[tuple, List[str]] = {}
        self._mesh_epoch = 0
        self.compile_count = 0
        self.served = 0
        self.failed = 0
        self.degraded_served = 0
        self.retries = 0
        self.guard_failures = 0
        self.mesh_changes = 0
        self.ticks = 0
        # observability: per-engine metric label into the process-wide
        # registry, plus the cost-model drift detector fed one sample per
        # successful rung-0 batch (wall) and per compile (HLO traffic)
        self.engine_label = f"e{next(GramEngine._ids)}"
        self.drift = drift if drift is not None \
            else DriftDetector(theta=drift_theta)
        self._drift_pred_cache: Dict[tuple, Optional[float]] = {}
        self._m_requests = _metrics.counter(
            "gram_requests_total", "requests submitted")
        self._m_served = _metrics.counter(
            "gram_served_total", "requests served ok, by served_by")
        self._m_failed = _metrics.counter(
            "gram_failed_total", "requests finished failed")
        self._m_deadline = _metrics.counter(
            "gram_deadline_expired_total", "requests failed on deadline")
        self._m_retries = _metrics.counter(
            "gram_retries_total", "failed executable attempts retried")
        self._m_vetoes = _metrics.counter(
            "gram_guard_vetoes_total", "output-guard vetoes")
        self._m_rung = _metrics.counter(
            "gram_rung_transitions_total", "degradation-ladder escalations")
        self._m_compiles = _metrics.counter(
            "gram_compiles_total", "executable compilations")
        self._m_exec_cache = _metrics.counter(
            "gram_exec_cache_total", "executable-cache lookups by outcome")
        self._m_queue = _metrics.gauge(
            "gram_queue_depth", "requests waiting across buckets")
        self._m_latency = _metrics.histogram(
            "gram_request_latency_s", "submit -> done seconds")
        self._m_qwait = _metrics.histogram(
            "gram_queue_wait_s", "submit -> batch-drain seconds")
        self._m_fill = _metrics.histogram(
            "gram_batch_fill", "live requests / slots per drained batch",
            lo=1.0 / 64, hi=2.0)
        self._m_exec = _metrics.histogram(
            "gram_exec_s", "executable wall seconds per batch attempt")
        # overload instruments: admission decisions, sheds by reason,
        # cancellations and deadline misses, labeled per tenant
        self._m_admitted = _metrics.counter(
            "gram_admitted_total", "requests accepted by admission control")
        self._m_shed = _metrics.counter(
            "gram_shed_total", "requests shed by admission/CoDel, by reason")
        self._m_cancelled = _metrics.counter(
            "gram_cancelled_total", "requests cancelled while queued")
        self._m_deadline_miss = _metrics.counter(
            "gram_deadline_miss_total", "deadline misses, by outcome")

    # -- request intake ----------------------------------------------------
    def submit(self, a, *, full: bool = True, gram_of: str = "cols",
               deadline_s: Optional[float] = None, tenant: str = "default",
               priority: int = 0, admission: Optional[str] = None,
               block_timeout_s: Optional[float] = None,
               operand_dtype=None) -> GramFuture:
        """Enqueue one Gram request; returns its :class:`GramFuture`.

        ``full`` selects the mirrored symmetric C (default) vs the lower
        triangle only; ``gram_of="rows"`` serves ``a @ a.T`` (the
        Arrigoni-Massini row gram — the ``aat`` leaf program on the
        fused path) instead of the default ``a.T @ a``.  ``deadline_s``
        (relative to submission) lets the engine fail the request fast
        instead of retrying past its usefulness; ``tenant`` and
        ``priority`` feed the weighted-fair / EDF scheduler.
        ``operand_dtype`` overrides the engine-level quantization for
        this request (fp8/bf16 operand tiles, DESIGN.md §16); quantized
        requests bucket separately from native ones.

        Admission is decided HERE (DESIGN.md §15): the request is either
        accepted (operand staged into the bucket's donated ring buffer),
        shed — the future fails fast with :class:`Overloaded`; ``submit``
        itself never raises on load — or, with ``admission="block"``,
        the caller blocks until space frees or ``block_timeout_s``
        expires (then sheds).  A request whose deadline is already
        unmeetable given the queue ahead of it is shed immediately
        rather than queued to die."""
        a = np.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"gram request must be 2-D, got {a.shape}")
        if gram_of not in ("cols", "rows"):
            raise ValueError(f"gram_of must be 'cols' or 'rows', got "
                             f"{gram_of!r}")
        mode = self.admission if admission is None else admission
        if mode not in ("shed", "block"):
            raise ValueError(f"admission must be 'shed' or 'block', got "
                             f"{mode!r}")
        now = time.perf_counter()
        od = operand_dtype if operand_dtype is not None \
            else self.operand_dtype
        od = "native" if od in (None, "native") else jnp.dtype(od).name
        r = GramRequest(uid=next(self._uid), a=a, shape=a.shape, full=full,
                        gram_of=gram_of, t_submit=now,
                        deadline_s=deadline_s, tenant=str(tenant),
                        priority=int(priority), operand_dtype=od)
        if deadline_s is not None:
            r.t_deadline = now + deadline_s
        fut = GramFuture(self, r)
        r.future = fut
        key = self._bucket_key(a.shape, a.dtype, gram_of, od)
        b = self._blabel(key)
        timeout = self.block_timeout_s if block_timeout_s is None \
            else block_timeout_s
        t_give_up = now + timeout
        with self._lock:
            ts = self._tenant(r.tenant)
            ts.submitted += 1
            self._m_requests.inc(engine=self.engine_label, bucket=b)
            _trace.instant("submit", trace_id=r.uid, bucket=b,
                           shape=f"{a.shape[0]}x{a.shape[1]}",
                           gram_of=gram_of, tenant=r.tenant)
            while True:
                if self._stop:
                    self._finish_failed(
                        r, "engine shutdown",
                        exc=EngineShutdown(
                            f"request {r.uid}: engine is shut down"))
                    return fut
                reason = self._admission_veto_locked(key, r, ts)
                if reason is None:
                    self._admit_locked(key, r, ts)
                    return fut
                if reason == "unmeetable":
                    # blocking cannot help a deadline the queue already
                    # makes unmeetable — shed even in block mode
                    self._finish_shed(r, reason)
                    return fut
                # before shedding, try to free space by failing queued
                # requests that are already doomed (CoDel discipline:
                # drop the dead, not the newest)
                if self._prune_queues_locked():
                    continue
                if mode == "block":
                    remaining = t_give_up - time.perf_counter()
                    if remaining > 0:
                        self._space.wait(remaining)
                        continue
                    reason = f"{reason}_timeout"
                self._finish_shed(r, reason)
                return fut

    # -- admission control (DESIGN.md §15) ---------------------------------
    def _tenant(self, name: str) -> TenantState:
        ts = self._tenants.get(name)
        if ts is None:
            ts = TenantState(name=name,
                             weight=max(self.tenant_weights.get(name, 1.0),
                                        1e-9),
                             vtime=self._vclock)
            self._tenants[name] = ts
        return ts

    def _admission_veto_locked(self, key, r: GramRequest,
                               ts: TenantState) -> Optional[str]:
        """None to accept, else the shed-reason slug.  The unmeetable
        check prices only the QUEUE ahead of the request (batches of
        ``slots`` at the bucket's estimated batch seconds) — never the
        request's own service time, so an empty queue always admits and
        the PR 6 deadline-expiry semantics are unchanged."""
        qb = len(self.waiting.get(key, ()))
        if self.deadline_shedding and r.t_deadline is not None:
            est = self._est_batch_s(key)
            if est is not None:
                wait_est = (qb // self.slots) * est
                if time.perf_counter() + wait_est > r.t_deadline:
                    return "unmeetable"
        if self._queued >= self.max_queue:
            return "queue_full"
        if (self.max_queue_per_bucket is not None
                and qb >= self.max_queue_per_bucket):
            return "bucket_full"
        if self.tenant_quota is not None and ts.queued >= self.tenant_quota:
            return "tenant_quota"
        return None

    def _admit_locked(self, key, r: GramRequest, ts: TenantState) -> None:
        self._stage_operand_locked(key, r)
        if ts.queued == 0:
            # (re)activating tenant: no banked WFQ credit from idling
            ts.vtime = max(ts.vtime, self._vclock)
        self.waiting.setdefault(key, []).append(r)
        self._queued += 1
        ts.queued += 1
        ts.admitted += 1
        self.queue_peak = max(self.queue_peak, self._queued)
        b = self._blabel(key)
        self._m_admitted.inc(engine=self.engine_label, bucket=b,
                             tenant=r.tenant)
        self._m_queue.set(self._queued, engine=self.engine_label)
        _trace.instant("admit", trace_id=r.uid, bucket=b, tenant=r.tenant,
                       queued=self._queued)
        self._work.notify()

    def _stage_operand_locked(self, key, r: GramRequest) -> None:
        """Copy the operand into a donated ring buffer for its bucket;
        ``r.a`` becomes the true-shape view into the staged copy."""
        M, N, dtype, _gram_of = key[:4]
        ring = self._rings.get(key)
        if ring is None:
            ring = self._rings[key] = _OperandRing(
                self.ring_depth, (M, N), jnp.dtype(dtype))
        idx = ring.acquire()
        m, n = r.shape
        if idx is None:                 # ring exhausted: plain allocation
            buf = np.zeros((M, N), jnp.dtype(dtype))
        else:
            buf = ring.bufs[idx]
            r.ring_slot = (key, idx)
        buf[:m, :n] = r.a
        r.a = buf[:m, :n]

    def _release_operand_locked(self, r: GramRequest) -> None:
        if r.ring_slot is not None:
            key, idx = r.ring_slot
            r.ring_slot = None
            ring = self._rings.get(key)
            if ring is not None:
                ring.release(idx)

    def _dequeue_locked(self, r: GramRequest) -> None:
        """Accounting for one request leaving a waiting queue (into a
        batch, a shed, a cancel or shutdown) — the caller removes it
        from the queue list itself."""
        self._queued -= 1
        self._tenants[r.tenant].queued -= 1

    def _notify_idle_locked(self) -> None:
        if self._queued == 0 and self._inflight == 0:
            self._idle.notify_all()

    # -- work estimation (cost model -> seconds) ---------------------------
    _EST_ALPHA = 0.3

    def _work_units(self, key) -> float:
        """Cost-model work units (exact leaf-product count) for one
        request of this bucket — the machine-independent currency of the
        shedder and the WFQ scheduler."""
        u = self._work_cache.get(key)
        if u is None:
            M, N, _dtype, gram_of = key[:4]
            cfg = self._bucket_config(key, 0)
            levels = cfg["levels"]
            if levels == "auto":
                levels = min(ata_levels_for(M, N, cfg["leaf"]),
                             AUTO_MAX_LEVELS)
            try:
                u = float(gram_serve_work(M, N, gram_of=gram_of,
                                          leaf=cfg["leaf"],
                                          levels=int(levels)))
            except Exception:
                u = float(M) * N * (N + 1) / 2.0
            self._work_cache[key] = u
        return u

    def _note_batch_seconds(self, key, dt: float) -> None:
        """Feed one measured batch service time (including injected
        exec_delay stalls — overload drills must inflate the estimate)
        into the per-bucket EWMA and the global seconds-per-work-unit
        EWMA used for never-measured buckets."""
        with self._lock:
            units = self._work_units(key) * self.slots
            per = dt / max(units, 1.0)
            a = self._EST_ALPHA
            self._sec_per_unit = per if self._sec_per_unit is None \
                else (1 - a) * self._sec_per_unit + a * per
            old = self._batch_s.get(key)
            self._batch_s[key] = dt if old is None \
                else (1 - a) * old + a * dt

    def _est_batch_s(self, key) -> Optional[float]:
        """Estimated seconds to serve one batch of this bucket; None
        until the engine has measured anything at all."""
        est = self._batch_s.get(key)
        if est is not None:
            return est
        if self._sec_per_unit is None:
            return None
        return self._sec_per_unit * self._work_units(key) * self.slots

    def _prune_queues_locked(self) -> List[GramRequest]:
        """CoDel-style sweep: walk every bucket queue in EDF order and
        remove the requests that are already dead — overdue ones fail as
        deadline misses, not-yet-overdue ones whose queue position makes
        their deadline unmeetable are shed — so overload pressure evicts
        the doomed, not the newest arrivals.  Returns the requests it
        finished."""
        now = time.perf_counter()
        done: List[GramRequest] = []
        for key in list(self.waiting):
            q = self.waiting[key]
            q.sort(key=_edf_key)
            est = self._est_batch_s(key) if self.deadline_shedding else None
            keep: List[GramRequest] = []
            for r in q:
                if r.t_deadline is None:
                    keep.append(r)
                elif now > r.t_deadline:
                    self._dequeue_locked(r)
                    self._finish_failed(r, "deadline exceeded in queue")
                    done.append(r)
                elif (est is not None
                      and now + (len(keep) // self.slots) * est
                      > r.t_deadline):
                    self._dequeue_locked(r)
                    self._finish_shed(r, "unmeetable")
                    done.append(r)
                else:
                    keep.append(r)
            if keep:
                self.waiting[key] = keep
            else:
                del self.waiting[key]
        if done:
            self._m_queue.set(self._queued, engine=self.engine_label)
            self._space.notify_all()
            self._notify_idle_locked()
        return done

    def _cancel(self, r: GramRequest) -> bool:
        """Cancel a queued request (GramFuture.cancel backend): False
        once it is in flight or terminal."""
        with self._lock:
            if r.done or r.running:
                return False
            key = self._bucket_key(r.shape, r.a.dtype, r.gram_of,
                                   r.operand_dtype)
            q = self.waiting.get(key)
            if q is None or r not in q:
                return False            # racing terminal transition
            q.remove(r)
            if not q:
                del self.waiting[key]
            self._dequeue_locked(r)
            self._m_queue.set(self._queued, engine=self.engine_label)
            self._space.notify_all()
            self._finish_cancelled(r)
        return True

    def _bucket_key(self, shape, dtype, gram_of: str = "cols",
                    operand_dtype=None) -> tuple:
        """5-tuple bucket identity: (M, N, dtype, gram_of, operand) where
        the last element is the quantization the bucket serves under —
        ``"native"`` (no quantization — the historical behavior) or the
        operand dtype name.  Quantized and native traffic for the same
        shape are distinct buckets: distinct executables, guard
        tolerances, rings, and drift histories."""
        M, N = _autotune.bucket_shape(*shape, min_side=self.min_bucket)
        od = operand_dtype if operand_dtype is not None \
            else self.operand_dtype
        od = "native" if od in (None, "native") else jnp.dtype(od).name
        return (M, N, jnp.dtype(dtype).name, gram_of, od)

    @staticmethod
    def _bucket_operand(key) -> Optional[str]:
        """Quantized operand dtype name of a bucket key, None for native
        (tolerates legacy 4-tuple keys fed by older tests/tools)."""
        od = key[4] if len(key) > 4 else "native"
        return None if od == "native" else od

    @classmethod
    def _blabel(cls, key) -> str:
        """Metric/trace label for one bucket key.  Native buckets keep
        the historical ``MxN/dtype/gram_of`` form bit-for-bit; quantized
        buckets append the operand dtype."""
        M, N, dtype, gram_of = key[:4]
        base = f"{M}x{N}/{dtype}/{gram_of}"
        od = cls._bucket_operand(key)
        return base if od is None else f"{base}/{od}"

    @classmethod
    def _drift_key(cls, key) -> str:
        """Drift-detector key: the bucket in autotune's vocabulary (the
        `kind` the winner was tuned for), so a finding maps 1:1 onto a
        cache entry ``invalidate_drifted`` can drop.  Native buckets keep
        the historical 3-segment form; quantized buckets append the
        operand dtype as a 4th segment."""
        M, N, dtype, gram_of = key[:4]
        base = f"{M}x{N}/{dtype}/{'aat' if gram_of == 'rows' else 'ata'}"
        od = cls._bucket_operand(key)
        return base if od is None else f"{base}/{od}"

    # -- degradation ladder ------------------------------------------------
    def _bucket_health(self, key) -> BucketHealth:
        return self._health.setdefault(key, BucketHealth())

    def _bucket_config(self, key, rung: int = 0) -> dict:
        """Engine config for one bucket at one ladder rung.

        Rung 0 behaves as always: the autotune winner fills in only the
        knobs the caller left open (mode/levels "auto", block None) —
        explicit engine arguments always win.  Mode/levels are adopted
        only from *measured* entries (wall-clock-backed: a model-only
        entry must not flip the backend-appropriate "auto" dispatch);
        block sizes only from fused winners (reference entries carry
        placeholder blocks).  Higher rungs degrade: 1 skips the autotune
        winner (quarantine), 2 forces the XLA reference recursion, 3 adds
        ``levels=0`` (classical — no fast-variant arithmetic at all).

        The §16 perf knobs ride the same policy: ``pipeline_depth`` is
        adopted only from *measured* fused winners (it is a wall-clock
        claim — a model-only entry must not pick the pipelined kernel on
        a backend where it was never timed), and ``operand_dtype`` is
        never adopted from the cache at all — quantization changes the
        served numerics, so it flows exclusively from the caller (engine
        kwarg / per-request override) via the bucket key.
        """
        M, N, dtype, gram_of = key[:4]
        cfg = {"mode": self.mode, "levels": self.levels, "leaf": self.leaf,
               "variant": self.variant, "block": self.block,
               "pipeline_depth": self.pipeline_depth,
               "operand_dtype": self._bucket_operand(key)}
        if self.use_autotune_cache and rung == 0:
            try:
                hit = _autotune.lookup(
                    M, N, dtype=dtype,
                    kind="aat" if gram_of == "rows" else "ata",
                    min_side=self.min_bucket)
            except Exception:
                hit = None
            if hit:
                if hit.get("source") == "measured":
                    if cfg["mode"] == "auto":
                        cfg["mode"] = hit["mode"]
                    if cfg["levels"] == "auto":
                        cfg["levels"] = hit["levels"]
                    if cfg["pipeline_depth"] is None \
                            and hit.get("mode") == "fused":
                        cfg["pipeline_depth"] = hit.get("pipeline_depth")
                if cfg["block"] is None and hit.get("mode") == "fused":
                    cfg["block"] = hit.get("bk")
        if rung >= 2:
            cfg["mode"] = "reference"
        if rung >= 3:
            cfg["levels"] = 0
        return cfg

    def _record_failure(self, key, health: BucketHealth, max_rung: int,
                        reason: str):
        """One failed attempt: bump counters; trip the breaker (escalate
        the rung, stickily) after ``breaker_threshold`` consecutive
        failures."""
        health.failures += 1
        health.consecutive_failures += 1
        self.retries += 1
        b = self._blabel(key)
        self._m_retries.inc(engine=self.engine_label, bucket=b)
        _trace.instant("retry", bucket=b, reason=reason)
        if (health.consecutive_failures >= self.breaker_threshold
                and health.rung < max_rung):
            health.rung += 1
            health.consecutive_failures = 0
            health.quarantined.append(
                f"rung{health.rung - 1}: {reason}")
            self._m_rung.inc(engine=self.engine_label, bucket=b,
                             rung=health.rung)
            _trace.instant("rung_transition", bucket=b, rung=health.rung,
                           reason=reason)

    def _record_success(self, key, health: BucketHealth):
        health.successes += 1
        health.consecutive_failures = 0

    def _backoff(self, attempt: int, batch: List[GramRequest]):
        if self.backoff_s <= 0:
            return
        wait = self.backoff_s * (2 ** (attempt - 1))
        # deadline-less requests must not sleep unboundedly: the
        # exponential is capped by max_backoff_s before any deadline math
        if self.max_backoff_s is not None:
            wait = min(wait, self.max_backoff_s)
        # never sleep past the tightest live deadline
        now = time.perf_counter()
        for r in batch:
            if r.t_deadline is not None:
                wait = min(wait, max(0.0, r.t_deadline - now))
        if wait > 0:
            time.sleep(wait)

    def _expire(self, entries):
        """Split [(slot, request)] into (live, newly-expired-and-failed)."""
        now = time.perf_counter()
        live, expired = [], []
        for slot, r in entries:
            if r.t_deadline is not None and now > r.t_deadline:
                self._finish_failed(r, "deadline exceeded")
                expired.append(r)
            else:
                live.append((slot, r))
        return live, expired

    # -- completion bookkeeping -------------------------------------------
    # Every terminal path claims the future FIRST (exactly-once), then
    # does its accounting under the engine lock.  A request taken into a
    # batch holds an in-flight slot; releasing it may wake drain().

    def _settle_locked(self, r: GramRequest) -> None:
        """Shared terminal accounting: in-flight slot, operand ring,
        host copy, finished history, idle wakeup."""
        if r.running:
            r.running = False
            self._inflight -= 1
            ts = self._tenants.get(r.tenant)
            if ts is not None:
                ts.inflight -= 1
        self._release_operand_locked(r)
        r.a = None                      # free the host copy
        self.finished.append(r)
        self._notify_idle_locked()

    def _note_deadline_miss_locked(self, r: GramRequest, b: str,
                                   outcome: str) -> None:
        self.deadline_missed += 1
        self._tenant(r.tenant).deadline_missed += 1
        self._m_deadline_miss.inc(engine=self.engine_label, bucket=b,
                                  tenant=r.tenant, outcome=outcome)
        _trace.instant_at("deadline_miss", r.t_deadline or r.t_done,
                          trace_id=r.uid, bucket=b, tenant=r.tenant,
                          outcome=outcome)

    def _finish_ok(self, r: GramRequest, c: np.ndarray, *, served_by: str,
                   degraded: bool, t_done: Optional[float] = None):
        if r.future is not None and not r.future._deliver(result=c):
            return
        with self._lock:
            b = self._blabel(self._bucket_key(r.shape, r.a.dtype,
                                              r.gram_of,
                                              r.operand_dtype))
            r.result = c
            r.status, r.done = "ok", True
            r.t_done = t_done if t_done is not None else time.perf_counter()
            r.degraded = degraded
            r.served_by = served_by
            r.verified = True if self._guard_on else None
            self.served += 1
            if degraded:
                self.degraded_served += 1
            self._tenant(r.tenant).served += 1
            if r.t_deadline is not None and r.t_done > r.t_deadline:
                self._note_deadline_miss_locked(r, b, "served_late")
            self._settle_locked(r)
            self._m_served.inc(engine=self.engine_label, bucket=b,
                               served_by=served_by)
            self._m_latency.observe(r.latency_s, engine=self.engine_label,
                                    bucket=b)
        _trace.instant("done", trace_id=r.uid, status="ok",
                       served_by=served_by)
        _trace.add_span("request", r.t_submit, r.t_done, trace_id=r.uid,
                        bucket=b, status="ok", served_by=served_by,
                        attempts=r.attempts)

    def _finish_failed(self, r: GramRequest, error: str, *,
                       exc: Optional[BaseException] = None):
        if r.future is not None and not r.future._deliver(
                exception=exc if exc is not None
                else GramServeError(f"request {r.uid} failed: {error}")):
            return
        with self._lock:
            b = self._blabel(self._bucket_key(r.shape, r.a.dtype,
                                              r.gram_of,
                                              r.operand_dtype))
            r.status, r.done = "failed", True
            r.error = error
            r.t_done = time.perf_counter()
            self.failed += 1
            self._tenant(r.tenant).failed += 1
            self._m_failed.inc(engine=self.engine_label, bucket=b)
            if error.startswith("deadline"):
                self._m_deadline.inc(engine=self.engine_label, bucket=b)
                self._note_deadline_miss_locked(r, b, "failed")
            self._settle_locked(r)
            self._m_latency.observe(r.latency_s, engine=self.engine_label,
                                    bucket=b)
        _trace.instant("done", trace_id=r.uid, status="failed", error=error)
        _trace.add_span("request", r.t_submit, r.t_done, trace_id=r.uid,
                        bucket=b, status="failed", error=error,
                        attempts=r.attempts)

    def _finish_shed(self, r: GramRequest, reason: str):
        if r.future is not None and not r.future._deliver(
                exception=Overloaded(
                    f"request {r.uid} shed ({reason}): engine "
                    f"{self.engine_label} is overloaded")):
            return
        with self._lock:
            b = self._blabel(self._bucket_key(r.shape, r.a.dtype,
                                              r.gram_of,
                                              r.operand_dtype))
            r.status, r.done = "shed", True
            r.error = f"shed: {reason}"
            r.t_done = time.perf_counter()
            self.shed += 1
            self._tenant(r.tenant).shed += 1
            self._m_shed.inc(engine=self.engine_label, bucket=b,
                             tenant=r.tenant, reason=reason)
            self._settle_locked(r)
        _trace.instant("shed", trace_id=r.uid, bucket=b, tenant=r.tenant,
                       reason=reason)
        _trace.add_span("request", r.t_submit, r.t_done, trace_id=r.uid,
                        bucket=b, status="shed", error=r.error,
                        attempts=r.attempts)

    def _finish_cancelled(self, r: GramRequest):
        if r.future is not None and not r.future._deliver(
                exception=CancelledError(f"request {r.uid} cancelled")):
            return
        with self._lock:
            b = self._blabel(self._bucket_key(r.shape, r.a.dtype,
                                              r.gram_of,
                                              r.operand_dtype))
            r.status, r.done = "cancelled", True
            r.error = "cancelled"
            r.t_done = time.perf_counter()
            self.cancelled += 1
            self._tenant(r.tenant).cancelled += 1
            self._m_cancelled.inc(engine=self.engine_label, bucket=b,
                                  tenant=r.tenant)
            self._settle_locked(r)
        _trace.instant("cancel", trace_id=r.uid, bucket=b, tenant=r.tenant)

    # -- output guards -----------------------------------------------------
    def _guard(self, key, entries, out) -> Optional[str]:
        """Run the output guards over a served batch; None when every
        result passes, else a reason string (the whole batch retries —
        corruption is a property of the executable run, not a request).

        The finite scan runs ONCE over the whole slot stack (padding
        slots are exact zeros, so they never veto) — one vectorized pass
        instead of per-request slices keeps the default-on guard off the
        latency profile; per-request work (diagonal, probes) only touches
        the small diag vector unless probes are enabled."""
        if not self._guard_on:
            return None
        M, N, dtype, gram_of = key[:4]
        # fast path: one float64 reduction (any NaN/Inf propagates); the
        # full scan only confirms — a float64 *overflow* in the reduction
        # of huge-but-finite values must not veto a correct result
        if not np.isfinite(np.sum(out, dtype=np.float64)) \
                and not np.isfinite(out).all():
            self._veto(key, "non_finite")
            return "guard veto: non-finite entries in served batch"
        rtol = self.verify_rtol
        if rtol is None:
            # precision-scaled: a quantized bucket's residual is bounded
            # by the operand quantization step, not the storage dtype
            rtol = _verify.default_rtol(self._bucket_operand(key) or dtype)
        for slot, r in entries:
            n = r.shape[0] if gram_of == "rows" else r.shape[1]
            c = out[slot, :n, :n] if out.ndim == 3 else out[:n, :n]
            d = np.diagonal(c).astype(np.float64)
            scale = float(np.abs(d).max()) if d.size else 0.0
            if not (d >= -rtol * max(scale, 1.0)).all():
                self._veto(key, "negative_diagonal", uid=r.uid)
                return f"guard veto on request {r.uid}: negative diagonal"
            if self._probes:
                ok, worst = _verify.freivalds_gram(
                    r.a, c, probes=self._probes, rtol=rtol,
                    gram_of=gram_of, full=False, rng=self._verify_rng)
                if not ok:
                    self._veto(key, "freivalds", uid=r.uid)
                    return (f"guard veto on request {r.uid}: freivalds "
                            f"identity violated (rel err {worst:.3e})")
        return None

    def _veto(self, key, reason: str, uid: Optional[int] = None) -> None:
        """One guard veto: counter + an instant on the shared timeline."""
        self.guard_failures += 1
        self._m_vetoes.inc(engine=self.engine_label,
                           bucket=self._blabel(key))
        _trace.instant("guard_veto", trace_id=uid, reason=reason,
                       bucket=self._blabel(key))

    # -- mesh lifecycle ----------------------------------------------------
    def apply_mesh(self, mesh) -> None:
        """Adopt a new (typically shrunk) device mesh mid-run: recompute
        the distributed axis mapping, invalidate every distributed
        executable and fallback chain, and reset distributed buckets'
        ladder rungs (the old rung judged the old mesh's schemes)."""
        dist_keys = [k for k in self._health if self._is_distributed(k)]
        self.mesh = mesh
        self.dist_axes = default_gram_axes(mesh) if mesh is not None else {}
        self._mesh_epoch += 1
        self.mesh_changes += 1
        self._dist_chains.clear()
        self._executables = {ek: exe for ek, exe in self._executables.items()
                             if ek[0] != "dist"}
        for k in dist_keys:
            self._health[k].rung = 0
            self._health[k].consecutive_failures = 0

    def _poll_faults(self):
        """Chaos hook: an armed ``mesh_shrink`` fault drops one replica
        group from the serving mesh (``runtime.faults``)."""
        if self.mesh is None:
            return
        if _faults.fire("mesh_shrink", "gram.engine.mesh"):
            new = shrink_mesh(self.mesh)
            if new is not None:
                self.apply_mesh(new)

    # -- executable cache --------------------------------------------------
    @staticmethod
    def _cfg_fingerprint(cfg) -> tuple:
        return (cfg["mode"], str(cfg["levels"]), cfg["leaf"],
                cfg["variant"], cfg["block"],
                cfg.get("pipeline_depth"), cfg.get("operand_dtype"))

    def _local_executable(self, key, cfg):
        M, N, dtype, gram_of = key[:4]
        ekey = ("local", key, self._cfg_fingerprint(cfg))
        if ekey in self._executables:
            self._m_exec_cache.inc(engine=self.engine_label, path="local",
                                   outcome="hit")
            return self._executables[ekey]
        self._m_exec_cache.inc(engine=self.engine_label, path="local",
                               outcome="miss")

        def single(x):
            return ata(x, gram_of=gram_of, levels=cfg["levels"],
                       leaf=cfg["leaf"], variant=cfg["variant"],
                       mode=cfg["mode"], out_dtype=self.out_dtype,
                       block=cfg["block"], interpret=self.interpret,
                       pipeline_depth=cfg.get("pipeline_depth"),
                       operand_dtype=cfg.get("operand_dtype"))
        spec = jax.ShapeDtypeStruct((self.slots, M, N), jnp.dtype(dtype))
        with _trace.span("compile", bucket=self._blabel(key), path="local",
                         mode=str(cfg["mode"]), levels=str(cfg["levels"])):
            compiled = jax.jit(jax.vmap(single)).lower(spec).compile()
        self.compile_count += 1
        self._m_compiles.inc(engine=self.engine_label,
                             bucket=self._blabel(key), path="local")
        self._observe_traffic(key, cfg, compiled)
        self._executables[ekey] = compiled
        return compiled

    def _dist_executable(self, key, scheme, cfg):
        M, N, dtype, gram_of = key[:4]
        ekey = ("dist", key, scheme, self._mesh_epoch)
        if ekey in self._executables:
            self._m_exec_cache.inc(engine=self.engine_label, path="dist",
                                   outcome="hit")
            return self._executables[ekey]
        self._m_exec_cache.inc(engine=self.engine_label, path="dist",
                               outcome="miss")

        # one request at a time on the whole mesh: the mesh IS the
        # batch dimension here, slot-stacking would fight the sharding
        # (autotuned mode/levels still apply; block resolves inside
        # the per-shard kernels via the ops-level autotune defaults)
        def one(x):
            return distributed_gram(
                x, self.mesh, scheme=scheme,
                levels=cfg["levels"], leaf=cfg["leaf"],
                variant=cfg["variant"], mode=cfg["mode"],
                out_dtype=self.out_dtype, interpret=self.interpret,
                **self.dist_axes)
        spec = jax.ShapeDtypeStruct((M, N), jnp.dtype(dtype))
        with _trace.span("compile", bucket=self._blabel(key),
                         path=f"dist:{scheme}"):
            compiled = jax.jit(one).lower(spec).compile()
        self.compile_count += 1
        self._m_compiles.inc(engine=self.engine_label,
                             bucket=self._blabel(key), path="dist")
        self._executables[ekey] = compiled
        return compiled

    # -- cost-model drift ---------------------------------------------------
    def _drift_prediction(self, key, cfg) -> Optional[float]:
        """Model-predicted HBM bytes for one (bucket, config) — the
        denominator of both drift channels.  Resolves the same defaults
        the executable resolves (the "auto" mode dispatch, natural
        recursion depth, default block) so the prediction prices the
        config actually run; None when the model cannot price it."""
        ck = (key, self._cfg_fingerprint(cfg))
        if ck in self._drift_pred_cache:
            return self._drift_pred_cache[ck]
        M, N, dtype, gram_of = key[:4]
        pred: Optional[float] = None
        try:
            levels = cfg["levels"]
            if levels == "auto":
                levels = min(ata_levels_for(M, N, cfg["leaf"]),
                             AUTO_MAX_LEVELS)
            blk = cfg["block"] or _autotune.DEFAULT_BLOCK
            cand = {"mode": resolve_mode(cfg["mode"]), "levels": int(levels),
                    "variant": cfg["variant"], "bm": blk, "bk": blk,
                    "bn": blk}
            pred = _autotune.model_score(
                M, N, cand, in_bytes=int(jnp.dtype(dtype).itemsize),
                out_bytes=int(self.out_dtype.itemsize),
                kind="aat" if gram_of == "rows" else "ata")
        except Exception:
            pred = None
        self._drift_pred_cache[ck] = pred
        return pred

    def _observe_traffic(self, key, cfg, compiled) -> None:
        """Traffic drift channel: HLO-census HBM bytes of the compiled
        executable vs the analytic traffic model (same units — the
        [1/theta, theta] band applies directly)."""
        pred = self._drift_prediction(key, cfg)
        if pred is None:
            return
        try:
            from ..roofline.hlo_census import hbm_intermediate_census
            measured = float(hbm_intermediate_census(
                compiled.as_text())["total_bytes"])
        except Exception:
            return                      # census is best-effort telemetry
        self.drift.observe(self._drift_key(key), measured=measured,
                           predicted=pred, channel="traffic",
                           config=str(self._cfg_fingerprint(cfg)))

    def invalidate_drifted(self, channel: str = "wall") -> List[str]:
        """Act on drift findings: drop each flagged bucket's autotune
        winner (``gram.autotune.invalidate``), its cached executables and
        prediction, and its drift history — the next touch re-tunes and
        re-measures from scratch.  Returns the flagged drift keys."""
        dropped = []
        for dk in self.drift.stale_keys(channel):
            parts = str(dk).split("/")
            size, dtype, kind = parts[:3]
            od = parts[3] if len(parts) > 3 else "native"
            M, N = (int(x) for x in size.split("x"))
            try:
                _autotune.invalidate(M, N, dtype=dtype, kind=kind,
                                     min_side=self.min_bucket)
            except Exception:
                pass                    # no cache entry to drop is fine
            key = (M, N, dtype, "rows" if kind == "aat" else "cols", od)
            self._executables = {
                ek: exe for ek, exe in self._executables.items()
                if ek[1] != key}
            self._drift_pred_cache = {
                ck: v for ck, v in self._drift_pred_cache.items()
                if ck[0] != key}
            self.drift.reset(dk)
            dropped.append(str(dk))
            _trace.instant("drift_invalidate", key=str(dk), channel=channel)
        return dropped

    def _is_distributed(self, key) -> bool:
        """Buckets at/above the element threshold route to the mesh (when
        one is configured and the configured scheme fits the bucket — for
        "auto", any feasible scheme; otherwise dist_scheme itself must be
        feasible, or the bucket stays local rather than failing mid-step
        on a shard_map divisibility error)."""
        M, N, _, gram_of = key[:4]
        if gram_of == "rows":
            # the distributed schemes decompose A^t A; row-gram buckets
            # stay on the local aat executor
            return False
        if self._bucket_operand(key) is not None:
            # quantized operand tiles are a fused-local-kernel feature;
            # the distributed schemes serve native precision only
            return False
        if self.mesh is None or M * N < self.dist_threshold:
            return False
        feas = feasible_schemes(M, N, self.mesh, **self.dist_axes)
        if self.dist_scheme == "auto":
            return bool(feas)
        return self.dist_scheme in feas

    def _dist_chain(self, key) -> List[str]:
        """Fallback chain for one distributed bucket on the current mesh
        (``core.distributed.scheme_fallback_chain`` + terminal "local"),
        cached per mesh epoch."""
        ck = (key, self._mesh_epoch)
        if ck not in self._dist_chains:
            M, N, dtype, gram_of = key[:4]
            chain = scheme_fallback_chain(
                M, N, self.mesh, scheme=self.dist_scheme,
                dtype_bytes=jnp.dtype(dtype).itemsize,
                out_bytes=self.out_dtype.itemsize,
                **self.dist_axes)
            self._dist_chains[ck] = [f"dist:{s}" for s in chain] + ["local"]
        return self._dist_chains[ck]

    def prewarm(self, shapes, dtype=jnp.float32) -> int:
        """Build executables for the buckets covering ``shapes`` ahead of
        traffic (steady-state serving pays no first-request compile).
        Returns the number of compilations triggered."""
        before = self.compile_count
        for shape in shapes:
            key = self._bucket_key(shape, dtype)
            cfg = self._bucket_config(key, rung=0)
            if self._is_distributed(key):
                scheme = self._dist_chain(key)[0]
                if scheme != "local":
                    self._dist_executable(key, scheme[len("dist:"):], cfg)
                    continue
            self._local_executable(key, cfg)
        return self.compile_count - before

    # -- scheduling (full-batch-first -> WFQ across buckets -> EDF) --------
    def _select_bucket_locked(self) -> tuple:
        """Pick the bucket to drain: any bucket with a full batch first
        (throughput, exactly as before), ties and partial batches broken
        by weighted-fair queuing — the bucket whose head request belongs
        to the tenant with the smallest virtual time — then by oldest
        head.  With a single tenant every vtime compares equal and this
        degenerates to the old oldest-head-first policy."""
        full = [k for k, q in self.waiting.items() if len(q) >= self.slots]
        pool = full or list(self.waiting)

        def rank(k):
            head = min(self.waiting[k], key=_edf_key)
            ts = self._tenants.get(head.tenant)
            return (ts.vtime if ts is not None else 0.0,
                    head.t_submit, head.uid)

        key = min(pool, key=rank)
        self._vclock = max(self._vclock, rank(key)[0])
        return key

    def _take_batch_locked(self, key) -> List[Tuple[int, GramRequest]]:
        """Pop up to ``slots`` requests from one bucket in EDF order,
        honoring the per-tenant in-flight cap (a capped tenant's surplus
        stays queued for the next tick; the bucket never stalls — if
        every waiting request is capped, the EDF head runs anyway)."""
        q = self.waiting[key]
        q.sort(key=_edf_key)
        cap = self.tenant_max_inflight
        take: List[GramRequest] = []
        leftover: List[GramRequest] = []
        taking: Dict[str, int] = {}
        for r in q:
            busy = (self._tenants[r.tenant].inflight
                    + taking.get(r.tenant, 0))
            if len(take) < self.slots and (cap is None or busy < cap):
                take.append(r)
                taking[r.tenant] = taking.get(r.tenant, 0) + 1
            else:
                leftover.append(r)
        if not take:                    # livelock guard: serve the head
            take, leftover = [q[0]], q[1:]
        if leftover:
            self.waiting[key] = leftover
        else:
            del self.waiting[key]
        units = self._work_units(key)
        for r in take:
            self._dequeue_locked(r)
            r.running = True
            ts = self._tenants[r.tenant]
            ts.inflight += 1
            self._inflight += 1
            # WFQ charge: one request's cost-model work over the
            # tenant's weight advances its virtual time
            ts.vtime += units / ts.weight
        self._m_queue.set(self._queued, engine=self.engine_label)
        self._space.notify_all()
        return list(enumerate(take))

    # -- one engine tick ---------------------------------------------------
    def step(self) -> List[GramRequest]:
        """Drain one batch: serve a full batch if any bucket has one
        (throughput), else weighted-fair across tenants / oldest head
        across buckets (fairness — sparse buckets cannot be starved by
        popular ones); EDF within a bucket (FIFO when no deadlines or
        priorities are in play).  Runs the bucket executable over up to
        ``slots`` stacked requests — through the degradation ladder
        (retry / escalate / fail, see module docstring) — and slices
        each result back to its true shape.  Returns the requests
        finished this tick (served, degraded, failed, or pruned by the
        shedder); never raises on an executable failure."""
        if not self.waiting:
            return []
        self._poll_faults()
        with self._lock:
            done = self._prune_queues_locked()
            if not self.waiting:
                return done
            self.ticks += 1
            key = self._select_bucket_locked()
            entries = self._take_batch_locked(key)
        batch = [r for _, r in entries]

        b = self._blabel(key)
        t_batch = time.perf_counter()
        for r in batch:
            self._m_qwait.observe(t_batch - r.t_submit,
                                  engine=self.engine_label, bucket=b)
        if _trace.tracing_enabled():
            for r in batch:
                _trace.add_span("queue_wait", r.t_submit, t_batch,
                                trace_id=r.uid, bucket=b)
        self._m_fill.observe(len(batch) / self.slots,
                             engine=self.engine_label)

        entries, expired = self._expire(entries)
        done.extend(expired)
        if entries:
            dist = self._is_distributed(key)
            with _trace.span("batch", bucket=b, n=len(entries),
                             path="dist" if dist else "local"):
                if dist:
                    for _, r in entries:
                        self._serve_one_distributed(key, r)
                        done.append(r)
                else:
                    done.extend(self._serve_local(key, entries))
        return done

    # -- local (slot-batched) serving -------------------------------------
    def _serve_local(self, key, entries) -> List[GramRequest]:
        """Serve [(slot, request)] through the slot-batched local
        executable under the retry/escalation ladder."""
        M, N, dtype, gram_of = key[:4]
        health = self._bucket_health(key)
        # reused per-bucket slot stack (zeroed each batch — the "clean
        # host copy" retries restart from); jnp.dtype resolves extended
        # names ("bfloat16") numpy alone won't
        clean = self._stacks.get(key)
        if clean is None or clean.shape[0] != self.slots:
            clean = np.zeros((self.slots, M, N), jnp.dtype(dtype))
            self._stacks[key] = clean
        else:
            clean.fill(0)
        for slot, r in entries:
            m, n = r.shape
            clean[slot, :m, :n] = r.a

        b = self._blabel(key)
        attempt, last_err = 0, "unknown failure"
        while True:
            entries, expired = self._expire(entries)
            if not entries:
                return expired + [r for _, r in entries]
            rung = health.rung
            cfg = self._bucket_config(key, rung)
            site = f"gram.engine.exec.local.{M}x{N}.{dtype}.{gram_of}"
            # service-time sampling starts BEFORE the fault hook: an
            # injected exec_delay stall is real service time and must
            # inflate the shedder's estimate
            t_a0 = time.perf_counter()
            try:
                _faults.check_exec(site)
                stack = _faults.poison("poison_operand",
                                       "gram.engine.operand", clean)
                exe = self._local_executable(key, cfg)
                t_x0 = time.perf_counter()
                if _trace.tracing_enabled():
                    with jax.profiler.TraceAnnotation(f"gram_exec:{b}"):
                        out = np.asarray(exe(jnp.asarray(stack)))
                else:
                    out = np.asarray(exe(jnp.asarray(stack)))
                t_x1 = time.perf_counter()
                self._m_exec.observe(t_x1 - t_x0, engine=self.engine_label,
                                     bucket=b, path="local")
                out = _faults.poison("poison_output",
                                     "gram.engine.output", out)
                t_v0 = time.perf_counter()
                veto = self._guard(key, entries, out)
                t_v1 = time.perf_counter()
                if _trace.tracing_enabled():
                    for _, r in entries:
                        _trace.add_span("execute", t_x0, t_x1,
                                        trace_id=r.uid, bucket=b,
                                        path="local", rung=rung,
                                        attempt=attempt)
                        if self._guard_on:
                            _trace.add_span("verify", t_v0, t_v1,
                                            trace_id=r.uid, bucket=b,
                                            vetoed=veto is not None)
                if veto is None:
                    self._note_batch_seconds(key, t_x1 - t_a0)
                    if rung == 0:
                        # wall drift channel: measured executable seconds
                        # vs model bytes, per tuned bucket (rung 0 only —
                        # degraded rungs run a different config)
                        pred = self._drift_prediction(key, cfg)
                        if pred is not None:
                            self.drift.observe(
                                self._drift_key(key),
                                measured=t_x1 - t_x0, predicted=pred,
                                channel="wall",
                                config=str(self._cfg_fingerprint(cfg)))
                    break                       # success
                last_err = veto
            except Exception as e:  # noqa: BLE001 — ladder, not crash
                last_err = f"{type(e).__name__}: {e}"
            self._record_failure(key, health, _LOCAL_MAX_RUNG, last_err)
            attempt += 1
            for _, r in entries:
                r.attempts += 1
            if attempt > self.max_retries:
                for _, r in entries:
                    self._finish_failed(r, last_err)
                return expired + [r for _, r in entries]
            self._backoff(attempt, [r for _, r in entries])

        self._record_success(key, health)
        t_done = time.perf_counter()
        served_by = "local" if rung == 0 else f"local:rung{rung}"
        for slot, r in entries:
            # the result spans the gram'd dimension: cols for A^tA,
            # rows for the gram_of="rows" AA^t buckets
            n = r.shape[0] if gram_of == "rows" else r.shape[1]
            c = out[slot, :n, :n]
            if r.full:
                c = np.asarray(symmetrize_from_lower(jnp.asarray(c)))
            r.attempts += 1
            self._finish_ok(r, c, served_by=served_by,
                            degraded=rung > 0, t_done=t_done)
        return expired + [r for _, r in entries]

    # -- distributed (mesh) serving ---------------------------------------
    def _serve_one_distributed(self, key, r: GramRequest) -> None:
        """Serve one request on the mesh, walking the scheme fallback
        chain (…-> local) on failure; the mesh may shrink between
        attempts (``_poll_faults`` runs per tick, ``apply_mesh`` any
        time), so the chain is re-read every attempt."""
        M, N, dtype, gram_of = key[:4]
        m, n = r.shape
        attempt, last_err = 0, "unknown failure"
        while True:
            if (r.t_deadline is not None and
                    time.perf_counter() > r.t_deadline):
                self._finish_failed(r, "deadline exceeded")
                return
            health = self._bucket_health(key)
            if not self._is_distributed(key):
                rung_name = "local"         # mesh shrank under the bucket
            else:
                chain = self._dist_chain(key)
                rung_name = chain[min(health.rung, len(chain) - 1)]
            if rung_name == "local":
                self._serve_local(key, [(0, r)])
                return
            site = f"gram.engine.exec.{rung_name}.{M}x{N}.{dtype}"
            scheme = rung_name[len("dist:"):]
            try:
                _faults.check_exec(site)
                clean = np.zeros((M, N), jnp.dtype(dtype))
                clean[:m, :n] = r.a
                pad = _faults.poison("poison_operand",
                                     "gram.engine.operand", clean)
                exe = self._dist_executable(key, scheme,
                                            self._bucket_config(key, 0))
                t_x0 = time.perf_counter()
                c = np.asarray(jax.device_get(exe(jnp.asarray(pad))))
                t_x1 = time.perf_counter()
                b = self._blabel(key)
                self._m_exec.observe(t_x1 - t_x0, engine=self.engine_label,
                                     bucket=b, path="dist")
                _trace.add_span("execute", t_x0, t_x1, trace_id=r.uid,
                                bucket=b, path=rung_name, attempt=attempt)
                c = _faults.poison("poison_output",
                                   "gram.engine.output", c)
                c = c[:n, :n]
                with _trace.span("verify", trace_id=r.uid, bucket=b):
                    veto = self._guard(key, [(0, r)], c[None])
                if veto is None:
                    if not r.full:
                        c = np.tril(c)
                    r.attempts += 1
                    self._finish_ok(r, c, served_by=rung_name,
                                    degraded=health.rung > 0)
                    self.dist_served += 1
                    return
                last_err = veto
            except Exception as e:  # noqa: BLE001 — ladder, not crash
                last_err = f"{type(e).__name__}: {e}"
            self._record_failure(key, health,
                                 len(self._dist_chain(key)) - 1, last_err)
            attempt += 1
            r.attempts += 1
            if attempt > self.max_retries:
                self._finish_failed(r, last_err)
                return
            self._backoff(attempt, [r])

    # -- background scheduler ----------------------------------------------
    def _scheduler_alive(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def start(self) -> "GramEngine":
        """Start the background scheduler loop: after this, ``submit``
        alone drives serving and futures resolve asynchronously.
        Idempotent; ``shutdown()`` stops it.  Returns self."""
        with self._lock:
            if self._scheduler_alive():
                return self
            self._stop = False
            self._thread = threading.Thread(
                target=self._scheduler_loop,
                name=f"gram-engine-{self.engine_label}", daemon=True)
            self._thread.start()
        return self

    def _scheduler_loop(self) -> None:
        while True:
            with self._work:
                while not self._stop and self._queued == 0:
                    # bounded wait: re-check stop even if a notify races
                    self._work.wait(0.05)
                if self._stop:
                    return
            try:
                self.step()
            except Exception as e:  # noqa: BLE001 — step() is supposed
                # to absorb executable failures; anything escaping here
                # must not kill the serving thread
                _trace.instant("scheduler_error",
                               error=f"{type(e).__name__}: {e}")
                time.sleep(0.005)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request is terminal (queues empty,
        nothing in flight).  True on success, False on timeout."""
        with self._lock:
            return self._idle.wait_for(
                lambda: self._queued == 0 and self._inflight == 0, timeout)

    def shutdown(self, *, timeout: float = 10.0) -> int:
        """Stop the scheduler and fail every still-queued request
        exceptionally (``EngineShutdown``) — no future is left hanging.
        Returns the number of requests failed this way.  The engine can
        be ``start()``-ed again afterwards."""
        with self._lock:
            self._stop = True
            self._work.notify_all()
            self._space.notify_all()
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout)
        with self._lock:
            pending = [r for q in self.waiting.values() for r in q]
            self.waiting.clear()
            for r in pending:
                self._dequeue_locked(r)
            self._m_queue.set(self._queued, engine=self.engine_label)
            for r in pending:
                self._finish_failed(
                    r, "engine shutdown",
                    exc=EngineShutdown(
                        f"request {r.uid}: engine {self.engine_label} "
                        f"shut down with the request still queued"))
            self._space.notify_all()
            self._notify_idle_locked()
        return len(pending)

    def serve(self, a, *, timeout: Optional[float] = None,
              **kw) -> np.ndarray:
        """Synchronous convenience path: ``submit(...).result()`` — all
        PR 6 retry/breaker/verify semantics apply unchanged.  Steps the
        engine inline when no background scheduler is running."""
        fut = self.submit(a, **kw)
        if not self._scheduler_alive():
            ticks = 0
            while not fut.done() and ticks < 10_000:
                self.step()
                ticks += 1
        return fut.result(timeout)

    def run_to_completion(self, max_ticks: int = 10_000) \
            -> List[GramRequest]:
        if self._scheduler_alive():
            self.drain()
            return list(self.finished)
        for _ in range(max_ticks):
            if not self.waiting:
                break
            self.step()
        return list(self.finished)

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        """Serving snapshot.  Latency percentiles read this engine's
        slice of the O(1)-update log-bucketed histogram in the metrics
        registry — ``stats()`` neither re-sorts a latency list nor
        depends on ``finished`` (which is capped at ``history_cap`` and
        kept only for callers that want the request objects).  ``drift``
        carries the wall-channel cost-model findings (``obs.drift``)."""
        eng = {"engine": self.engine_label}
        bucket_keys = sorted({ek[1] for ek in self._executables})
        return {
            "served": self.served,
            "failed": self.failed,
            "degraded_served": self.degraded_served,
            "retries": self.retries,
            "guard_failures": self.guard_failures,
            "mesh_changes": self.mesh_changes,
            "dist_served": self.dist_served,
            "ticks": self.ticks,
            "compile_count": self.compile_count,
            "buckets": bucket_keys,
            "distributed_buckets": sorted(
                k for k in bucket_keys if self._is_distributed(k)),
            "quarantined": {str(k): list(h.quarantined)
                            for k, h in self._health.items()
                            if h.quarantined},
            "history_cap": self.history_cap,
            "engine": self.engine_label,
            "queue_depth": self._queued,
            "queue_peak": self.queue_peak,
            "inflight": self._inflight,
            "shed": self.shed,
            "cancelled": self.cancelled,
            "deadline_missed": self.deadline_missed,
            "scheduler_running": self._scheduler_alive(),
            "sec_per_work_unit": self._sec_per_unit,
            "ring": {
                "depth": self.ring_depth,
                "hits": sum(rg.hits for rg in self._rings.values()),
                "misses": sum(rg.misses for rg in self._rings.values()),
            },
            "admission": {
                "mode": self.admission,
                "max_queue": self.max_queue,
                "max_queue_per_bucket": self.max_queue_per_bucket,
                "tenant_quota": self.tenant_quota,
                "tenant_max_inflight": self.tenant_max_inflight,
                "deadline_shedding": self.deadline_shedding,
            },
            "tenants": {name: ts.snapshot()
                        for name, ts in sorted(self._tenants.items())},
            "p50_latency_s": self._m_latency.quantile(0.50, eng),
            "p99_latency_s": self._m_latency.quantile(0.99, eng),
            "drift": [f.as_dict() for f in self.drift.findings("wall")],
        }
