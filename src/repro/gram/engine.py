"""GramEngine: slot-based multi-tenant batched A^tA serving.

The serving analogue of ``runtime/serving.py``'s continuous-batching KV
engine, for the paper's operation instead of token decode:

* **Bucketing.**  Request shapes are rounded up to power-of-two buckets
  (``gram.autotune.bucket_shape``) — exact for Gram, because zero rows of
  A add nothing to A^tA and zero columns only add zero rows/columns to C
  that are sliced away on completion.
* **Slot batching.**  Each tick drains up to ``slots`` same-bucket
  requests, stacks them (padding the batch with zero matrices when fewer
  are waiting) and runs ONE vmapped ATA over the stack — the fused Pallas
  schedule on TPU, the XLA reference recursion elsewhere
  (``core.ata.resolve_mode``).
* **Bounded recompiles.**  Executables are cached per
  ``(bucket_m, bucket_n, dtype)``; because the batch is always padded to
  exactly ``slots`` entries, a mixed trace costs at most one compilation
  per distinct bucket key (``compile_count``; the acceptance test pins
  ``compile_count <= len(buckets)`` on a 64-request trace).
* **Autotuned per-bucket config.**  On first touch of a bucket the
  engine consults the ``gram.autotune`` JSON cache; a hit overrides
  mode / levels / block for that bucket's executable.
* **Mesh-aware distributed routing.**  With ``mesh=`` set, buckets whose
  padded size reaches ``dist_threshold`` elements are served through
  ``core.distributed.distributed_gram`` (``dist_scheme`` — default
  "auto", the communication cost model picks allreduce / reducescatter /
  half-ring / 2.5D bfs25d per shape) instead of the single-device
  vmapped executable; small buckets keep the slot-batched local path.
"""
from __future__ import annotations

import itertools
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ata import ata, ata_full
from ..core.distributed import (default_gram_axes, distributed_gram,
                                feasible_schemes)
from ..core.symmetry import symmetrize_from_lower
from . import autotune as _autotune

__all__ = ["GramEngine", "GramRequest", "batched_gram"]


def batched_gram(blocks: jax.Array, *, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Full symmetric Gram of a (K, m, n) stack -> (K, n, n), vmapped
    through the mode-dispatched ATA path (fused kernel on TPU).

    The batched building block of the service layer; also the in-repo
    consumer hook for ``optim/shampoo.py``'s per-block statistics.
    """
    if blocks.ndim != 3:
        raise ValueError(f"batched_gram expects (K, m, n), got {blocks.shape}")
    return jax.vmap(lambda b: ata_full(
        b, levels=levels, leaf=leaf, variant=variant, mode=mode,
        out_dtype=out_dtype, block=block, interpret=interpret))(blocks)


@dataclass
class GramRequest:
    uid: int
    a: np.ndarray                     # host copy; padded/stacked at batch time
    shape: Tuple[int, int]
    full: bool                        # symmetric result vs lower triangle
    gram_of: str                      # "cols" (A^tA) | "rows" (AA^t)
    t_submit: float
    t_done: Optional[float] = None
    result: Optional[np.ndarray] = None
    done: bool = False

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


class GramEngine:
    """Multi-tenant batched Gram service (see module docstring)."""

    def __init__(self, *, slots: int = 4, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=jnp.float32, min_bucket: int = 32,
                 use_autotune_cache: bool = True,
                 interpret: Optional[bool] = None,
                 mesh=None, dist_scheme: str = "auto",
                 dist_threshold: int = 1 << 21):
        self.slots = slots
        self.levels, self.leaf, self.variant = levels, leaf, variant
        self.mode, self.block = mode, block
        self.out_dtype = jnp.dtype(out_dtype)
        self.min_bucket = min_bucket
        self.use_autotune_cache = use_autotune_cache
        self.interpret = interpret
        # distributed routing: buckets of >= dist_threshold elements go to
        # distributed_gram on `mesh` (axis names per default_gram_axes)
        self.mesh = mesh
        self.dist_scheme = dist_scheme
        self.dist_threshold = dist_threshold
        self.dist_axes = default_gram_axes(mesh) if mesh is not None else {}
        self.dist_served = 0
        self._uid = itertools.count()
        # bucket key -> FIFO of waiting requests (insertion-ordered so
        # tick scheduling is deterministic)
        self.waiting: "OrderedDict[tuple, List[GramRequest]]" = OrderedDict()
        self.finished: List[GramRequest] = []
        self._executables: Dict[tuple, object] = {}
        self.compile_count = 0
        self.served = 0
        self.ticks = 0

    # -- request intake ----------------------------------------------------
    def submit(self, a, *, full: bool = True,
               gram_of: str = "cols") -> int:
        """Enqueue one Gram request; returns its uid.  ``full`` selects the
        mirrored symmetric C (default) vs the lower triangle only;
        ``gram_of="rows"`` serves ``a @ a.T`` (the Arrigoni-Massini row
        gram — the ``aat`` leaf program on the fused path) instead of the
        default ``a.T @ a``."""
        a = np.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"gram request must be 2-D, got {a.shape}")
        if gram_of not in ("cols", "rows"):
            raise ValueError(f"gram_of must be 'cols' or 'rows', got "
                             f"{gram_of!r}")
        r = GramRequest(uid=next(self._uid), a=a, shape=a.shape, full=full,
                        gram_of=gram_of, t_submit=time.perf_counter())
        key = self._bucket_key(a.shape, a.dtype, gram_of)
        self.waiting.setdefault(key, []).append(r)
        return r.uid

    def _bucket_key(self, shape, dtype, gram_of: str = "cols") -> tuple:
        M, N = _autotune.bucket_shape(*shape, min_side=self.min_bucket)
        return (M, N, jnp.dtype(dtype).name, gram_of)

    # -- executable cache --------------------------------------------------
    def _bucket_config(self, key) -> dict:
        """Engine config for one bucket; the autotune winner fills in only
        the knobs the caller left open (mode/levels "auto", block None) —
        explicit engine arguments always win.  Mode/levels are adopted
        only from *measured* entries (wall-clock-backed: a model-only
        entry must not flip the backend-appropriate "auto" dispatch);
        block sizes only from fused winners (reference entries carry
        placeholder blocks)."""
        M, N, dtype, gram_of = key
        cfg = {"mode": self.mode, "levels": self.levels, "leaf": self.leaf,
               "variant": self.variant, "block": self.block}
        if self.use_autotune_cache:
            try:
                hit = _autotune.lookup(
                    M, N, dtype=dtype,
                    kind="aat" if gram_of == "rows" else "ata",
                    min_side=self.min_bucket)
            except Exception:
                hit = None
            if hit:
                if hit.get("source") == "measured":
                    if cfg["mode"] == "auto":
                        cfg["mode"] = hit["mode"]
                    if cfg["levels"] == "auto":
                        cfg["levels"] = hit["levels"]
                if cfg["block"] is None and hit.get("mode") == "fused":
                    cfg["block"] = hit.get("bk")
        return cfg

    def _is_distributed(self, key) -> bool:
        """Buckets at/above the element threshold route to the mesh (when
        one is configured and the configured scheme fits the bucket — for
        "auto", any feasible scheme; otherwise dist_scheme itself must be
        feasible, or the bucket stays local rather than failing mid-step
        on a shard_map divisibility error)."""
        M, N, _, gram_of = key
        if gram_of == "rows":
            # the distributed schemes decompose A^t A; row-gram buckets
            # stay on the local aat executor
            return False
        if self.mesh is None or M * N < self.dist_threshold:
            return False
        feas = feasible_schemes(M, N, self.mesh, **self.dist_axes)
        if self.dist_scheme == "auto":
            return bool(feas)
        return self.dist_scheme in feas

    def _executable(self, key):
        if key in self._executables:
            return self._executables[key]
        M, N, dtype, gram_of = key
        cfg = self._bucket_config(key)
        if self._is_distributed(key):
            # one request at a time on the whole mesh: the mesh IS the
            # batch dimension here, slot-stacking would fight the sharding
            # (autotuned mode/levels still apply; block resolves inside
            # the per-shard kernels via the ops-level autotune defaults)
            def one(x):
                return distributed_gram(
                    x, self.mesh, scheme=self.dist_scheme,
                    levels=cfg["levels"], leaf=cfg["leaf"],
                    variant=cfg["variant"], mode=cfg["mode"],
                    out_dtype=self.out_dtype, interpret=self.interpret,
                    **self.dist_axes)
            spec = jax.ShapeDtypeStruct((M, N), jnp.dtype(dtype))
        else:
            def single(x):
                return ata(x, gram_of=gram_of, levels=cfg["levels"],
                           leaf=cfg["leaf"], variant=cfg["variant"],
                           mode=cfg["mode"], out_dtype=self.out_dtype,
                           block=cfg["block"], interpret=self.interpret)
            one = jax.vmap(single)
            spec = jax.ShapeDtypeStruct((self.slots, M, N),
                                        jnp.dtype(dtype))
        compiled = jax.jit(one).lower(spec).compile()
        self.compile_count += 1
        self._executables[key] = compiled
        return compiled

    def prewarm(self, shapes, dtype=jnp.float32) -> int:
        """Build executables for the buckets covering ``shapes`` ahead of
        traffic (steady-state serving pays no first-request compile).
        Returns the number of compilations triggered."""
        before = self.compile_count
        for shape in shapes:
            self._executable(self._bucket_key(shape, dtype))
        return self.compile_count - before

    # -- one engine tick ---------------------------------------------------
    def step(self) -> List[GramRequest]:
        """Drain one batch: serve a full batch if any bucket has one
        (throughput), else the bucket whose head request has waited
        longest (fairness — sparse buckets cannot be starved by popular
        ones); FIFO within a bucket.  Runs the bucket executable over up
        to ``slots`` stacked requests and slices each result back to its
        true shape.  Returns the requests finished this tick."""
        if not self.waiting:
            return []
        self.ticks += 1
        full = [k for k, q in self.waiting.items() if len(q) >= self.slots]
        key = min(full or self.waiting,
                  key=lambda k: self.waiting[k][0].t_submit)
        queue = self.waiting[key]
        batch, rest = queue[:self.slots], queue[self.slots:]
        if rest:
            self.waiting[key] = rest
        else:
            del self.waiting[key]

        M, N, dtype, gram_of = key
        if self._is_distributed(key):
            # mesh path: the device mesh is the parallel dimension — serve
            # the drained requests one at a time through distributed_gram
            exe = self._executable(key)
            for r in batch:
                m, n = r.shape
                pad = np.zeros((M, N), jnp.dtype(dtype))
                pad[:m, :n] = r.a
                c = np.asarray(jax.device_get(exe(jnp.asarray(pad))))[:n, :n]
                if not r.full:
                    c = np.tril(c)
                r.result, r.t_done, r.done = c, time.perf_counter(), True
                r.a = None
                self.finished.append(r)
            self.dist_served += len(batch)
            self.served += len(batch)
            return batch

        # jnp.dtype resolves extended names ("bfloat16") numpy alone won't
        stack = np.zeros((self.slots, M, N), jnp.dtype(dtype))
        for s, r in enumerate(batch):
            m, n = r.shape
            stack[s, :m, :n] = r.a
        out = np.asarray(self._executable(key)(jnp.asarray(stack)))
        t_done = time.perf_counter()
        for s, r in enumerate(batch):
            # the result spans the gram'd dimension: cols for A^tA,
            # rows for the gram_of="rows" AA^t buckets
            n = r.shape[0] if gram_of == "rows" else r.shape[1]
            c = out[s, :n, :n]
            if r.full:
                c = np.asarray(symmetrize_from_lower(jnp.asarray(c)))
            r.result, r.t_done, r.done = c, t_done, True
            r.a = None                      # free the host copy
            self.finished.append(r)
        self.served += len(batch)
        return batch

    def run_to_completion(self, max_ticks: int = 10_000) \
            -> List[GramRequest]:
        for _ in range(max_ticks):
            if not self.waiting:
                break
            self.step()
        return self.finished

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        lats = sorted(r.latency_s for r in self.finished
                      if r.latency_s is not None)

        def pct(p):
            return lats[min(int(p * len(lats)), len(lats) - 1)] \
                if lats else None
        return {
            "served": self.served,
            "dist_served": self.dist_served,
            "ticks": self.ticks,
            "compile_count": self.compile_count,
            "buckets": sorted(self._executables),
            "distributed_buckets": sorted(
                k for k in self._executables if self._is_distributed(k)),
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
        }
