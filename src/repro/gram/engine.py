"""GramEngine: slot-based multi-tenant batched A^tA serving.

The serving analogue of ``runtime/serving.py``'s continuous-batching KV
engine, for the paper's operation instead of token decode:

* **Bucketing.**  Request shapes are rounded up to power-of-two buckets
  (``gram.autotune.bucket_shape``) — exact for Gram, because zero rows of
  A add nothing to A^tA and zero columns only add zero rows/columns to C
  that are sliced away on completion.
* **Slot batching.**  Each tick drains up to ``slots`` same-bucket
  requests, stacks them (padding the batch with zero matrices when fewer
  are waiting) and runs ONE vmapped ATA over the stack — the fused Pallas
  schedule on TPU, the XLA reference recursion elsewhere
  (``core.ata.resolve_mode``).
* **Bounded recompiles.**  Executables are cached per
  ``(bucket_m, bucket_n, dtype)``; because the batch is always padded to
  exactly ``slots`` entries, a mixed trace costs at most one compilation
  per distinct bucket key (``compile_count``; the acceptance test pins
  ``compile_count <= len(buckets)`` on a 64-request trace).
* **Autotuned per-bucket config.**  On first touch of a bucket the
  engine consults the ``gram.autotune`` JSON cache; a hit overrides
  mode / levels / block for that bucket's executable.
* **Mesh-aware distributed routing.**  With ``mesh=`` set, buckets whose
  padded size reaches ``dist_threshold`` elements are served through
  ``core.distributed.distributed_gram`` (``dist_scheme`` — default
  "auto", the communication cost model picks allreduce / reducescatter /
  half-ring / 2.5D bfs25d per shape) instead of the single-device
  vmapped executable; small buckets keep the slot-batched local path.

Failure model (DESIGN.md §13).  Serving "fast when everything works" is
not serving: devices drop, low-precision tiles overflow, a wedged
executable is an outage.  Every batch therefore runs inside a
**degradation ladder**:

* **Output guards** (``gram.verify``): a NaN/Inf scan plus — when
  ``verify`` asks for probes — a randomized Freivalds identity check
  (x^t C x vs ||Ax||^2) and diagonal nonnegativity, on every served
  result.  A guard failure is treated exactly like a crashed executable.
* **Bounded retry with backoff**: a failed attempt (exception, injected
  fault, guard veto) retries up to ``max_retries`` times with
  exponential backoff, always from the clean host copy of the operands.
* **Circuit breaker / health ladder**: per-bucket health counters
  escalate a persistently failing bucket down a config ladder — first
  quarantining its autotune winner, then forcing ``mode="reference"``,
  then ``levels=0`` (classical) — so a poisoned tuned config cannot take
  the bucket down permanently.
* **Distributed scheme fallback**: distributed buckets walk
  ``core.distributed.scheme_fallback_chain`` (bfs25d -> ring ->
  reducescatter -> allreduce -> local single-device) when a scheme's
  executable fails; a **mesh shrink** (lost replica group — injected via
  ``runtime.faults`` in drills, ``apply_mesh`` in production) invalidates
  the distributed executables and rebuilds the chain on the surviving
  sub-mesh.
* **Deadlines**: a request past its ``deadline_s`` is failed fast
  instead of holding its batch hostage.

Requests that exhaust the ladder are marked ``status="failed"`` with the
error preserved — ``step()`` never propagates an executable exception,
so one poisoned bucket cannot wedge ``run_to_completion``.

Flight recorder (DESIGN.md §14).  The full request lifecycle — submit →
queue-wait → batch → compile → execute (local or ``dist:scheme``) →
verify → retry/backoff → rung transition → done — emits request-scoped
spans and instants through ``obs.trace`` (one Perfetto-loadable
timeline, shared with ``runtime.faults`` firings and guard vetoes), every
serving count lands in the ``obs.metrics`` registry labeled by
(engine, bucket, served_by), and each successful batch feeds an
``obs.drift.DriftDetector`` comparing measured executable wall clock
(and, at compile time, HLO-census traffic) against the
``cost_model``/traffic-model predictions — ``stats()["drift"]`` surfaces
buckets whose autotuned winner has drifted from its model, and
``invalidate_drifted()`` drops those winners from the autotune cache.
"""
from __future__ import annotations

import itertools
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ata import ata, ata_full, ata_levels_for
from ..core.distributed import (default_gram_axes, distributed_gram,
                                feasible_schemes, scheme_fallback_chain,
                                shrink_mesh)
from ..core.strassen import AUTO_MAX_LEVELS, resolve_mode
from ..core.symmetry import symmetrize_from_lower
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..obs.drift import DriftDetector
from ..runtime import faults as _faults
from . import autotune as _autotune
from . import verify as _verify

__all__ = ["GramEngine", "GramRequest", "BucketHealth", "batched_gram"]


def batched_gram(blocks: jax.Array, *, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Full symmetric Gram of a (K, m, n) stack -> (K, n, n), vmapped
    through the mode-dispatched ATA path (fused kernel on TPU).

    The batched building block of the service layer; also the in-repo
    consumer hook for ``optim/shampoo.py``'s per-block statistics.
    """
    if blocks.ndim != 3:
        raise ValueError(f"batched_gram expects (K, m, n), got {blocks.shape}")
    return jax.vmap(lambda b: ata_full(
        b, levels=levels, leaf=leaf, variant=variant, mode=mode,
        out_dtype=out_dtype, block=block, interpret=interpret))(blocks)


@dataclass
class GramRequest:
    uid: int
    a: np.ndarray                     # host copy; padded/stacked at batch time
    shape: Tuple[int, int]
    full: bool                        # symmetric result vs lower triangle
    gram_of: str                      # "cols" (A^tA) | "rows" (AA^t)
    t_submit: float
    deadline_s: Optional[float] = None  # fail fast past t_submit + deadline
    t_done: Optional[float] = None
    result: Optional[np.ndarray] = None
    done: bool = False
    status: str = "pending"           # -> "ok" | "failed"
    error: Optional[str] = None
    attempts: int = 0                 # executable attempts spent on it
    degraded: bool = False            # served below the bucket's first rung
    served_by: Optional[str] = None   # "local" | "local:rungK" | "dist:SCHEME"
    verified: Optional[bool] = None   # output guards ran and passed

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclass
class BucketHealth:
    """Per-bucket circuit-breaker state (one per executable family)."""
    rung: int = 0                     # current degradation-ladder rung
    consecutive_failures: int = 0
    failures: int = 0
    successes: int = 0
    quarantined: List[str] = field(default_factory=list)  # rung descriptions


# local ladder: 0 = autotuned config, 1 = autotune winner quarantined,
# 2 = reference (XLA) mode, 3 = reference + classical recursion
_LOCAL_MAX_RUNG = 3


class GramEngine:
    """Multi-tenant batched Gram service (see module docstring)."""

    _ids = itertools.count()   # per-process engine label allocator

    def __init__(self, *, slots: int = 4, levels: Union[int, str] = 1,
                 leaf: int = 256, variant: str = "strassen",
                 mode: str = "auto", block: Optional[int] = None,
                 out_dtype=jnp.float32, min_bucket: int = 32,
                 use_autotune_cache: bool = True,
                 interpret: Optional[bool] = None,
                 mesh=None, dist_scheme: str = "auto",
                 dist_threshold: int = 1 << 21,
                 verify: Union[None, str, int] = "finite",
                 verify_rtol: Optional[float] = None,
                 verify_seed: int = 0,
                 max_retries: int = 3, backoff_s: float = 0.0,
                 breaker_threshold: int = 2,
                 history_cap: int = 1024, drift_theta: float = 2.0,
                 drift: Optional[DriftDetector] = None):
        self.slots = slots
        self.levels, self.leaf, self.variant = levels, leaf, variant
        self.mode, self.block = mode, block
        self.out_dtype = jnp.dtype(out_dtype)
        self.min_bucket = min_bucket
        self.use_autotune_cache = use_autotune_cache
        self.interpret = interpret
        # distributed routing: buckets of >= dist_threshold elements go to
        # distributed_gram on `mesh` (axis names per default_gram_axes)
        self.mesh = mesh
        self.dist_scheme = dist_scheme
        self.dist_threshold = dist_threshold
        self.dist_axes = default_gram_axes(mesh) if mesh is not None else {}
        self.dist_served = 0
        # failure model knobs: `verify` is None/"off" (no guards),
        # "finite" (NaN/Inf + diagonal scan — the default) or an int k
        # (finite scan + k Freivalds probes per served result)
        if verify in (None, "off", False, 0):
            self._guard_on, self._probes = False, 0
        elif verify == "finite":
            self._guard_on, self._probes = True, 0
        else:
            self._guard_on, self._probes = True, int(verify)
        self.verify_rtol = verify_rtol
        self._verify_rng = np.random.default_rng(verify_seed)
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.breaker_threshold = max(1, breaker_threshold)
        self._uid = itertools.count()
        # bucket key -> FIFO of waiting requests (insertion-ordered so
        # tick scheduling is deterministic)
        self.waiting: "OrderedDict[tuple, List[GramRequest]]" = OrderedDict()
        # finished history is CAPPED: the flight-recorder discipline —
        # stats() reads the metrics histograms, not this buffer, so a
        # long-running service neither grows without bound nor re-sorts
        # its whole past on every scrape
        self.history_cap = max(1, history_cap)
        self.finished: "deque[GramRequest]" = deque(maxlen=self.history_cap)
        self._executables: Dict[tuple, object] = {}
        self._health: Dict[tuple, BucketHealth] = {}
        self._dist_chains: Dict[tuple, List[str]] = {}
        self._mesh_epoch = 0
        self.compile_count = 0
        self.served = 0
        self.failed = 0
        self.degraded_served = 0
        self.retries = 0
        self.guard_failures = 0
        self.mesh_changes = 0
        self.ticks = 0
        # observability: per-engine metric label into the process-wide
        # registry, plus the cost-model drift detector fed one sample per
        # successful rung-0 batch (wall) and per compile (HLO traffic)
        self.engine_label = f"e{next(GramEngine._ids)}"
        self.drift = drift if drift is not None \
            else DriftDetector(theta=drift_theta)
        self._drift_pred_cache: Dict[tuple, Optional[float]] = {}
        self._m_requests = _metrics.counter(
            "gram_requests_total", "requests submitted")
        self._m_served = _metrics.counter(
            "gram_served_total", "requests served ok, by served_by")
        self._m_failed = _metrics.counter(
            "gram_failed_total", "requests finished failed")
        self._m_deadline = _metrics.counter(
            "gram_deadline_expired_total", "requests failed on deadline")
        self._m_retries = _metrics.counter(
            "gram_retries_total", "failed executable attempts retried")
        self._m_vetoes = _metrics.counter(
            "gram_guard_vetoes_total", "output-guard vetoes")
        self._m_rung = _metrics.counter(
            "gram_rung_transitions_total", "degradation-ladder escalations")
        self._m_compiles = _metrics.counter(
            "gram_compiles_total", "executable compilations")
        self._m_exec_cache = _metrics.counter(
            "gram_exec_cache_total", "executable-cache lookups by outcome")
        self._m_queue = _metrics.gauge(
            "gram_queue_depth", "requests waiting across buckets")
        self._m_latency = _metrics.histogram(
            "gram_request_latency_s", "submit -> done seconds")
        self._m_qwait = _metrics.histogram(
            "gram_queue_wait_s", "submit -> batch-drain seconds")
        self._m_fill = _metrics.histogram(
            "gram_batch_fill", "live requests / slots per drained batch",
            lo=1.0 / 64, hi=2.0)
        self._m_exec = _metrics.histogram(
            "gram_exec_s", "executable wall seconds per batch attempt")

    # -- request intake ----------------------------------------------------
    def submit(self, a, *, full: bool = True, gram_of: str = "cols",
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one Gram request; returns its uid.  ``full`` selects the
        mirrored symmetric C (default) vs the lower triangle only;
        ``gram_of="rows"`` serves ``a @ a.T`` (the Arrigoni-Massini row
        gram — the ``aat`` leaf program on the fused path) instead of the
        default ``a.T @ a``.  ``deadline_s`` (relative to submission) lets
        the engine fail the request fast instead of retrying past its
        usefulness."""
        a = np.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"gram request must be 2-D, got {a.shape}")
        if gram_of not in ("cols", "rows"):
            raise ValueError(f"gram_of must be 'cols' or 'rows', got "
                             f"{gram_of!r}")
        r = GramRequest(uid=next(self._uid), a=a, shape=a.shape, full=full,
                        gram_of=gram_of, t_submit=time.perf_counter(),
                        deadline_s=deadline_s)
        key = self._bucket_key(a.shape, a.dtype, gram_of)
        self.waiting.setdefault(key, []).append(r)
        b = self._blabel(key)
        self._m_requests.inc(engine=self.engine_label, bucket=b)
        self._m_queue.set(sum(len(q) for q in self.waiting.values()),
                          engine=self.engine_label)
        _trace.instant("submit", trace_id=r.uid, bucket=b,
                       shape=f"{a.shape[0]}x{a.shape[1]}", gram_of=gram_of)
        return r.uid

    def _bucket_key(self, shape, dtype, gram_of: str = "cols") -> tuple:
        M, N = _autotune.bucket_shape(*shape, min_side=self.min_bucket)
        return (M, N, jnp.dtype(dtype).name, gram_of)

    @staticmethod
    def _blabel(key) -> str:
        """Metric/trace label for one bucket key."""
        M, N, dtype, gram_of = key
        return f"{M}x{N}/{dtype}/{gram_of}"

    @staticmethod
    def _drift_key(key) -> str:
        """Drift-detector key: the bucket in autotune's vocabulary (the
        `kind` the winner was tuned for), so a finding maps 1:1 onto a
        cache entry ``invalidate_drifted`` can drop."""
        M, N, dtype, gram_of = key
        return f"{M}x{N}/{dtype}/{'aat' if gram_of == 'rows' else 'ata'}"

    # -- degradation ladder ------------------------------------------------
    def _bucket_health(self, key) -> BucketHealth:
        return self._health.setdefault(key, BucketHealth())

    def _bucket_config(self, key, rung: int = 0) -> dict:
        """Engine config for one bucket at one ladder rung.

        Rung 0 behaves as always: the autotune winner fills in only the
        knobs the caller left open (mode/levels "auto", block None) —
        explicit engine arguments always win.  Mode/levels are adopted
        only from *measured* entries (wall-clock-backed: a model-only
        entry must not flip the backend-appropriate "auto" dispatch);
        block sizes only from fused winners (reference entries carry
        placeholder blocks).  Higher rungs degrade: 1 skips the autotune
        winner (quarantine), 2 forces the XLA reference recursion, 3 adds
        ``levels=0`` (classical — no fast-variant arithmetic at all).
        """
        M, N, dtype, gram_of = key
        cfg = {"mode": self.mode, "levels": self.levels, "leaf": self.leaf,
               "variant": self.variant, "block": self.block}
        if self.use_autotune_cache and rung == 0:
            try:
                hit = _autotune.lookup(
                    M, N, dtype=dtype,
                    kind="aat" if gram_of == "rows" else "ata",
                    min_side=self.min_bucket)
            except Exception:
                hit = None
            if hit:
                if hit.get("source") == "measured":
                    if cfg["mode"] == "auto":
                        cfg["mode"] = hit["mode"]
                    if cfg["levels"] == "auto":
                        cfg["levels"] = hit["levels"]
                if cfg["block"] is None and hit.get("mode") == "fused":
                    cfg["block"] = hit.get("bk")
        if rung >= 2:
            cfg["mode"] = "reference"
        if rung >= 3:
            cfg["levels"] = 0
        return cfg

    def _record_failure(self, key, health: BucketHealth, max_rung: int,
                        reason: str):
        """One failed attempt: bump counters; trip the breaker (escalate
        the rung, stickily) after ``breaker_threshold`` consecutive
        failures."""
        health.failures += 1
        health.consecutive_failures += 1
        self.retries += 1
        b = self._blabel(key)
        self._m_retries.inc(engine=self.engine_label, bucket=b)
        _trace.instant("retry", bucket=b, reason=reason)
        if (health.consecutive_failures >= self.breaker_threshold
                and health.rung < max_rung):
            health.rung += 1
            health.consecutive_failures = 0
            health.quarantined.append(
                f"rung{health.rung - 1}: {reason}")
            self._m_rung.inc(engine=self.engine_label, bucket=b,
                             rung=health.rung)
            _trace.instant("rung_transition", bucket=b, rung=health.rung,
                           reason=reason)

    def _record_success(self, key, health: BucketHealth):
        health.successes += 1
        health.consecutive_failures = 0

    def _backoff(self, attempt: int, batch: List[GramRequest]):
        if self.backoff_s <= 0:
            return
        wait = self.backoff_s * (2 ** (attempt - 1))
        # never sleep past the tightest live deadline
        now = time.perf_counter()
        for r in batch:
            if r.deadline_s is not None:
                wait = min(wait, max(0.0,
                                     r.t_submit + r.deadline_s - now))
        if wait > 0:
            time.sleep(wait)

    def _expire(self, entries):
        """Split [(slot, request)] into (live, newly-expired-and-failed)."""
        now = time.perf_counter()
        live, expired = [], []
        for slot, r in entries:
            if (r.deadline_s is not None
                    and now > r.t_submit + r.deadline_s):
                self._finish_failed(r, "deadline exceeded")
                expired.append(r)
            else:
                live.append((slot, r))
        return live, expired

    # -- completion bookkeeping -------------------------------------------
    def _finish_ok(self, r: GramRequest, c: np.ndarray, *, served_by: str,
                   degraded: bool, t_done: Optional[float] = None):
        b = self._blabel(self._bucket_key(r.shape, r.a.dtype, r.gram_of))
        r.result = c
        r.status, r.done = "ok", True
        r.t_done = t_done if t_done is not None else time.perf_counter()
        r.degraded = degraded
        r.served_by = served_by
        r.verified = True if self._guard_on else None
        r.a = None                      # free the host copy
        self.finished.append(r)
        self.served += 1
        if degraded:
            self.degraded_served += 1
        self._m_served.inc(engine=self.engine_label, bucket=b,
                           served_by=served_by)
        self._m_latency.observe(r.latency_s, engine=self.engine_label,
                                bucket=b)
        _trace.instant("done", trace_id=r.uid, status="ok",
                       served_by=served_by)
        _trace.add_span("request", r.t_submit, r.t_done, trace_id=r.uid,
                        bucket=b, status="ok", served_by=served_by,
                        attempts=r.attempts)

    def _finish_failed(self, r: GramRequest, error: str):
        b = self._blabel(self._bucket_key(r.shape, r.a.dtype, r.gram_of))
        r.status, r.done = "failed", True
        r.error = error
        r.t_done = time.perf_counter()
        r.a = None
        self.finished.append(r)
        self.failed += 1
        self._m_failed.inc(engine=self.engine_label, bucket=b)
        if error.startswith("deadline"):
            self._m_deadline.inc(engine=self.engine_label, bucket=b)
        self._m_latency.observe(r.latency_s, engine=self.engine_label,
                                bucket=b)
        _trace.instant("done", trace_id=r.uid, status="failed", error=error)
        _trace.add_span("request", r.t_submit, r.t_done, trace_id=r.uid,
                        bucket=b, status="failed", error=error,
                        attempts=r.attempts)

    # -- output guards -----------------------------------------------------
    def _guard(self, key, entries, out) -> Optional[str]:
        """Run the output guards over a served batch; None when every
        result passes, else a reason string (the whole batch retries —
        corruption is a property of the executable run, not a request).

        The finite scan runs ONCE over the whole slot stack (padding
        slots are exact zeros, so they never veto) — one vectorized pass
        instead of per-request slices keeps the default-on guard off the
        latency profile; per-request work (diagonal, probes) only touches
        the small diag vector unless probes are enabled."""
        if not self._guard_on:
            return None
        M, N, dtype, gram_of = key
        # fast path: one float64 reduction (any NaN/Inf propagates); the
        # full scan only confirms — a float64 *overflow* in the reduction
        # of huge-but-finite values must not veto a correct result
        if not np.isfinite(np.sum(out, dtype=np.float64)) \
                and not np.isfinite(out).all():
            self._veto(key, "non_finite")
            return "guard veto: non-finite entries in served batch"
        rtol = self.verify_rtol
        if rtol is None:
            rtol = _verify.default_rtol(dtype)
        for slot, r in entries:
            n = r.shape[0] if gram_of == "rows" else r.shape[1]
            c = out[slot, :n, :n] if out.ndim == 3 else out[:n, :n]
            d = np.diagonal(c).astype(np.float64)
            scale = float(np.abs(d).max()) if d.size else 0.0
            if not (d >= -rtol * max(scale, 1.0)).all():
                self._veto(key, "negative_diagonal", uid=r.uid)
                return f"guard veto on request {r.uid}: negative diagonal"
            if self._probes:
                ok, worst = _verify.freivalds_gram(
                    r.a, c, probes=self._probes, rtol=rtol,
                    gram_of=gram_of, full=False, rng=self._verify_rng)
                if not ok:
                    self._veto(key, "freivalds", uid=r.uid)
                    return (f"guard veto on request {r.uid}: freivalds "
                            f"identity violated (rel err {worst:.3e})")
        return None

    def _veto(self, key, reason: str, uid: Optional[int] = None) -> None:
        """One guard veto: counter + an instant on the shared timeline."""
        self.guard_failures += 1
        self._m_vetoes.inc(engine=self.engine_label,
                           bucket=self._blabel(key))
        _trace.instant("guard_veto", trace_id=uid, reason=reason,
                       bucket=self._blabel(key))

    # -- mesh lifecycle ----------------------------------------------------
    def apply_mesh(self, mesh) -> None:
        """Adopt a new (typically shrunk) device mesh mid-run: recompute
        the distributed axis mapping, invalidate every distributed
        executable and fallback chain, and reset distributed buckets'
        ladder rungs (the old rung judged the old mesh's schemes)."""
        dist_keys = [k for k in self._health if self._is_distributed(k)]
        self.mesh = mesh
        self.dist_axes = default_gram_axes(mesh) if mesh is not None else {}
        self._mesh_epoch += 1
        self.mesh_changes += 1
        self._dist_chains.clear()
        self._executables = {ek: exe for ek, exe in self._executables.items()
                             if ek[0] != "dist"}
        for k in dist_keys:
            self._health[k].rung = 0
            self._health[k].consecutive_failures = 0

    def _poll_faults(self):
        """Chaos hook: an armed ``mesh_shrink`` fault drops one replica
        group from the serving mesh (``runtime.faults``)."""
        if self.mesh is None:
            return
        if _faults.fire("mesh_shrink", "gram.engine.mesh"):
            new = shrink_mesh(self.mesh)
            if new is not None:
                self.apply_mesh(new)

    # -- executable cache --------------------------------------------------
    @staticmethod
    def _cfg_fingerprint(cfg) -> tuple:
        return (cfg["mode"], str(cfg["levels"]), cfg["leaf"],
                cfg["variant"], cfg["block"])

    def _local_executable(self, key, cfg):
        M, N, dtype, gram_of = key
        ekey = ("local", key, self._cfg_fingerprint(cfg))
        if ekey in self._executables:
            self._m_exec_cache.inc(engine=self.engine_label, path="local",
                                   outcome="hit")
            return self._executables[ekey]
        self._m_exec_cache.inc(engine=self.engine_label, path="local",
                               outcome="miss")

        def single(x):
            return ata(x, gram_of=gram_of, levels=cfg["levels"],
                       leaf=cfg["leaf"], variant=cfg["variant"],
                       mode=cfg["mode"], out_dtype=self.out_dtype,
                       block=cfg["block"], interpret=self.interpret)
        spec = jax.ShapeDtypeStruct((self.slots, M, N), jnp.dtype(dtype))
        with _trace.span("compile", bucket=self._blabel(key), path="local",
                         mode=str(cfg["mode"]), levels=str(cfg["levels"])):
            compiled = jax.jit(jax.vmap(single)).lower(spec).compile()
        self.compile_count += 1
        self._m_compiles.inc(engine=self.engine_label,
                             bucket=self._blabel(key), path="local")
        self._observe_traffic(key, cfg, compiled)
        self._executables[ekey] = compiled
        return compiled

    def _dist_executable(self, key, scheme, cfg):
        M, N, dtype, gram_of = key
        ekey = ("dist", key, scheme, self._mesh_epoch)
        if ekey in self._executables:
            self._m_exec_cache.inc(engine=self.engine_label, path="dist",
                                   outcome="hit")
            return self._executables[ekey]
        self._m_exec_cache.inc(engine=self.engine_label, path="dist",
                               outcome="miss")

        # one request at a time on the whole mesh: the mesh IS the
        # batch dimension here, slot-stacking would fight the sharding
        # (autotuned mode/levels still apply; block resolves inside
        # the per-shard kernels via the ops-level autotune defaults)
        def one(x):
            return distributed_gram(
                x, self.mesh, scheme=scheme,
                levels=cfg["levels"], leaf=cfg["leaf"],
                variant=cfg["variant"], mode=cfg["mode"],
                out_dtype=self.out_dtype, interpret=self.interpret,
                **self.dist_axes)
        spec = jax.ShapeDtypeStruct((M, N), jnp.dtype(dtype))
        with _trace.span("compile", bucket=self._blabel(key),
                         path=f"dist:{scheme}"):
            compiled = jax.jit(one).lower(spec).compile()
        self.compile_count += 1
        self._m_compiles.inc(engine=self.engine_label,
                             bucket=self._blabel(key), path="dist")
        self._executables[ekey] = compiled
        return compiled

    # -- cost-model drift ---------------------------------------------------
    def _drift_prediction(self, key, cfg) -> Optional[float]:
        """Model-predicted HBM bytes for one (bucket, config) — the
        denominator of both drift channels.  Resolves the same defaults
        the executable resolves (the "auto" mode dispatch, natural
        recursion depth, default block) so the prediction prices the
        config actually run; None when the model cannot price it."""
        ck = (key, self._cfg_fingerprint(cfg))
        if ck in self._drift_pred_cache:
            return self._drift_pred_cache[ck]
        M, N, dtype, gram_of = key
        pred: Optional[float] = None
        try:
            levels = cfg["levels"]
            if levels == "auto":
                levels = min(ata_levels_for(M, N, cfg["leaf"]),
                             AUTO_MAX_LEVELS)
            blk = cfg["block"] or _autotune.DEFAULT_BLOCK
            cand = {"mode": resolve_mode(cfg["mode"]), "levels": int(levels),
                    "variant": cfg["variant"], "bm": blk, "bk": blk,
                    "bn": blk}
            pred = _autotune.model_score(
                M, N, cand, in_bytes=int(jnp.dtype(dtype).itemsize),
                out_bytes=int(self.out_dtype.itemsize),
                kind="aat" if gram_of == "rows" else "ata")
        except Exception:
            pred = None
        self._drift_pred_cache[ck] = pred
        return pred

    def _observe_traffic(self, key, cfg, compiled) -> None:
        """Traffic drift channel: HLO-census HBM bytes of the compiled
        executable vs the analytic traffic model (same units — the
        [1/theta, theta] band applies directly)."""
        pred = self._drift_prediction(key, cfg)
        if pred is None:
            return
        try:
            from ..roofline.hlo_census import hbm_intermediate_census
            measured = float(hbm_intermediate_census(
                compiled.as_text())["total_bytes"])
        except Exception:
            return                      # census is best-effort telemetry
        self.drift.observe(self._drift_key(key), measured=measured,
                           predicted=pred, channel="traffic",
                           config=str(self._cfg_fingerprint(cfg)))

    def invalidate_drifted(self, channel: str = "wall") -> List[str]:
        """Act on drift findings: drop each flagged bucket's autotune
        winner (``gram.autotune.invalidate``), its cached executables and
        prediction, and its drift history — the next touch re-tunes and
        re-measures from scratch.  Returns the flagged drift keys."""
        dropped = []
        for dk in self.drift.stale_keys(channel):
            size, dtype, kind = str(dk).split("/")
            M, N = (int(x) for x in size.split("x"))
            try:
                _autotune.invalidate(M, N, dtype=dtype, kind=kind,
                                     min_side=self.min_bucket)
            except Exception:
                pass                    # no cache entry to drop is fine
            key = (M, N, dtype, "rows" if kind == "aat" else "cols")
            self._executables = {
                ek: exe for ek, exe in self._executables.items()
                if ek[1] != key}
            self._drift_pred_cache = {
                ck: v for ck, v in self._drift_pred_cache.items()
                if ck[0] != key}
            self.drift.reset(dk)
            dropped.append(str(dk))
            _trace.instant("drift_invalidate", key=str(dk), channel=channel)
        return dropped

    def _is_distributed(self, key) -> bool:
        """Buckets at/above the element threshold route to the mesh (when
        one is configured and the configured scheme fits the bucket — for
        "auto", any feasible scheme; otherwise dist_scheme itself must be
        feasible, or the bucket stays local rather than failing mid-step
        on a shard_map divisibility error)."""
        M, N, _, gram_of = key
        if gram_of == "rows":
            # the distributed schemes decompose A^t A; row-gram buckets
            # stay on the local aat executor
            return False
        if self.mesh is None or M * N < self.dist_threshold:
            return False
        feas = feasible_schemes(M, N, self.mesh, **self.dist_axes)
        if self.dist_scheme == "auto":
            return bool(feas)
        return self.dist_scheme in feas

    def _dist_chain(self, key) -> List[str]:
        """Fallback chain for one distributed bucket on the current mesh
        (``core.distributed.scheme_fallback_chain`` + terminal "local"),
        cached per mesh epoch."""
        ck = (key, self._mesh_epoch)
        if ck not in self._dist_chains:
            M, N, dtype, gram_of = key
            chain = scheme_fallback_chain(
                M, N, self.mesh, scheme=self.dist_scheme,
                dtype_bytes=jnp.dtype(dtype).itemsize,
                out_bytes=self.out_dtype.itemsize,
                **self.dist_axes)
            self._dist_chains[ck] = [f"dist:{s}" for s in chain] + ["local"]
        return self._dist_chains[ck]

    def prewarm(self, shapes, dtype=jnp.float32) -> int:
        """Build executables for the buckets covering ``shapes`` ahead of
        traffic (steady-state serving pays no first-request compile).
        Returns the number of compilations triggered."""
        before = self.compile_count
        for shape in shapes:
            key = self._bucket_key(shape, dtype)
            cfg = self._bucket_config(key, rung=0)
            if self._is_distributed(key):
                scheme = self._dist_chain(key)[0]
                if scheme != "local":
                    self._dist_executable(key, scheme[len("dist:"):], cfg)
                    continue
            self._local_executable(key, cfg)
        return self.compile_count - before

    # -- one engine tick ---------------------------------------------------
    def step(self) -> List[GramRequest]:
        """Drain one batch: serve a full batch if any bucket has one
        (throughput), else the bucket whose head request has waited
        longest (fairness — sparse buckets cannot be starved by popular
        ones); FIFO within a bucket.  Runs the bucket executable over up
        to ``slots`` stacked requests — through the degradation ladder
        (retry / escalate / fail, see module docstring) — and slices each
        result back to its true shape.  Returns the requests finished
        this tick (served, degraded, or failed); never raises on an
        executable failure."""
        if not self.waiting:
            return []
        self.ticks += 1
        self._poll_faults()
        full = [k for k, q in self.waiting.items() if len(q) >= self.slots]
        key = min(full or self.waiting,
                  key=lambda k: self.waiting[k][0].t_submit)
        queue = self.waiting[key]
        batch, rest = queue[:self.slots], queue[self.slots:]
        if rest:
            self.waiting[key] = rest
        else:
            del self.waiting[key]

        b = self._blabel(key)
        t_batch = time.perf_counter()
        for r in batch:
            self._m_qwait.observe(t_batch - r.t_submit,
                                  engine=self.engine_label, bucket=b)
        if _trace.tracing_enabled():
            for r in batch:
                _trace.add_span("queue_wait", r.t_submit, t_batch,
                                trace_id=r.uid, bucket=b)
        self._m_queue.set(sum(len(q) for q in self.waiting.values()),
                          engine=self.engine_label)
        self._m_fill.observe(len(batch) / self.slots,
                             engine=self.engine_label)

        entries, done = self._expire(list(enumerate(batch)))
        if entries:
            dist = self._is_distributed(key)
            with _trace.span("batch", bucket=b, n=len(entries),
                             path="dist" if dist else "local"):
                if dist:
                    for _, r in entries:
                        self._serve_one_distributed(key, r)
                        done.append(r)
                else:
                    done.extend(self._serve_local(key, entries))
        return done

    # -- local (slot-batched) serving -------------------------------------
    def _serve_local(self, key, entries) -> List[GramRequest]:
        """Serve [(slot, request)] through the slot-batched local
        executable under the retry/escalation ladder."""
        M, N, dtype, gram_of = key
        health = self._bucket_health(key)
        # jnp.dtype resolves extended names ("bfloat16") numpy alone won't
        clean = np.zeros((self.slots, M, N), jnp.dtype(dtype))
        for slot, r in entries:
            m, n = r.shape
            clean[slot, :m, :n] = r.a

        b = self._blabel(key)
        attempt, last_err = 0, "unknown failure"
        while True:
            entries, expired = self._expire(entries)
            if not entries:
                return expired + [r for _, r in entries]
            rung = health.rung
            cfg = self._bucket_config(key, rung)
            site = f"gram.engine.exec.local.{M}x{N}.{dtype}.{gram_of}"
            try:
                _faults.check_exec(site)
                stack = _faults.poison("poison_operand",
                                       "gram.engine.operand", clean)
                exe = self._local_executable(key, cfg)
                t_x0 = time.perf_counter()
                if _trace.tracing_enabled():
                    with jax.profiler.TraceAnnotation(f"gram_exec:{b}"):
                        out = np.asarray(exe(jnp.asarray(stack)))
                else:
                    out = np.asarray(exe(jnp.asarray(stack)))
                t_x1 = time.perf_counter()
                self._m_exec.observe(t_x1 - t_x0, engine=self.engine_label,
                                     bucket=b, path="local")
                out = _faults.poison("poison_output",
                                     "gram.engine.output", out)
                t_v0 = time.perf_counter()
                veto = self._guard(key, entries, out)
                t_v1 = time.perf_counter()
                if _trace.tracing_enabled():
                    for _, r in entries:
                        _trace.add_span("execute", t_x0, t_x1,
                                        trace_id=r.uid, bucket=b,
                                        path="local", rung=rung,
                                        attempt=attempt)
                        if self._guard_on:
                            _trace.add_span("verify", t_v0, t_v1,
                                            trace_id=r.uid, bucket=b,
                                            vetoed=veto is not None)
                if veto is None:
                    if rung == 0:
                        # wall drift channel: measured executable seconds
                        # vs model bytes, per tuned bucket (rung 0 only —
                        # degraded rungs run a different config)
                        pred = self._drift_prediction(key, cfg)
                        if pred is not None:
                            self.drift.observe(
                                self._drift_key(key),
                                measured=t_x1 - t_x0, predicted=pred,
                                channel="wall",
                                config=str(self._cfg_fingerprint(cfg)))
                    break                       # success
                last_err = veto
            except Exception as e:  # noqa: BLE001 — ladder, not crash
                last_err = f"{type(e).__name__}: {e}"
            self._record_failure(key, health, _LOCAL_MAX_RUNG, last_err)
            attempt += 1
            for _, r in entries:
                r.attempts += 1
            if attempt > self.max_retries:
                for _, r in entries:
                    self._finish_failed(r, last_err)
                return expired + [r for _, r in entries]
            self._backoff(attempt, [r for _, r in entries])

        self._record_success(key, health)
        t_done = time.perf_counter()
        served_by = "local" if rung == 0 else f"local:rung{rung}"
        for slot, r in entries:
            # the result spans the gram'd dimension: cols for A^tA,
            # rows for the gram_of="rows" AA^t buckets
            n = r.shape[0] if gram_of == "rows" else r.shape[1]
            c = out[slot, :n, :n]
            if r.full:
                c = np.asarray(symmetrize_from_lower(jnp.asarray(c)))
            r.attempts += 1
            self._finish_ok(r, c, served_by=served_by,
                            degraded=rung > 0, t_done=t_done)
        return expired + [r for _, r in entries]

    # -- distributed (mesh) serving ---------------------------------------
    def _serve_one_distributed(self, key, r: GramRequest) -> None:
        """Serve one request on the mesh, walking the scheme fallback
        chain (…-> local) on failure; the mesh may shrink between
        attempts (``_poll_faults`` runs per tick, ``apply_mesh`` any
        time), so the chain is re-read every attempt."""
        M, N, dtype, gram_of = key
        m, n = r.shape
        attempt, last_err = 0, "unknown failure"
        while True:
            if (r.deadline_s is not None and
                    time.perf_counter() > r.t_submit + r.deadline_s):
                self._finish_failed(r, "deadline exceeded")
                return
            health = self._bucket_health(key)
            if not self._is_distributed(key):
                rung_name = "local"         # mesh shrank under the bucket
            else:
                chain = self._dist_chain(key)
                rung_name = chain[min(health.rung, len(chain) - 1)]
            if rung_name == "local":
                self._serve_local(key, [(0, r)])
                return
            site = f"gram.engine.exec.{rung_name}.{M}x{N}.{dtype}"
            scheme = rung_name[len("dist:"):]
            try:
                _faults.check_exec(site)
                clean = np.zeros((M, N), jnp.dtype(dtype))
                clean[:m, :n] = r.a
                pad = _faults.poison("poison_operand",
                                     "gram.engine.operand", clean)
                exe = self._dist_executable(key, scheme,
                                            self._bucket_config(key, 0))
                t_x0 = time.perf_counter()
                c = np.asarray(jax.device_get(exe(jnp.asarray(pad))))
                t_x1 = time.perf_counter()
                b = self._blabel(key)
                self._m_exec.observe(t_x1 - t_x0, engine=self.engine_label,
                                     bucket=b, path="dist")
                _trace.add_span("execute", t_x0, t_x1, trace_id=r.uid,
                                bucket=b, path=rung_name, attempt=attempt)
                c = _faults.poison("poison_output",
                                   "gram.engine.output", c)
                c = c[:n, :n]
                with _trace.span("verify", trace_id=r.uid, bucket=b):
                    veto = self._guard(key, [(0, r)], c[None])
                if veto is None:
                    if not r.full:
                        c = np.tril(c)
                    r.attempts += 1
                    self._finish_ok(r, c, served_by=rung_name,
                                    degraded=health.rung > 0)
                    self.dist_served += 1
                    return
                last_err = veto
            except Exception as e:  # noqa: BLE001 — ladder, not crash
                last_err = f"{type(e).__name__}: {e}"
            self._record_failure(key, health,
                                 len(self._dist_chain(key)) - 1, last_err)
            attempt += 1
            r.attempts += 1
            if attempt > self.max_retries:
                self._finish_failed(r, last_err)
                return
            self._backoff(attempt, [r])

    def run_to_completion(self, max_ticks: int = 10_000) \
            -> List[GramRequest]:
        for _ in range(max_ticks):
            if not self.waiting:
                break
            self.step()
        return list(self.finished)

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        """Serving snapshot.  Latency percentiles read this engine's
        slice of the O(1)-update log-bucketed histogram in the metrics
        registry — ``stats()`` neither re-sorts a latency list nor
        depends on ``finished`` (which is capped at ``history_cap`` and
        kept only for callers that want the request objects).  ``drift``
        carries the wall-channel cost-model findings (``obs.drift``)."""
        eng = {"engine": self.engine_label}
        bucket_keys = sorted({ek[1] for ek in self._executables})
        return {
            "served": self.served,
            "failed": self.failed,
            "degraded_served": self.degraded_served,
            "retries": self.retries,
            "guard_failures": self.guard_failures,
            "mesh_changes": self.mesh_changes,
            "dist_served": self.dist_served,
            "ticks": self.ticks,
            "compile_count": self.compile_count,
            "buckets": bucket_keys,
            "distributed_buckets": sorted(
                k for k in bucket_keys if self._is_distributed(k)),
            "quarantined": {str(k): list(h.quarantined)
                            for k, h in self._health.items()
                            if h.quarantined},
            "history_cap": self.history_cap,
            "engine": self.engine_label,
            "queue_depth": sum(len(q) for q in self.waiting.values()),
            "p50_latency_s": self._m_latency.quantile(0.50, eng),
            "p99_latency_s": self._m_latency.quantile(0.99, eng),
            "drift": [f.as_dict() for f in self.drift.findings("wall")],
        }
