"""Online (streaming) Gram accumulation: C += A_chunk^t A_chunk.

The paper frames A^tA as "an intermediate operation during the solution
of a wide set of problems"; in most of those problems A arrives in row
chunks (minibatches, shards, token streams).  This module keeps the
running Gram in **packed lower-triangular form** — n(n+1)/2 words, the
paper's storage saving (`core/symmetry.py`) — and folds each chunk in
through the ATA recursion (fused Pallas kernel on TPU via
``mode="auto"``), with the state buffer **donated** so the accumulator is
updated in place rather than reallocated per chunk.

Exactness over ragged chunks: ``C = sum_i A_i^t A_i`` for any row
partition of A (the C11/C22 two-addend identity of Algorithm 1
generalized to any number of addends), so any chunking — including a
ragged final chunk — reproduces the one-shot ``ata_full(A)`` up to fp32
accumulation-order rounding.  ``tests/test_gram_stream.py`` and the
hypothesis property in ``tests/test_properties.py`` pin this down.

Fused updates are end-to-end *packed* — the kernel's tri-block stack
feeds the element-packed state through one static gather, so neither the
forward delta nor (via the gather's scatter-add VJP composed with the
packed kernel's packed-cotangent VJP) the backward ever materializes a
dense (n, n) buffer (DESIGN.md §11); ``tests/test_fused_grads.py``
checks streamed-update gradients against the reference recursion.

Sharded variant: ``update_sharded`` composes with
``core.distributed.gram_reducescatter`` — each device streams its *row
shard* of the chunk and holds only its block-row shard of C, so the
replicated C of the paper-faithful all-reduce scheme never materializes.

Distributed variant: ``distributed_init`` / ``distributed_update`` /
``distributed_finalize`` are the pjit-level composition with ANY
``core.distributed`` scheme — including the half-ring and the
communication-avoiding 2.5D ``bfs25d``, whose circulant block-stack
state (n(n+1)/2-ish words, sharded over the ring axis) accumulates
per-chunk deltas without ever materializing a replicated C.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.ata import ata, ata_levels_for
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..core.distributed import (assemble_ring_gram, gram_bfs25d,
                                gram_reducescatter, gram_ring,
                                ring_stack_len, shard_map_compat)
from ..core.strassen import AUTO_MAX_LEVELS, resolve_mode
from ..core.symmetry import pack_tril, tril_vector_from_blocks, unpack_tril

__all__ = ["GramStream", "init", "update", "finalize",
           "GramStackStream", "stack_init", "stack_update", "stack_finalize",
           "sharded_init", "update_sharded",
           "distributed_init", "distributed_update", "distributed_finalize",
           "CheckpointedGramStream"]


class GramStream(NamedTuple):
    """Running Gram state (a pytree — jit/scan/donate friendly).

    packed: (n(n+1)/2,) packed lower triangle of the accumulated C.
    rows:   scalar int32, total rows streamed so far (for normalized
            second-moment readings: C / rows).
    """
    packed: jax.Array
    rows: jax.Array

    @property
    def n(self) -> int:
        # n(n+1)/2 = L  =>  n = (sqrt(8L+1) - 1) / 2
        return (math.isqrt(8 * self.packed.shape[0] + 1) - 1) // 2


def init(n: int, *, dtype=jnp.float32) -> GramStream:
    """Fresh accumulator for an n-column stream (fp32 by default: the
    accumulation dtype must not lose bits across many chunks)."""
    return GramStream(packed=jnp.zeros(n * (n + 1) // 2, dtype),
                      rows=jnp.zeros((), jnp.int32))


@functools.lru_cache(maxsize=None)
def _updater(levels, leaf, variant, mode, block, interpret):
    resolved = resolve_mode(mode)

    def step(packed, rows, chunk):
        if resolved == "fused":
            # End-to-end packed: the fused kernel's tri-block stack feeds
            # the element-packed state through one static gather — the
            # dense (n, n) delta never materializes, and because the
            # gather's VJP is a scatter back into the stack (consumed by
            # the packed kernel's own packed-cotangent VJP), jax.grad of
            # a streamed update stays dense-free too (DESIGN.md §11).
            from ..kernels.ops import ata_fused_packed
            m, n = chunk.shape
            lv = (min(ata_levels_for(m, n, leaf), AUTO_MAX_LEVELS)
                  if levels == "auto" else levels)
            stack = ata_fused_packed(chunk, levels=lv, variant=variant,
                                     bk=block, bn=block,
                                     out_dtype=packed.dtype,
                                     interpret=interpret)
            delta = tril_vector_from_blocks(stack, stack.shape[1], n)
        else:
            delta = pack_tril(ata(chunk, levels=levels, leaf=leaf,
                                  variant=variant, mode=mode,
                                  out_dtype=packed.dtype, block=block,
                                  interpret=interpret))
        return packed + delta, rows + chunk.shape[0]
    # donate the packed accumulator: the update runs in place, no second
    # n(n+1)/2 buffer per chunk
    return jax.jit(step, donate_argnums=(0,))


def update(state: GramStream, chunk: jax.Array, *,
           levels: Union[int, str] = 2, leaf: int = 256,
           variant: str = "strassen", mode: str = "auto",
           block: Optional[int] = None,
           interpret: Optional[bool] = None) -> GramStream:
    """Fold one row chunk in: state.packed += pack_tril(tril(chunk^t chunk)).

    ``chunk`` is (m_chunk, n) with any m_chunk >= 1 (ragged tails fine).
    Kernel knobs mirror ``core.ata``; ``block=None`` consults the
    autotune cache (``gram.autotune``).
    """
    if chunk.ndim != 2 or state.n != chunk.shape[1]:
        raise ValueError(
            f"chunk shape {chunk.shape} does not match stream n={state.n}")
    fn = _updater(levels, leaf, variant, mode, block, interpret)
    packed, rows = fn(state.packed, state.rows, chunk)
    return GramStream(packed=packed, rows=rows)


def finalize(state: GramStream, *, symmetrize: bool = True,
             out_dtype=None, guard: bool = False) -> jax.Array:
    """Dense (n, n) Gram from the packed state (mirrored when
    ``symmetrize``, else lower-triangular like ``ata``).

    ``guard=True`` runs the streaming output guards first
    (``gram.verify.check_packed_state``: NaN/Inf scan + diagonal
    nonnegativity on the packed state — the chunks are gone, so no
    Freivalds probe) and raises :class:`~.verify.VerificationError`
    instead of handing corrupted state downstream.
    """
    if guard:
        import numpy as np
        from .verify import check_packed_state
        check_packed_state(np.asarray(jax.device_get(state.packed)), state.n)
    c = unpack_tril(state.packed, state.n, symmetrize=symmetrize)
    return c.astype(out_dtype) if out_dtype is not None else c


# ---------------------------------------------------------------------------
# Rank-k streaming: the state IS the kernel's packed tile stack, and each
# chunk folds in through the accumulating (rank_k) leaf program — the
# kernel seeds its VMEM accumulator from the stack, so no per-chunk delta
# stack, no unpack and no gather ever materializes (the PR-2 element-
# packed ``update`` above computes a full n(n+1)/2 delta per chunk and
# adds it; this path replaces that with ONE kernel per chunk).
# ---------------------------------------------------------------------------

class GramStackStream(NamedTuple):
    """Running Gram state in the executor's packed tile-stack layout.

    stack: (T(T+1)/2 * block, block) lower-triangular tile stack of the
           accumulated C (``kernels.syrk`` / ``fused_ata_packed``
           ordering; diagonal tiles full).
    rows:  scalar int32, total rows streamed so far.
    """
    stack: jax.Array
    rows: jax.Array

    @property
    def block(self) -> int:
        return self.stack.shape[1]

    @property
    def n_padded(self) -> int:
        n_tri = self.stack.shape[0] // self.block
        t = (math.isqrt(8 * n_tri + 1) - 1) // 2
        return t * self.block


def stack_init(n: int, *, block: Optional[int] = None,
               dtype=jnp.float32) -> GramStackStream:
    """Fresh rank-k accumulator for an n-column stream.

    ``block`` is the stack's tile edge (``None`` consults the autotune
    cache for the (n, n) bucket, 256 when untuned); the stack spans
    ``ceil(n / block)`` tiles — padded columns are exact zeros.
    """
    if block is None:
        from ..kernels.ops import _resolve_blocks
        block = _resolve_blocks("rank_k", n, n, dtype, bn=None)["bn"]
    t = -(-n // block)
    return GramStackStream(
        stack=jnp.zeros((t * (t + 1) // 2 * block, block), dtype),
        rows=jnp.zeros((), jnp.int32))


def stack_update(state: GramStackStream, chunk: jax.Array, *,
                 levels: Union[int, str] = 2, leaf: int = 256,
                 variant: str = "strassen", block: Optional[int] = None,
                 interpret: Optional[bool] = None) -> GramStackStream:
    """Fold one row chunk in: ``state.stack += packed(tril(chunk^t chunk))``
    — one accumulating kernel, state donated, no intermediate delta.

    ``chunk`` is (m_chunk, n) with n <= the stack's padded span.
    ``block`` is the *contraction* tile (rows of the chunk; the output
    tile edge is fixed by the stack).  ``levels`` clamps to depths the
    stack layout divides, like the symm executor.
    """
    if chunk.ndim != 2 or chunk.shape[1] > state.n_padded:
        raise ValueError(
            f"chunk shape {chunk.shape} does not fit stream "
            f"n_padded={state.n_padded}")
    from ..kernels.ops import rank_k_update
    m, n = chunk.shape
    lv = (min(ata_levels_for(m, n, leaf), AUTO_MAX_LEVELS)
          if levels == "auto" else levels)
    stack = rank_k_update(state.stack, chunk, levels=lv, variant=variant,
                          bk=block, interpret=interpret)
    return GramStackStream(stack=stack, rows=state.rows + m)


def stack_finalize(state: GramStackStream, n: Optional[int] = None, *,
                   symmetrize: bool = True, out_dtype=None,
                   guard: bool = False) -> jax.Array:
    """Dense (n, n) Gram from the stacked state (mirrored when
    ``symmetrize``, else lower-triangular like ``ata``).

    ``guard=True`` scans the tile stack for NaN/Inf before unpacking and
    raises :class:`~.verify.VerificationError` on corruption (the
    diagonal check happens on the unpacked dense form below — tile-stack
    indexing of the diagonal is block-size dependent)."""
    import numpy as np
    from ..core.symmetry import unpack_tril_blocks
    if guard:
        from .verify import VerificationError
        if not np.isfinite(np.asarray(jax.device_get(state.stack))).all():
            raise VerificationError(
                "streamed Gram tile stack contains non-finite entries")
    n_pad = state.n_padded
    c = unpack_tril_blocks(state.stack, n_pad, state.block,
                           symmetrize=False)
    c = jnp.tril(c)
    if guard:
        from .verify import VerificationError
        d = np.asarray(jax.device_get(jnp.diagonal(c))).astype(np.float64)
        scale = float(np.abs(d).max()) if d.size else 0.0
        if not (d >= -1e-4 * max(scale, 1.0)).all():
            raise VerificationError(
                "streamed Gram state has a negative diagonal entry")
    if symmetrize:
        from ..core.symmetry import symmetrize_from_lower
        c = symmetrize_from_lower(c)
    if n is not None:
        c = c[:n, :n]
    return c.astype(out_dtype) if out_dtype is not None else c


# ---------------------------------------------------------------------------
# Sharded streaming (inside shard_map): C lives sharded by block-rows.
# ---------------------------------------------------------------------------

def sharded_init(n: int, axis_size: int, *, dtype=jnp.float32) -> jax.Array:
    """Per-device state for ``update_sharded``: this device's (n/P, n)
    block-row shard of C (call inside shard_map, or build the global
    (n, n) array with a ``P(row_axis, None)`` sharding outside)."""
    if n % axis_size:
        raise ValueError(f"n={n} not divisible by axis_size={axis_size}")
    return jnp.zeros((n // axis_size, n), dtype)


def update_sharded(c_shard: jax.Array, chunk_local: jax.Array,
                   row_axis: str, *, levels: Union[int, str] = 2,
                   leaf: int = 256, variant: str = "strassen",
                   mode: str = "auto") -> jax.Array:
    """One streamed chunk under shard_map: rows of the chunk sharded over
    ``row_axis``, C sharded by block-rows over the same axis.

    Per chunk each device computes the Gram of its row shard (fused
    pipeline via ``mode="auto"``) and a single ``psum_scatter``
    (``gram_reducescatter``) lands each device's block-row slice — the
    full C is never replicated, and per-chunk collective bandwidth is
    n^2/P words per device instead of n^2.
    """
    delta = gram_reducescatter(chunk_local, row_axis, levels=levels,
                               leaf=leaf, variant=variant, mode=mode,
                               out_dtype=c_shard.dtype)
    return c_shard + delta


# ---------------------------------------------------------------------------
# pjit-level distributed streaming: state sharded by the scheme's natural
# output layout, chunks sharded like the scheme's input.
# ---------------------------------------------------------------------------

def _state_spec(scheme: str, row_axis: str, col_axis: Optional[str]):
    if scheme == "reducescatter":
        return P(row_axis, None)
    if scheme in ("ring", "bfs25d"):
        return P(None, None, col_axis)
    raise ValueError(f"unsupported streaming scheme {scheme!r}")


def distributed_init(n: int, mesh: Mesh, *, scheme: str = "reducescatter",
                     row_axis: str = "data",
                     col_axis: Optional[str] = "model",
                     dtype=jnp.float32) -> jax.Array:
    """Zero accumulator for ``distributed_update`` on ``mesh``.

    * ``"reducescatter"`` — dense (n, n) C sharded by block-rows over
      ``row_axis`` (never replicated).
    * ``"ring"`` / ``"bfs25d"`` — the half-ring circulant block stack
      (floor(T/2)+1, n/T, n) sharded over ``col_axis``: ~n(n+1)/2 words
      of global state, the packed-triangle saving at mesh scale.
    """
    spec = _state_spec(scheme, row_axis, col_axis)
    if scheme == "reducescatter":
        shape = (n, n)
    else:
        T = mesh.shape[col_axis]
        if n % T:
            raise ValueError(f"n={n} not divisible by ring size {T}")
        shape = (ring_stack_len(T), n // T, n)
    return jax.device_put(jnp.zeros(shape, dtype),
                          NamedSharding(mesh, spec))


def distributed_update(state: jax.Array, chunk: jax.Array, mesh: Mesh, *,
                       scheme: str = "reducescatter",
                       row_axis: str = "data",
                       col_axis: Optional[str] = "model",
                       rep_axis: Optional[str] = None,
                       levels: Union[int, str] = 2, leaf: int = 256,
                       variant: str = "strassen",
                       mode: str = "auto") -> jax.Array:
    """Fold one globally-sharded row chunk into the distributed state:
    ``state += scheme(chunk)``.  Chunk rows must divide by the row axis;
    for the ring family the chunk is also column-sharded (and, for
    ``bfs25d``, replicated over ``rep_axis`` — the 2.5D trade applies
    per chunk, so each update ships only ceil(half/c) permute hops)."""
    shard_map, unchecked = shard_map_compat()
    spec = _state_spec(scheme, row_axis, col_axis)

    if scheme == "reducescatter":
        def body(c_shard, chunk_local):
            return update_sharded(c_shard, chunk_local, row_axis,
                                  levels=levels, leaf=leaf, variant=variant,
                                  mode=mode)
        chunk_spec = P(row_axis, None)
    else:
        T = mesh.shape[col_axis]

        def body(stack, chunk_local):
            if scheme == "ring":
                delta = gram_ring(chunk_local, col_axis, row_axis,
                                  levels=levels, leaf=leaf, variant=variant,
                                  mode=mode, out_dtype=stack.dtype,
                                  axis_size=T)
            else:
                if rep_axis is None:
                    raise ValueError("bfs25d streaming needs rep_axis")
                delta = gram_bfs25d(chunk_local, col_axis, rep_axis,
                                    row_axis, levels=levels, leaf=leaf,
                                    variant=variant, mode=mode,
                                    out_dtype=stack.dtype, col_size=T,
                                    rep_size=mesh.shape[rep_axis])
            return stack + delta
        chunk_spec = P(row_axis, col_axis)

    return shard_map(body, mesh=mesh, in_specs=(spec, chunk_spec),
                     out_specs=spec, **unchecked)(state, chunk)


def distributed_finalize(state: jax.Array, mesh: Mesh, *,
                         scheme: str = "reducescatter",
                         col_axis: Optional[str] = "model") -> jax.Array:
    """Dense symmetric (n, n) C from the distributed state (the
    reduce-scatter state already IS dense; ring-family states are
    assembled from the circulant block layout)."""
    if scheme == "reducescatter":
        return state
    T = mesh.shape[col_axis]
    return assemble_ring_gram(state, T, state.shape[2])


# ---------------------------------------------------------------------------
# Crash-recoverable streaming: write-ahead checkpoints of the accumulator.
# ---------------------------------------------------------------------------

class CheckpointedGramStream:
    """A streaming Gram whose state survives the process (DESIGN.md §13).

    Wraps :class:`GramStream` (``layout="packed"``) or
    :class:`GramStackStream` (``layout="stack"``) and commits the
    accumulator to a :class:`~repro.checkpoint.CheckpointManager`
    directory every ``every`` chunks — atomic rename commits, so a kill
    at ANY point leaves either the previous or the new checkpoint
    intact, never a torn one.  The commit step number is the count of
    chunks *fully folded in* (write-ahead in the sense that the state on
    disk is always a prefix of the stream: resume never replays a chunk
    into state that already contains it, and never skips one — the
    resumer re-feeds chunks from ``next_chunk`` on).

    Because chunked accumulation is exact over any row partition (module
    docstring) and the resumed state is the *bit-identical* buffer the
    crashed process committed, a resumed run's finalize is bit-exact
    against the uninterrupted run as long as chunks are re-fed at the
    same boundaries (fp addition is order-sensitive; the checkpoint
    preserves the order).

    ::

        s = CheckpointedGramStream(n, workdir, every=4)
        for i, chunk in enumerate(chunks):
            if i < s.next_chunk:      # already folded in pre-crash
                continue
            s.update(chunk)
        c = s.finalize(guard=True)
    """

    def __init__(self, n: int, workdir: str, *, every: int = 1,
                 layout: str = "packed", block: Optional[int] = None,
                 dtype=jnp.float32, keep: int = 2,
                 async_save: bool = False, **update_kw):
        if layout not in ("packed", "stack"):
            raise ValueError(f"layout must be 'packed' or 'stack', "
                             f"got {layout!r}")
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        from ..checkpoint import CheckpointManager
        self.n = n
        self.layout = layout
        self.every = every
        self.update_kw = update_kw
        # sync by default: a streaming WAL wants the commit durable when
        # .commit() returns (the trainer's overlap-with-compute motive
        # doesn't apply to host-side accumulator snapshots)
        self.manager = CheckpointManager(workdir, keep=keep,
                                         async_save=async_save)
        self.chunks = 0            # chunks fully folded into .state
        self._dirty = 0            # chunks since the last commit
        self.resumed = False
        if layout == "packed":
            self.state = init(n, dtype=dtype)
        else:
            self.state = stack_init(n, block=block, dtype=dtype)
        with _trace.span("stream_restore", layout=layout):
            restored, meta = self.manager.restore()
        if restored is not None:
            if int(meta.get("n", n)) != n or meta.get("layout") != layout:
                raise ValueError(
                    f"checkpoint in {workdir} holds a "
                    f"{meta.get('layout')} stream of n={meta.get('n')}, "
                    f"not the requested {layout} n={n}")
            if layout == "packed":
                self.state = GramStream(
                    packed=jnp.asarray(restored["packed"]),
                    rows=jnp.asarray(restored["rows"]))
            else:
                self.state = GramStackStream(
                    stack=jnp.asarray(restored["stack"]),
                    rows=jnp.asarray(restored["rows"]))
            self.chunks = int(meta["chunks"])
            self.resumed = True

    @property
    def next_chunk(self) -> int:
        """Index of the first chunk NOT yet folded in (resume cursor)."""
        return self.chunks

    def update(self, chunk) -> None:
        """Fold one chunk in; commits every ``every`` chunks."""
        if self.layout == "packed":
            self.state = update(self.state, chunk, **self.update_kw)
        else:
            self.state = stack_update(self.state, chunk, **self.update_kw)
        self.chunks += 1
        self._dirty += 1
        if self._dirty >= self.every:
            self.commit()

    def commit(self) -> None:
        """Force a checkpoint of the current state (no-op when clean)."""
        if self._dirty == 0 and self.manager.latest_step() == self.chunks:
            return
        if self.layout == "packed":
            tree = {"packed": self.state.packed, "rows": self.state.rows}
        else:
            tree = {"stack": self.state.stack, "rows": self.state.rows}
        with _trace.span("stream_commit", chunks=self.chunks,
                         dirty=self._dirty, layout=self.layout):
            self.manager.save(self.chunks, tree,
                              extra={"chunks": self.chunks, "n": self.n,
                                     "layout": self.layout})
        _metrics.counter("gram_stream_commits_total",
                         "checkpoint commits of streamed Gram state").inc(
            layout=self.layout)
        self._dirty = 0

    def finalize(self, *, symmetrize: bool = True, out_dtype=None,
                 guard: bool = False) -> jax.Array:
        """Commit any uncheckpointed chunks, then the dense Gram (with
        the output guards when ``guard`` — see ``finalize``)."""
        self.commit()
        self.manager.wait()
        if self.layout == "packed":
            return finalize(self.state, symmetrize=symmetrize,
                            out_dtype=out_dtype, guard=guard)
        return stack_finalize(self.state, self.n, symmetrize=symmetrize,
                              out_dtype=out_dtype, guard=guard)
