"""Per-bucket autotuning for the Gram service.

The Benson–Ballard observation carried into this repo: a fast-matmul
variant only pays off when the variant *and* blocking are selected per
shape.  This module searches ``mode x levels x variant x gram x
(bm, bk, bn)`` per (shape bucket, dtype, backend) — the variant and
gram-algebra axes enumerate the live leaf-IR registries, so registering
a new algebra automatically enters it in the contest — ranks candidates
with the analytic HBM
traffic model (``kernels.strassen_fused.ata_traffic_model`` — exact for
the fused kernel on hardware), optionally times the top-K on the current
device, and persists the winner to a JSON cache under
``artifacts/autotune/``.

``kernels/ops.py`` and the ``core`` recursions consult this cache for
their block-size defaults (``resolve_block_defaults``) instead of the
historical hardcoded 256s; ``gram.engine.GramEngine`` consults it for the
full per-bucket config (mode + levels + blocks).

Cache file format (``gram_autotune.json``)::

    {"version": 2,
     "entries": {
       "<backend>/jax-<version>/<dtype>/<kind>/<M>x<N>": {
          "mode": "fused", "levels": 2, "variant": "strassen",
          "gram": "strassen", "bm": 256, "bk": 256, "bn": 256,
          "model_bytes": 1234, "measured_s": null, "source": "model",
          "jax": "<version>", "backend": "<backend>"}}}

Keys are *bucketed* shapes (``bucket_shape``), so one entry serves every
request shape that rounds up to the same bucket — and they pin the
persist-time (backend, jax version) pair: winners are measurements of
one toolchain, and before v2 a stale winner from a different jax
silently applied after an upgrade (pre-v2 files are ignored wholesale;
see ``load_cache``).  Invalidation: the file is re-read whenever its
mtime changes (delete it, or re-run ``autotune`` with ``refresh=True``,
to invalidate).  Set ``REPRO_AUTOTUNE_CACHE`` to relocate the cache
(tests point it at a tmp dir).

Kinds: ``ata`` (forward column gram), ``aat`` (row gram,
``gram_of="rows"``), ``rank_k`` (the accumulating streamed update),
``ata_bwd`` (the Gram backward) — all scored by the one IR-driven
traffic core in ``kernels.strassen_fused``.
"""
from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp

from ..obs import metrics as _metrics

__all__ = [
    "bucket_shape", "candidate_space", "dedupe_candidates", "model_score",
    "autotune", "lookup", "resolve_block_defaults", "load_cache",
    "default_cache_path", "invalidate", "DEFAULT_BLOCK",
]

def _cache_event(outcome: str, amount: float = 1.0) -> None:
    """Count one cache-lifecycle event: "hit"/"miss" per lookup,
    "corrupt" (unparseable file degraded to empty), "stale_dropped"
    (pre-v2 entries dropped wholesale at load), "persist" (entry
    written), "invalidate" (winner dropped — drift findings land here).
    Resolved from the live registry per call so a test-time registry
    reset cannot orphan the counter."""
    _metrics.counter("gram_autotune_cache_total",
                     "autotune cache events by outcome").inc(
        amount, outcome=outcome)

DEFAULT_BLOCK = 256
# v2: cache keys gained the jax-version segment (see _key) — a winner
# measured under one jax/backend silently applying after an upgrade was a
# real bug; v1 files are ignored wholesale (stale by construction).
_CACHE_VERSION = 2

# (path, mtime) -> parsed entries; re-read on mtime change (invalidation).
_memo: dict = {}


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    # src/repro/gram/autotune.py -> repo root is parents[3]
    root = Path(__file__).resolve().parents[3]
    return root / "artifacts" / "autotune" / "gram_autotune.json"


def next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def bucket_shape(m: int, n: int, *, min_side: int = 32) -> tuple[int, int]:
    """Round a request shape up to the service bucket (powers of two,
    floored at ``min_side``).  Exact for Gram: zero-padding rows of A adds
    nothing to A^tA and zero columns are sliced away by the caller."""
    return (max(next_pow2(m), min_side), max(next_pow2(n), min_side))


def _key(backend: str, dtype: str, kind: str, M: int, N: int) -> str:
    """Cache key for one tuned bucket.

    Includes the *persist-time* backend name AND the jax version: tuned
    winners are measurements of one (jax, backend) pair — block sizes
    and mode crossovers move across jax upgrades, and before v2 a stale
    winner from a different jax silently applied after an upgrade
    (lookups key on the same two values, so mismatched entries simply
    never match).
    """
    return f"{backend}/jax-{jax.__version__}/{dtype}/{kind}/{M}x{N}"


# ---------------------------------------------------------------------------
# Search space + model scoring
# ---------------------------------------------------------------------------

def _variant_axis(kind: str) -> list:
    """(variant, gram) pairs ``kind`` can execute, from the live
    registries.  Gram kinds need square 2x2 variants for the off-diagonal
    table expansion; the gram-algebra axis only exists for them (matmul
    and everything else runs any registered split with the fixed
    placeholder gram)."""
    from ..core import leaf_ir
    if kind in ("ata", "aat", "rank_k", "ata_bwd"):
        variants = [v for v in leaf_ir.registered_algebras()
                    if leaf_ir.algebra_dims(v) == (2, 2, 2)]
        return [(v, g) for v in variants
                for g in leaf_ir.registered_gram_algebras()]
    return [(v, "strassen") for v in leaf_ir.registered_algebras()]


def candidate_space(M: int, N: int, *, backend: Optional[str] = None,
                    blocks=(128, 256, 512), levels=(0, 1, 2),
                    modes=("fused", "reference"), kind: str = "ata",
                    pipeline_depths=(1, 2), operand_dtypes=(None,)):
    """Enumerate (mode, levels, variant, gram, bm/bk/bn, pipeline_depth,
    operand_dtype) candidates for an (M, N) bucket.

    The variant/gram axes come from the live leaf-IR registries
    (``_variant_axis``), so registering a new algebra automatically puts
    it in contention — the historical hardcoded ``"strassen"`` meant even
    the registered winograd table could never win.  Blocks larger than
    the bucket only add padding, so they are dropped (keeping at least
    the smallest candidate).  The grid only varies the knobs ``kind``
    actually uses — ``aat`` ties bm=bk and ignores bn, and at levels=0
    every (variant, gram) compiles the identical classical program, so
    only one candidate is emitted there.  ``pipeline_depths`` /
    ``operand_dtypes`` (DESIGN.md §16) are fused-kernel knobs: the
    reference recursion pins depth 1 / native operands.
    """
    usable = [b for b in blocks if b <= max(M, N)] or [min(blocks)]
    axis = _variant_axis(kind)
    out = []
    for mode in modes:
        for lv in levels:
            if mode == "reference":
                # blocking/pipelining are fused-kernel knobs; the
                # reference recursion leaves tiling to XLA — one
                # candidate per level.
                out.append({"mode": "reference", "levels": lv,
                            "variant": "strassen", "gram": "strassen",
                            "bm": min(usable), "bk": min(usable),
                            "bn": min(usable), "pipeline_depth": 1,
                            "operand_dtype": None})
                continue
            pairs = axis if lv > 0 else [("strassen", "strassen")]
            for variant, gram in pairs:
                for bk in usable:
                    bns = [bk] if kind == "aat" else usable
                    for bn in bns:
                        for pd in pipeline_depths:
                            for od in operand_dtypes:
                                out.append({
                                    "mode": "fused", "levels": lv,
                                    "variant": variant, "gram": gram,
                                    "bm": bk, "bk": bk, "bn": bn,
                                    "pipeline_depth": int(pd),
                                    "operand_dtype": od})
    return dedupe_candidates(out, kind=kind)


def dedupe_candidates(cands, kind: str = "ata"):
    """Drop candidates that bind the identical executable config.

    The enumeration axes overshoot the kernel's real degrees of freedom:
    ``aat`` ties bm=bk and never reads bn (the historical tie-duplication
    that filled the measured top-K with re-timings of one config),
    levels=0 compiles the same classical program for every (variant,
    gram) pair, and reference candidates ignore blocking and the fused
    perf knobs entirely.  Keyed on the knobs ``kind`` actually uses,
    first occurrence wins (order — and therefore model ranking — is
    preserved)."""
    seen, out = set(), []
    for c in cands:
        lv = c["levels"]
        alg = ((c["variant"], c.get("gram", "strassen")) if lv > 0
               else ("classical", "classical"))
        if c["mode"] == "reference":
            sig = ("reference", lv, alg)
        else:
            blocks = ((c["bm"], c["bk"]) if kind == "aat"
                      else (c["bk"], c["bn"]))
            sig = ("fused", lv, alg, blocks,
                   int(c.get("pipeline_depth") or 1),
                   c.get("operand_dtype"))
        if sig in seen:
            continue
        seen.add(sig)
        out.append(c)
    return out


def _pipelined_side_score(side: dict, cand: dict, in_bytes: int) -> float:
    """Score one traffic-model side dict for a candidate.

    Legacy byte-sum (read + write + intermediate) unless the candidate
    carries the §16 perf knobs AND they deviate from the unpipelined
    native-operand baseline — entries tuned before those axes existed
    (and the depth-1/native candidates) keep their historical scores
    bit-for-bit.  ``operand_dtype`` rescales operand read traffic by the
    quantized itemsize; ``pipeline_depth`` >= 2 swaps the byte sum for
    ``cost_model.pipelined_bytes_score`` (max(mem, compute) + amortized
    fill instead of their sum)."""
    reads = float(side["read_bytes"])
    writes = float(side["write_bytes"])
    inter = float(side.get("intermediate_bytes", 0))
    if "pipeline_depth" not in cand and "operand_dtype" not in cand:
        return reads + writes + inter           # legacy candidate
    od = cand.get("operand_dtype")
    pd = int(cand.get("pipeline_depth") or 1)
    if od is not None:
        reads *= jnp.dtype(od).itemsize / float(in_bytes)
    from ..core.cost_model import pipelined_bytes_score
    # Depth 1 and depth >= 2 are both scored on the roofline (sum vs
    # max + fill) so the depth axis is an apples-to-apples contest; the
    # legacy byte-sum above has no compute term and would misrank
    # compute-bound shapes against pipelined candidates.
    return pipelined_bytes_score(
        reads + inter, writes, float(side.get("flops", 0)),
        pipeline_depth=pd, grid_steps=int(side.get("grid_steps", 1)))


def model_score(m: int, n: int, cand: dict, *, in_bytes: int = 4,
                out_bytes: int = 4, kind: str = "ata") -> float:
    """HBM-bytes score (lower is better) used to seed the search.

    Fused candidates use the exact analytic kernel models — all thin
    wrappers over the one IR-driven traffic core in
    ``kernels.strassen_fused`` (``_traffic`` over a bound program spec),
    so every kind (``ata``, ``aat``, ``rank_k``, ``ata_bwd``) is scored
    by the same machinery the executor is built on rather than a
    per-kind closed form.  Reference candidates use a closed-form upper
    estimate of what the recursion (or the relevant dense baseline)
    materializes — a deliberate heuristic.  Because the reference score
    is a heuristic while the fused score is exact, model-only search
    ranks fused candidates only — reference candidates compete through
    ``measure=True`` wall clock (see :func:`autotune`).

    Candidates carrying the §16 perf knobs (``pipeline_depth`` >= 2 or a
    quantized ``operand_dtype``) are scored with the pipelined roofline
    term (``_pipelined_side_score``); legacy candidates keep the
    historical byte sum.
    """
    if kind == "ata_bwd":
        from ..kernels.strassen_fused import ata_bwd_traffic_model
        # cotangent="dense": score the same entry point the measured
        # runner (and the ata() consumer the winner applies to) drives —
        # jax.grad through the dense forward packs the cotangent first.
        t = ata_bwd_traffic_model(m, n, levels=cand["levels"],
                                  variant=cand["variant"],
                                  gram=cand.get("gram", "strassen"),
                                  bk=cand["bk"],
                                  bn=cand["bn"], in_bytes=in_bytes,
                                  cotangent="dense")
        side = t if cand["mode"] == "fused" else t["dense_baseline"]
        return _pipelined_side_score(side, cand, in_bytes)
    if kind == "rank_k":
        from ..kernels.strassen_fused import rank_k_traffic_model
        t = rank_k_traffic_model(m, n, levels=cand["levels"],
                                 variant=cand["variant"],
                                 gram=cand.get("gram", "strassen"),
                                 bk=cand["bk"],
                                 bn=cand["bn"], in_bytes=in_bytes,
                                 state_bytes=out_bytes)
        # "reference" = the status-quo streamed update (delta stack +
        # gather-add) the accumulating kernel replaces
        side = t if cand["mode"] == "fused" else t["baseline"]
        return _pipelined_side_score(side, cand, in_bytes)
    if cand["mode"] == "fused":
        from ..kernels.strassen_fused import (aat_traffic_model,
                                              ata_traffic_model)
        if kind == "aat":
            t = aat_traffic_model(m, n, levels=cand["levels"],
                                  variant=cand["variant"],
                                  gram=cand.get("gram", "strassen"),
                                  bm=cand["bm"],
                                  bk=cand["bk"], in_bytes=in_bytes,
                                  out_bytes=out_bytes)
        else:
            t = ata_traffic_model(m, n, levels=cand["levels"],
                                  variant=cand["variant"],
                                  gram=cand.get("gram", "strassen"),
                                  bk=cand["bk"],
                                  bn=cand["bn"], in_bytes=in_bytes,
                                  out_bytes=out_bytes)
        return _pipelined_side_score(t, cand, in_bytes)
    lv = cand["levels"]
    amplification = (7.0 / 4.0) ** lv
    d = m if kind == "aat" else n          # gram output dimension
    reads = m * n * in_bytes * max(amplification, 1.0)
    writes = d * d * out_bytes
    intermediates = (m * n + d * d) * in_bytes * (amplification - 1.0) * 2
    return float(reads + writes + intermediates)


# ---------------------------------------------------------------------------
# Cache IO
# ---------------------------------------------------------------------------

def load_cache(path: Optional[os.PathLike] = None) -> dict:
    """Entries dict from the JSON cache ({} when absent/corrupt).
    Memoized on (path, mtime): touching the file invalidates.

    A corrupt file (truncated by a crash mid-write outside our atomic
    path, or bit rot) must never take serving down: it degrades to an
    empty cache — untuned defaults — with ONE warning per file snapshot
    (the mtime memo dedups it; the next ``_save_entry`` rewrites the
    file whole, which is the repair)."""
    p = Path(path) if path is not None else default_cache_path()
    # chaos hook: an armed cache_corrupt fault truncates the file first,
    # exercising exactly the recovery path below (tests/chaos CI)
    if p.exists():
        from ..runtime import faults as _faults
        _faults.corrupt_file("gram.autotune.cache", p)
    try:
        mtime = p.stat().st_mtime_ns
    except OSError:
        return {}
    memo_key = (str(p), mtime)
    if memo_key in _memo:
        return _memo[memo_key]
    try:
        with open(p) as f:
            raw = json.load(f)
        entries = raw.get("entries", {}) if isinstance(raw, dict) else {}
        # pre-v2 files keyed without the jax version — every entry is
        # potentially a stale winner from another jax; drop them all and
        # let autotune repopulate (the migration path)
        if not isinstance(raw, dict) or raw.get("version", 0) \
                < _CACHE_VERSION:
            _cache_event("stale_dropped", len(entries) or 1)
            entries = {}
    except OSError:
        entries = {}
    except ValueError as e:
        import warnings
        warnings.warn(
            f"autotune cache {p} is corrupt ({e}); ignoring it and "
            f"serving with untuned defaults — the next autotune run "
            f"rewrites it", stacklevel=2)
        _cache_event("corrupt")
        entries = {}
    _memo.clear()           # one live file snapshot is enough
    _memo[memo_key] = entries
    return entries


def _save_entry(key: str, entry: dict, path: Optional[os.PathLike]) -> Path:
    p = Path(path) if path is not None else default_cache_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    entries = dict(load_cache(p))
    entries[key] = entry
    tmp = p.with_suffix(".tmp")
    with open(tmp, "w") as f:
        json.dump({"version": _CACHE_VERSION, "entries": entries}, f,
                  indent=1, sort_keys=True)
    os.replace(tmp, p)
    _cache_event("persist")
    return p


def invalidate(m: int, n: int, *, dtype: str = "float32",
               kind: str = "ata", backend: Optional[str] = None,
               min_side: int = 32,
               cache_path: Optional[os.PathLike] = None) -> bool:
    """Drop the persisted winner for the bucket containing (m, n) —
    the action a cost-model drift finding maps to
    (``GramEngine.invalidate_drifted``): the entry was a measurement of
    conditions that no longer hold, so the next autotune re-measures.
    Returns whether an entry existed."""
    backend = backend or jax.default_backend()
    M, N = bucket_shape(m, n, min_side=min_side)
    key = _key(backend, str(dtype), kind, M, N)
    p = Path(cache_path) if cache_path is not None else default_cache_path()
    entries = dict(load_cache(p))
    if key not in entries:
        return False
    del entries[key]
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    with open(tmp, "w") as f:
        json.dump({"version": _CACHE_VERSION, "entries": entries}, f,
                  indent=1, sort_keys=True)
    os.replace(tmp, p)
    _cache_event("invalidate")
    return True


def lookup(m: int, n: int, *, dtype: str = "float32", kind: str = "ata",
           backend: Optional[str] = None, min_side: int = 32,
           cache_path: Optional[os.PathLike] = None) -> Optional[dict]:
    """Winner entry for the bucket containing (m, n), or None.
    ``min_side`` must match the bucketing used when tuning (the engine
    threads its ``min_bucket`` here)."""
    backend = backend or jax.default_backend()
    M, N = bucket_shape(m, n, min_side=min_side)
    hit = load_cache(cache_path).get(_key(backend, str(dtype), kind, M, N))
    _cache_event("hit" if hit is not None else "miss")
    return hit


def resolve_block_defaults(kind: str, m: int, n: int, dtype,
                           **blocks) -> dict:
    """Fill ``None`` block sizes from the autotune cache (256 fallback).

    The hook through which ``kernels/ops.py`` / ``core`` consult the
    cache: explicit caller values always win; a missing cache (or any
    cache error) degrades to the historical hardcoded default.  Only
    ``mode="fused"`` winners carry meaningful block sizes (blocking is a
    fused-kernel knob — reference entries hold placeholders), so other
    entries are ignored here.
    """
    if all(v is not None for v in blocks.values()):
        return blocks
    best = None
    if kind in ("ata", "matmul", "ata_bwd", "aat", "rank_k"):
        try:
            best = lookup(m, n, dtype=jnp.dtype(dtype).name, kind=kind)
        except Exception:
            best = None
        if best is not None and best.get("mode") != "fused":
            best = None
    return {k: int(v if v is not None
                   else (best or {}).get(k) or DEFAULT_BLOCK)
            for k, v in blocks.items()}


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------

def _build_runner(M: int, N: int, dtype, cand: dict, interpret,
                  kind: str = "ata"):
    from ..core.ata import ata

    galg = cand.get("gram", "strassen")
    # §16 perf knobs — fused-kernel only; the reference recursion takes
    # pipeline_depth=None (a no-op there) and quantizes via ata()'s
    # operand_dtype oracle path.
    pdepth = cand.get("pipeline_depth")
    odtype = cand.get("operand_dtype")
    if kind == "aat":
        def fn(a):
            return ata(a, gram_of="rows", levels=cand["levels"],
                       variant=cand["variant"], gram=galg,
                       mode=cand["mode"], block=cand["bk"],
                       out_dtype=jnp.float32, interpret=interpret,
                       pipeline_depth=pdepth, operand_dtype=odtype)
        return jax.jit(fn)

    if kind == "rank_k":
        # fused: the accumulating kernel on a live stack; reference: the
        # status-quo element-packed streamed update it replaces.
        if cand["mode"] == "fused":
            from ..kernels.ops import rank_k_update

            def fn(a):
                t = -(-N // cand["bn"])
                stack = jnp.zeros((t * (t + 1) // 2 * cand["bn"],
                                   cand["bn"]), jnp.float32)
                return rank_k_update(stack, a, levels=cand["levels"],
                                     variant=cand["variant"], gram=galg,
                                     bk=cand["bk"], interpret=interpret,
                                     donate=False, pipeline_depth=pdepth,
                                     operand_dtype=odtype)
            return jax.jit(fn)

        from . import stream as _stream

        def fn(a):
            state = _stream.init(N)
            return _stream.update(state, a, levels=cand["levels"],
                                  variant=cand["variant"], mode="auto",
                                  interpret=interpret).packed
        return fn                      # stream.update jits internally

    if kind == "ata_bwd":
        # time jax.grad through the fused forward; the candidate mode
        # picks the VJP engine ("reference" = the dense-dot baseline).
        bwd = "fused" if cand["mode"] == "fused" else "dense"

        def fn(a):
            return jax.grad(lambda x: ata(
                x, levels=cand["levels"], variant=cand["variant"],
                gram=galg, mode="fused", bwd=bwd, block=cand["bk"],
                out_dtype=jnp.float32, interpret=interpret,
                pipeline_depth=pdepth).sum())(a)
        return jax.jit(fn)

    def fn(a):
        return ata(a, levels=cand["levels"], variant=cand["variant"],
                   gram=galg, mode=cand["mode"], block=cand["bk"],
                   out_dtype=jnp.float32, interpret=interpret,
                   pipeline_depth=pdepth, operand_dtype=odtype)
    return jax.jit(fn)


def _time_candidate(fn, a, iters: int = 2) -> float:
    jax.block_until_ready(fn(a))            # compile + warm
    best = math.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(a))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(m: int, n: int, *, dtype: str = "float32", kind: str = "ata",
             backend: Optional[str] = None, measure: bool = False,
             top_k: int = 3, blocks=(128, 256, 512), levels=(0, 1, 2),
             modes=("fused", "reference"), min_side: int = 32,
             pipeline_depths=(1, 2), operand_dtypes=(None,),
             cache_path: Optional[os.PathLike] = None,
             interpret: Optional[bool] = None,
             refresh: bool = False) -> dict:
    """Pick (and persist) the best config for the bucket containing (m, n).

    Model-only by default: ranks *fused* candidates by ``model_score``
    (the model is exact for the fused kernel; the reference estimate is a
    heuristic, so it never decides a contest).  With ``measure=True`` the
    top-K fused candidates plus the reference candidates are compiled and
    timed on the current device and wall clock picks the winner.  Returns
    the cached entry when one exists unless ``refresh``.

    ``kind="ata_bwd"`` tunes the *backward*: candidates are scored with
    ``ata_bwd_traffic_model`` (mode "fused" = the packed-cotangent symm
    kernel, "reference" = the dense-dot ``A (S + S^t)`` baseline) and
    measured — when requested — as ``jax.grad`` wall clock through the
    fused forward with the corresponding ``bwd=`` engine.
    """
    backend = backend or jax.default_backend()
    M, N = bucket_shape(m, n, min_side=min_side)
    key = _key(backend, str(dtype), kind, M, N)
    if not refresh:
        hit = load_cache(cache_path).get(key)
        if hit is not None:
            return hit

    in_bytes = jnp.dtype(dtype).itemsize
    cands = candidate_space(M, N, backend=backend, blocks=blocks,
                            levels=levels, modes=modes, kind=kind,
                            pipeline_depths=pipeline_depths,
                            operand_dtypes=operand_dtypes)
    score = lambda c: model_score(M, N, c, in_bytes=in_bytes,  # noqa: E731
                                  kind=kind)
    fused = sorted((c for c in cands if c["mode"] == "fused"), key=score)
    refs = sorted((c for c in cands if c["mode"] == "reference"), key=score)
    winner, measured = (fused or refs)[0], None
    if measure:
        a = jax.random.normal(jax.random.PRNGKey(0), (M, N)).astype(dtype)
        timed = []
        for cand in fused[:top_k] + refs:
            try:
                timed.append((_time_candidate(
                    _build_runner(M, N, dtype, cand, interpret, kind), a),
                    cand))
            except Exception:
                continue            # unrunnable candidate (e.g. VMEM clamp)
        if timed:
            measured, winner = min(timed, key=lambda tc: tc[0])

    entry = {**winner,
             "model_bytes": model_score(M, N, winner, in_bytes=in_bytes,
                                        kind=kind),
             "measured_s": measured,
             "source": "measured" if measured is not None else "model",
             # introspection copies of what the key already pins: the
             # (jax, backend) pair this winner was tuned under
             "jax": jax.__version__,
             "backend": backend}
    _save_entry(key, entry, cache_path)
    return entry
