"""Output guards for served Grams: NaN/Inf scan + Freivalds-style probe.

The Gram's defining identity is a nearly-free correctness oracle: for any
vector x,

    x^t (A^t A) x  =  (Ax)^t (Ax)  =  ||Ax||^2            (cols gram)
    x^t (A A^t) x  =  ||A^t x||^2                          (rows gram)

so a candidate C can be checked against A at O(mn + n^2) cost per probe —
without ever recomputing the n^log2(7)-cost fast product it came from.
This is Freivalds' algorithm specialized to the symmetric case: with x
drawn uniformly from {-1, +1}^n (Rademacher), a C that differs from
A^t A in even one entry satisfies the identity with probability at most
1/2 per probe, so ``probes=k`` bounds the false-negative probability by
2^-k while NaN/Inf and negative-diagonal corruption are caught
deterministically (DESIGN.md §13 derives the bound).

Three layers, all host-side numpy in float64 (the probe must not itself
run through the machinery it is checking):

* :func:`finite_ok` — NaN/Inf scan (catches poisoned tiles, bf16
  overflow, uninitialized output).
* :func:`freivalds_gram` — the randomized identity probe (catches
  *finite* silent corruption: a wrong tile, a dropped leaf product, a
  stale executable).
* :func:`verify_gram` — the combined verdict the serving layer consults
  (``gram.engine.GramEngine``): finite scan, diagonal nonnegativity
  (diag(A^t A)_j = ||A[:, j]||^2 >= 0 — exact for the packed/tril path),
  then ``probes`` Freivalds rounds.

Tolerances: the probe compares two float64 reductions of data that was
*accumulated* in the kernel's fp32 (or looser) arithmetic, so the
threshold is relative to the probe's own magnitude ``||Ax||^2`` with a
dtype-driven default (``default_rtol``).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import numpy as np

from ..obs import trace as _trace

__all__ = [
    "GramVerdict", "VerificationError", "default_rtol", "finite_ok",
    "freivalds_gram", "verify_gram", "check_packed_state",
]


class VerificationError(RuntimeError):
    """A served/finalized Gram failed its output guard."""


class GramVerdict(NamedTuple):
    ok: bool                 # all guards passed
    finite: bool             # no NaN/Inf anywhere in C
    diag_ok: bool            # diag(C) >= -tol (Gram diagonals are norms)
    freivalds_ok: bool       # every probe satisfied the identity
    probes: int              # probes run
    max_rel_err: float       # worst |x^tCx - ||Ax||^2| / max(||Ax||^2, eps)

    def reason(self) -> str:
        if self.ok:
            return "ok"
        if not self.finite:
            return "non-finite entries"
        if not self.diag_ok:
            return "negative diagonal"
        return (f"freivalds identity violated "
                f"(rel err {self.max_rel_err:.3e} over {self.probes} probes)")


def default_rtol(dtype) -> float:
    """Probe tolerance by *operand* dtype: fp32 accumulation error across
    a Strassen recursion sits well under 1e-4 relative (the repo's parity
    suites pin 1e-5 at 512^2); half dtypes carry ~5e-2.  fp8 operand
    tiles (DESIGN.md §16) quantize once before fp32 accumulation, so the
    Freivalds residual is bounded by the quantization step: eps(e4m3) =
    2^-3, eps(e5m2) = 2^-2, each given 2x headroom for the Strassen
    signed-sum amplification."""
    dt = np.dtype(dtype) if not isinstance(dtype, str) else None
    name = dt.name if dt is not None else str(dtype)
    if name == "float8_e5m2":
        return 5e-1
    if name.startswith("float8"):
        return 2.5e-1
    if name in ("float16", "bfloat16"):
        return 5e-2
    if name == "float64":
        return 1e-10
    return 1e-4


def finite_ok(c: np.ndarray) -> bool:
    return bool(np.isfinite(c).all())


def _as_full(c: np.ndarray, full: bool) -> np.ndarray:
    """Symmetric C from a served result (mirror a tril-only result)."""
    c = np.asarray(c, np.float64)
    if full:
        return c
    return np.tril(c) + np.tril(c, -1).T


def freivalds_gram(a: np.ndarray, c: np.ndarray, *, probes: int = 2,
                   rtol: Optional[float] = None, gram_of: str = "cols",
                   full: bool = True,
                   rng: Optional[np.random.Generator] = None
                   ) -> tuple[bool, float]:
    """(passed, max relative error) of ``probes`` Rademacher probes of the
    identity x^t C x == ||Ax||^2 (cols) / ||A^t x||^2 (rows).

    ``full=False`` treats ``c`` as lower-triangular (the packed serving
    path) and mirrors it first.  O(probes * (mn + n^2)) on the host.
    """
    if probes <= 0:
        return True, 0.0
    a64 = np.asarray(a, np.float64)
    if gram_of == "rows":
        a64 = a64.T                   # C = A A^t == (A^t)^t (A^t)
    c64 = _as_full(c, full)
    n = c64.shape[0]
    if a64.shape[1] != n:
        raise ValueError(f"A {a.shape} does not produce a {c64.shape} "
                         f"{gram_of} gram")
    if rtol is None:
        rtol = default_rtol(np.asarray(a).dtype)
    if rng is None:
        rng = np.random.default_rng(0)
    worst = 0.0
    for _ in range(probes):
        x = rng.integers(0, 2, size=n).astype(np.float64) * 2.0 - 1.0
        lhs = float(x @ (c64 @ x))
        ax = a64 @ x
        rhs = float(ax @ ax)
        # scale by the probe magnitude; the Frobenius floor keeps a tiny
        # ||Ax||^2 (possible for rank-deficient A) from exploding the
        # relative error on a correct C
        scale = max(rhs, float(np.sum(a64 * a64)) / max(n, 1), 1e-30)
        worst = max(worst, abs(lhs - rhs) / scale)
    return worst <= rtol, worst


def verify_gram(a: np.ndarray, c: np.ndarray, *, probes: int = 2,
                rtol: Optional[float] = None, gram_of: str = "cols",
                full: bool = True,
                rng: Optional[np.random.Generator] = None) -> GramVerdict:
    """Full guard stack for one served Gram (see module docstring).

    Deterministic guards run first (finite scan, diagonal nonnegativity);
    the randomized identity probes only run on arrays that passed them —
    a NaN would otherwise poison the probe arithmetic itself.
    """
    c_arr = np.asarray(c)
    finite = finite_ok(c_arr)
    diag_ok = True
    fre_ok, worst = True, math.inf
    if finite:
        if rtol is None:
            rtol = default_rtol(np.asarray(a).dtype)
        d = np.diagonal(c_arr).astype(np.float64)
        scale = float(np.abs(d).max()) if d.size else 0.0
        diag_ok = bool((d >= -rtol * max(scale, 1.0)).all())
        fre_ok, worst = freivalds_gram(a, c_arr, probes=probes, rtol=rtol,
                                       gram_of=gram_of, full=full, rng=rng)
    ok = finite and diag_ok and fre_ok
    if not ok:
        _trace.instant(
            "verify_veto",
            reason=("non_finite" if not finite
                    else "negative_diagonal" if not diag_ok
                    else "freivalds"))
    return GramVerdict(ok=ok, finite=finite, diag_ok=diag_ok,
                       freivalds_ok=fre_ok,
                       probes=probes if finite else 0, max_rel_err=worst)


def check_packed_state(packed: np.ndarray, n: int, *,
                       rtol: float = 1e-4) -> None:
    """Finalize-time guard for streamed packed-tril state: NaN/Inf scan +
    diagonal nonnegativity (no A to probe against — the stream consumed
    it).  Raises :class:`VerificationError` on violation."""
    p = np.asarray(packed)
    if not np.isfinite(p).all():
        _trace.instant("verify_veto", reason="non_finite", where="stream")
        raise VerificationError(
            "streamed Gram state contains non-finite entries")
    # diagonal of the packed lower triangle: row r starts at r(r+1)/2,
    # its diagonal entry sits at offset r within the row
    idx = np.arange(n) * (np.arange(n) + 3) // 2
    d = p.astype(np.float64)[idx]
    scale = float(np.abs(d).max()) if d.size else 0.0
    if not (d >= -rtol * max(scale, 1.0)).all():
        _trace.instant("verify_veto", reason="negative_diagonal",
                       where="stream")
        raise VerificationError(
            "streamed Gram state has a negative diagonal entry")
