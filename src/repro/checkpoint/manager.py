"""Checkpointing: atomic, async, keep-K, mesh-agnostic (elastic restart).

Layout: <dir>/step_<n>/state.npz + meta.json, committed by atomic rename of
a ".tmp" directory — a crash mid-write never corrupts the latest
checkpoint. Leaves are stored as host numpy keyed by their pytree path
('/'-joined dict keys), independent of any device mesh; ``restore``
re-places them with whatever shardings the *current* mesh wants, so a
restart may use a different device count (elastic reshard-on-load).

The async writer runs on one background thread; ``wait()`` joins it (used
before reading a checkpoint back and at shutdown). Failed async saves are
re-raised on the next call so errors are never silently dropped.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path

_BF16_PREFIX = "__bf16__"


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(f"#{k.idx}")
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_pytree(tree: Any, file: str) -> None:
    """Flatten (dicts/lists of arrays) -> npz with path-encoded keys."""
    flat, _ = tree_flatten_with_path(tree)
    out: Dict[str, np.ndarray] = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:          # npz has no bf16: tag + u16
            out[_BF16_PREFIX + key] = arr.view(np.uint16)
        else:
            out[key] = arr
    np.savez(file, **out)


def _insert(tree: dict, parts, value):
    head = parts[0]
    if len(parts) == 1:
        tree[head] = value
        return
    tree.setdefault(head, {})
    _insert(tree[head], parts[1:], value)


def _listify(node):
    """Convert {'#0':..., '#1':...} dicts back into lists."""
    if not isinstance(node, dict):
        return node
    if node and all(re.fullmatch(r"#\d+", k) for k in node):
        return [_listify(node[f"#{i}"]) for i in range(len(node))]
    return {k: _listify(v) for k, v in node.items()}


def load_pytree(file: str, shardings=None) -> Any:
    """npz -> nested dict/list tree. ``shardings``: optional matching pytree
    of NamedSharding — leaves are device_put with them (elastic reshard)."""
    data = np.load(file)
    tree: dict = {}
    for key in data.files:
        arr = data[key]
        if key.startswith(_BF16_PREFIX):
            key = key[len(_BF16_PREFIX):]
            arr = arr.view(jnp.bfloat16)
        _insert(tree, key.split("/"), arr)
    tree = _listify(tree)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s),
                            tree, shardings)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        self.wait()                       # one in-flight save at a time
        if self._error:
            err, self._error = self._error, None
            raise err
        host_state = jax.device_get(state)   # snapshot NOW (async-safe)

        def work():
            try:
                self._write(step, host_state, extra or {})
            except BaseException as e:       # surfaced on next save/wait
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error:
                err, self._error = self._error, None
                raise err

    def _write(self, step: int, state, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        save_pytree(state, os.path.join(tmp, "state.npz"))
        meta = {"step": step, "time": time.time(), **extra}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- read -------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings=None):
        """Returns (state, meta). ``shardings``: pytree for elastic
        reshard-on-load (may target a different mesh than the save).

        Asking for the *latest* checkpoint (``step=None``) walks back
        over unreadable ones (torn meta.json / bit-rotted npz — the
        atomic-rename commit makes these rare, but a disk can still rot
        a committed directory) with a warning per skip, so a recovering
        process restarts from the newest *intact* state instead of
        dying on the newest directory.  An explicitly requested step
        still raises: the caller asked for that state, silently handing
        back another would be wrong.
        """
        self.wait()
        if step is not None:
            return self._read(step, shardings)
        for s in reversed(self.all_steps()):
            try:
                return self._read(s, shardings)
            except (OSError, ValueError, KeyError,
                    json.JSONDecodeError) as e:
                warnings.warn(
                    f"checkpoint step_{s:08d} in {self.dir} is unreadable "
                    f"({type(e).__name__}: {e}); falling back to the "
                    f"previous checkpoint", stacklevel=2)
        return None, None

    def _read(self, step: int, shardings=None):
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        state = load_pytree(os.path.join(d, "state.npz"), shardings)
        return state, meta
