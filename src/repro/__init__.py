"""repro: Strassen-based A^tA (ATA) multi-pod JAX framework."""
__version__ = "1.0.0"
