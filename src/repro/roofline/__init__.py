from .hlo_census import collective_census, CollectiveOp  # noqa: F401
from .analysis import roofline_terms, load_artifacts, HW  # noqa: F401
