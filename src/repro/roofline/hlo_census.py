"""Collective census over post-SPMD compiled HLO text.

``cost_analysis`` has no collective figures, so we parse
``compiled.as_text()`` and sum bytes per collective kind. Shapes in
post-partitioning HLO are PER-DEVICE, so the census yields per-device
collective traffic directly.

Wire-byte model per device for a group of size P (ring algorithms):
  all-reduce:          2 (P-1)/P * result_bytes
  all-gather:            (P-1)/P * result_bytes  (result = P * shard)
  reduce-scatter:        (P-1)/P * operand_bytes = (P-1) * result_bytes
  all-to-all:            (P-1)/P * result_bytes
  collective-permute:              result_bytes

CPU-backend caveat: XLA:CPU legalizes bf16 dots to f32 and sometimes hoists
the convert ABOVE a collective, inflating its dtype to f32 (2x bytes vs the
TPU lowering). Ops whose operand chain is a convert-from-bf16 are flagged
and an adjusted (halved) byte count is reported alongside the raw one.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, List

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_OP = re.compile(
    r"=\s*(?:\(?[a-z0-9]+\[[\d,]*\][^ ]*,?\s*)+\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERANDS = re.compile(r"\(\s*(%?[\w.\-]+)")


@dataclass
class CollectiveOp:
    kind: str
    dtype: str
    elements: int
    result_bytes: int
    group_size: int
    wire_bytes: float            # per-device, ring model
    bf16_inflated: bool          # CPU legalization hoisted a bf16->f32 convert
    name: str = ""


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _wire_bytes(kind: str, result_bytes: int, p: int) -> float:
    if kind == "collective-permute":     # no replica_groups attr: p-free
        return float(result_bytes)
    if p <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (p - 1) / p * result_bytes
    if kind == "all-gather":
        return (p - 1) / p * result_bytes
    if kind == "reduce-scatter":
        return float((p - 1) * result_bytes)
    if kind == "all-to-all":
        return (p - 1) / p * result_bytes
    return float(result_bytes)   # collective-permute


def collective_census(hlo_text: str) -> List[CollectiveOp]:
    # first pass: instruction table name -> (dtype, opcode-ish line)
    instr: Dict[str, tuple] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if m:
            instr[m.group(1)] = (m.group(2), line)

    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        om = _OP.search(line)
        if not om:
            continue
        kind = om.group(1).replace("-start", "")
        im = _INSTR.match(line)
        if not im:
            continue
        name, dtype, dims = im.group(1), im.group(2), im.group(3)
        elems = _shape_elems(dims)
        nbytes = elems * DTYPE_BYTES.get(dtype, 4)

        g = _GROUPS_IOTA.search(line)
        if g:
            group_size = int(g.group(2))
        else:
            g2 = _GROUPS_LIST.search(line)
            group_size = (g2.group(1).count(",") + 1) if g2 else 1

        # detect convert-inflation: operand instruction is a convert (or a
        # convert fusion) — the TPU lowering would move bf16 on the wire.
        inflated = False
        after = line[om.end():]
        opm = _OPERANDS.match("(" + after)
        if opm and dtype == "f32":
            op_name = opm.group(1).lstrip("%")
            src = instr.get(op_name)
            if src and "convert" in op_name:
                inflated = True
            elif src and "convert" in src[1][:200]:
                inflated = True

        ops.append(CollectiveOp(
            kind=kind, dtype=dtype, elements=elems, result_bytes=nbytes,
            group_size=group_size,
            wire_bytes=_wire_bytes(kind, nbytes, group_size),
            bf16_inflated=inflated, name=name))
    return ops


# ---------------------------------------------------------------------------
# HBM-materialized intermediate census (DESIGN.md §4).
#
# Model: every non-trivial HLO instruction output is a buffer the backend
# may materialize; summing their sizes over the compiled module (loop
# bodies counted once) gives a backend-agnostic upper bound on intermediate
# HBM traffic.  Parameters, constants and pure aliasing ops are excluded.
# This is the metric BENCH_ata.json tracks for fused-vs-reference: the
# reference ATA recursion materializes every operand sum, every Strassen
# M_i and the per-level pad/concatenate copies, all of which simply do not
# exist in the fused schedule's HLO.
# ---------------------------------------------------------------------------

_ALIAS_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "iota",
})

_RHS_INSTR = re.compile(
    r"=\s*\(?\s*((?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?\s*,?\s*)+)\)?\s*"
    r"([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# Computation headers: `%name (params...) -> type {` / `ENTRY %name (...)`.
# Param lists contain nested parens for tuple-typed args (while/cond region
# bodies), so the header is recognized structurally — name followed by "("
# on a line that declares a return type and opens a body — rather than by
# matching the param list itself.
_COMP_HEADER = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def hbm_intermediate_census(hlo_text: str) -> Dict:
    """Sum HBM-materialized intermediate bytes over compiled HLO text.

    Instructions inside fusion computations are skipped — only the fusion's
    own output buffer materializes, and it is counted at the call site.
    The ENTRY computation's ROOT is the program's *result*, not an
    intermediate, and is excluded (a bare ``jit(dot)`` censuses as 0).

    Returns ``{"total_bytes", "count", "by_opcode": {op: bytes}}``.
    """
    by_opcode: Dict[str, int] = {}
    count = 0
    total = 0
    in_fusion = False
    in_entry = False
    for line in hlo_text.splitlines():
        hdr = _COMP_HEADER.match(line)
        if hdr and "->" in line and line.rstrip().endswith("{"):
            in_fusion = "fused" in hdr.group(1)
            in_entry = line.lstrip().startswith("ENTRY")
            continue
        if in_fusion:
            continue
        if in_entry and line.lstrip().startswith("ROOT"):
            continue
        m = _RHS_INSTR.search(line)
        if not m:
            continue
        shapes, opcode = m.group(1), m.group(2)
        if opcode in _ALIAS_OPS:
            continue
        nbytes = 0
        for dtype, dims in _SHAPE.findall(shapes):
            if dtype not in DTYPE_BYTES:
                continue
            nbytes += _shape_elems(dims) * DTYPE_BYTES[dtype]
        if nbytes == 0:
            continue
        total += nbytes
        count += 1
        by_opcode[opcode] = by_opcode.get(opcode, 0) + nbytes
    return {"total_bytes": total, "count": count,
            "by_opcode": dict(sorted(by_opcode.items(),
                                     key=lambda kv: -kv[1]))}


def summarize(ops: List[CollectiveOp]) -> Dict:
    by_kind: Dict[str, Dict] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "result_bytes": 0,
                                         "wire_bytes": 0.0,
                                         "wire_bytes_bf16adj": 0.0})
        d["count"] += 1
        d["result_bytes"] += op.result_bytes
        d["wire_bytes"] += op.wire_bytes
        d["wire_bytes_bf16adj"] += (op.wire_bytes / 2 if op.bf16_inflated
                                    else op.wire_bytes)
    total = sum(d["wire_bytes"] for d in by_kind.values())
    total_adj = sum(d["wire_bytes_bf16adj"] for d in by_kind.values())
    return {"by_kind": by_kind,
            "wire_bytes_total": total,
            "wire_bytes_total_bf16adj": total_adj,
            "ops": [asdict(o) for o in ops]}
