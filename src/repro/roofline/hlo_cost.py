"""Trip-count-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` (HloCostAnalysis) counts a ``while`` body ONCE
— for scan-over-layers models that undercounts FLOPs/bytes/collectives by
the layer count (verified: a 48-iteration scan of a 2*8*128*128-FLOP body
reports 262146 flops). This module re-derives costs from ``as_text()``:

  * computations are parsed into instruction lists;
  * ``while`` bodies are weighted by ``backend_config known_trip_count``;
  * ``fusion``/``call`` recurse for FLOPs, but count only interface bytes
    (a fusion is one kernel: inputs read once, outputs written once);
  * ``dot`` FLOPs are exact: 2 * prod(result) * prod(contracting dims);
    everything else counts ~1 FLOP/output element;
  * dynamic-update-slice counts update bytes only (in-place semantics,
    matching HloCostAnalysis), so scan-carried KV caches are not
    overcounted;
  * collectives are censused with their loop multiplier applied.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hlo_census import DTYPE_BYTES, CollectiveOp, _wire_bytes

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count...?.?n.:."?(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE = re.compile(
    r"true_computation=%?([\w.\-]+).*false_computation=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start"}

# Pure dtype-conversion (+layout move) fusions: XLA:CPU legalizes bf16 dot
# operands to f32 — these fusions do not exist in the TPU lowering, so the
# TPU-adjusted bytes model drops them (raw bytes kept separately).
_PURE_CONVERT = re.compile(
    r"^(?:(?:bitcast|copy|convert|transpose)_)*convert"
    r"(?:_(?:bitcast|copy|transpose))*(?:_fusion)?(?:\.\d+)?$")
ZERO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "iota", "partition-id",
                  "replica-id", "opt-barrier"}


@dataclass
class Instr:
    name: str
    dtype: Optional[str]
    dims: Optional[List[int]]
    opcode: str
    operands: List[str]
    attrs: str
    raw_shape: str = ""

    @property
    def elems(self) -> int:
        if self.dims is None:
            return 0
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def nbytes(self) -> int:
        if self.dtype is None:
            return 0
        return self.elems * DTYPE_BYTES.get(self.dtype, 4)


def parse_module(text: str) -> Tuple[Dict[str, Dict[str, Instr]], str]:
    comps: Dict[str, Dict[str, Instr]] = {}
    entry = ""
    cur: Optional[Dict[str, Instr]] = None
    for line in text.splitlines():
        hm = _COMP_HDR.match(line.strip())
        if hm and "=" not in line.split("(")[0]:
            name = hm.group(2)
            cur = comps.setdefault(name, {})
            if hm.group(1):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, shape_s, opcode, rest = im.groups()
        sm = _SHAPE.match(shape_s)
        if sm and not shape_s.startswith("("):
            dtype = sm.group(1)
            dims = [int(x) for x in sm.group(2).split(",")] if sm.group(2) \
                else []
        else:
            dtype, dims = None, None
        # operands: %names before the closing paren of the op call
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opstr = rest[:end]
        operands = _OPERAND.findall(opstr)
        cur[name] = Instr(name, dtype, dims, opcode, operands,
                          rest[end:], raw_shape=shape_s)
    return comps, entry


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._flops: Dict[str, float] = {}
        self._bytes: Dict[str, float] = {}
        self._census: Dict[str, List[CollectiveOp]] = {}
        self.unknown_trip_loops = 0

    # ---- helpers ---------------------------------------------------------
    def _called(self, instr: Instr):
        m = _CALLS.search(instr.attrs)
        return m.group(1) if m else None

    def _dot_flops(self, comp: Dict[str, Instr], instr: Instr) -> float:
        m = _CONTRACT.search(instr.attrs)
        contract = [int(x) for x in m.group(1).split(",")] if m and m.group(1) \
            else []
        lhs = comp.get(instr.operands[0]) if instr.operands else None
        k = 1
        if lhs is not None and lhs.dims is not None:
            for c in contract:
                if c < len(lhs.dims):
                    k *= lhs.dims[c]
        return 2.0 * instr.elems * k

    # ---- FLOPs (fusions recursed) ----------------------------------------
    def comp_flops(self, name: str) -> float:
        if name in self._flops:
            return self._flops[name]
        self._flops[name] = 0.0           # cycle guard
        comp = self.comps.get(name, {})
        total = 0.0
        for instr in comp.values():
            op = instr.opcode
            if op == "dot":
                total += self._dot_flops(comp, instr)
            elif op == "fusion" or op == "call":
                callee = self._called(instr)
                if callee:
                    total += self.comp_flops(callee)
            elif op == "while":
                trip = self._trip(instr)
                body = self._called(instr)
                cond = _COND.search(instr.attrs)
                t = self.comp_flops(body) if body else 0.0
                if cond:
                    t += self.comp_flops(cond.group(1))
                total += trip * t
            elif op == "conditional":
                total += max((self.comp_flops(b)
                              for b in self._branches(instr)), default=0.0)
            elif op in COLLECTIVES or op in ZERO_BYTES_OPS:
                pass
            elif op == "reduce" or op == "reduce-window":
                # ~1 flop per reduced input element
                src = comp.get(instr.operands[0]) if instr.operands else None
                total += src.elems if (src and src.dims) else instr.elems
            else:
                total += instr.elems
        self._flops[name] = total
        return total

    def _trip(self, instr: Instr) -> int:
        m = _TRIP.search(instr.attrs)
        if m:
            return int(m.group(1))
        self.unknown_trip_loops += 1
        return 1

    def _branches(self, instr: Instr) -> List[str]:
        m = _BRANCHES.search(instr.attrs)
        if m:
            return _OPERAND.findall(m.group(1)) or \
                [s.strip().lstrip("%") for s in m.group(1).split(",")]
        m = _TRUE_FALSE.search(instr.attrs)
        return list(m.groups()) if m else []

    # ---- bytes (fusion interface only; control flow recursed) -------------
    def comp_bytes(self, name: str) -> float:
        if name in self._bytes:
            return self._bytes[name]
        self._bytes[name] = 0.0
        comp = self.comps.get(name, {})
        total = 0.0
        for instr in comp.values():
            op = instr.opcode
            if op in ZERO_BYTES_OPS or op in COLLECTIVES:
                continue
            if op == "fusion" and _PURE_CONVERT.match(instr.name):
                continue                      # CPU-only bf16->f32 legalization
            if op == "fusion" and "dynamic-update-slice" in instr.name:
                # in-place update: traffic = update in + out (not the buffer)
                small = min((comp[o].nbytes for o in instr.operands
                             if o in comp and comp[o].nbytes > 0),
                            default=instr.nbytes)
                total += 2.0 * small
                continue
            if op == "fusion" and "dynamic-slice" in instr.name:
                total += 2.0 * instr.nbytes   # slice read + result write
                continue
            if op == "while":
                body = self._called(instr)
                total += self._trip(instr) * (self.comp_bytes(body)
                                              if body else 0.0)
                continue
            if op == "conditional":
                total += max((self.comp_bytes(b)
                              for b in self._branches(instr)), default=0.0)
                continue
            if op == "call":
                callee = self._called(instr)
                total += self.comp_bytes(callee) if callee else 0.0
                continue
            if op == "dynamic-update-slice":
                upd = comp.get(instr.operands[1]) if len(instr.operands) > 1 \
                    else None
                total += 2.0 * (upd.nbytes if upd else 0)
                continue
            if op == "dynamic-slice":
                total += 2.0 * instr.nbytes
                continue
            # default: result + operand interface bytes
            total += instr.nbytes
            for o in instr.operands:
                src = comp.get(o)
                if src is not None and src.opcode not in ("constant",):
                    total += src.nbytes
        self._bytes[name] = total
        return total

    # ---- collectives (with loop multipliers) -------------------------------
    def comp_census(self, name: str, mult: float = 1.0,
                    out: Optional[List] = None) -> List[CollectiveOp]:
        out = out if out is not None else []
        comp = self.comps.get(name, {})
        for instr in comp.values():
            op = instr.opcode
            if op in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                kind = op.replace("-start", "")
                g = _GROUPS_IOTA.search(instr.attrs)
                if g:
                    group = int(g.group(2))
                else:
                    g2 = _GROUPS_LIST.search(instr.attrs)
                    group = (g2.group(1).count(",") + 1) if g2 else 1
                nbytes = instr.nbytes
                if instr.dims is None:
                    # tuple result (async start ops): sum the element shapes
                    nbytes = 0
                    for dt, dims in _SHAPE.findall(instr.raw_shape or ""):
                        n = 1
                        for x in dims.split(","):
                            if x:
                                n *= int(x)
                        nbytes += n * DTYPE_BYTES.get(dt, 4)
                    nbytes //= 2 if "-start" in op else 1
                inflated = instr.dtype == "f32" and any(
                    "convert" in o for o in instr.operands)
                wire = _wire_bytes(kind, nbytes, group) * mult
                out.append(CollectiveOp(
                    kind=kind, dtype=instr.dtype or "f32",
                    elements=int(instr.elems * mult),
                    result_bytes=int(nbytes * mult), group_size=group,
                    wire_bytes=wire, bf16_inflated=inflated,
                    name=f"{name}/{instr.name}"))
            elif op == "while":
                body = self._called(instr)
                if body:
                    self.comp_census(body, mult * self._trip(instr), out)
            elif op in ("fusion", "call"):
                callee = self._called(instr)
                if callee:
                    self.comp_census(callee, mult, out)
            elif op == "conditional":
                for b in self._branches(instr):
                    self.comp_census(b, mult, out)
        return out

    # ---- totals ------------------------------------------------------------
    def totals(self) -> Dict:
        census = self.comp_census(self.entry)
        from .hlo_census import summarize
        summary = summarize(census)
        summary.pop("ops", None)
        return {
            "flops": self.comp_flops(self.entry),
            "bytes": self.comp_bytes(self.entry),
            "collectives": summary,
            "unknown_trip_loops": self.unknown_trip_loops,
        }


def analyze_hlo(text: str) -> Dict:
    return HloCost(text).totals()
