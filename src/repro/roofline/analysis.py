"""Three-term roofline from dry-run artifacts (TPU v5e constants).

Per (arch x shape x mesh) cell, from the compiled dry-run:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / ICI_link_bw

cost_analysis() of a partitioned executable reports PER-DEVICE figures
(verified against hand-computed examples), which is equivalent to the
global/(chips * peak) formulation. wire bytes come from the HLO census
(ring-model per-device traffic; bf16-adjusted for CPU convert hoisting).

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = (active) params,
D = tokens — the "useful" fraction MODEL_FLOPS / (HLO_FLOPs * chips)
exposes remat recompute and padding waste.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class HW:
    """TPU v5e."""
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # capacity / chip


V5E = HW()


def model_flops(artifact: dict) -> Optional[float]:
    kind = artifact.get("kind")
    n = artifact.get("active_param_count") or artifact.get("param_count")
    if kind == "gram":
        # classical FLOPs of A^tA: m*n^2 MACs = 2*m*n^2 (upper bound ref)
        return 2.0 * artifact["m"] * artifact["n"] ** 2
    if not n:
        return None
    tokens = artifact["global_batch"] * (
        1 if kind == "decode" else artifact["seq_len"])
    per_token = 6.0 * n if kind == "train" else 2.0 * n
    return per_token * tokens


def roofline_terms(artifact: dict, hw: HW = V5E) -> Dict:
    if artifact.get("status") != "ok":
        return {"cell": artifact.get("cell"), "status": artifact.get("status")}
    cost = artifact["cost"]
    chips = 1
    for s in artifact.get("mesh_shape", []):
        chips *= s
    if not artifact.get("mesh_shape"):
        chips = 512 if "2x16x16" in artifact.get("mesh", "") else 256

    corrected = artifact.get("cost_corrected") or {}
    flops_dev = corrected.get("flops") or cost.get("flops", 0.0)
    bytes_dev = corrected.get("bytes") or cost.get("bytes accessed", 0.0)
    sub = artifact.get("kernel_substitution")
    if sub:     # hand-written kernel FLOPs, counted analytically
        chips_tmp = 1
        for s in artifact.get("mesh_shape", []) or [512]:
            chips_tmp *= s
        flops_dev += sub["flops_global"] / chips_tmp
    coll = artifact.get("collectives_corrected") or artifact["collectives"]
    wire_dev = coll.get("wire_bytes_total_bf16adj",
                        coll.get("wire_bytes_total", 0.0))

    t_compute = flops_dev / hw.peak_flops
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = wire_dev / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())

    mf = model_flops(artifact)
    useful = (mf / (flops_dev * chips)) if (mf and flops_dev) else None
    t_model = (mf / (chips * hw.peak_flops)) if mf else None
    frac = (t_model / t_bound) if (t_model and t_bound > 0) else None

    mem = artifact["memory"]
    hbm_per_dev = (mem["argument_size_in_bytes"]
                   + mem["temp_size_in_bytes"]
                   + mem["output_size_in_bytes"]
                   - mem["alias_size_in_bytes"])
    return {
        "cell": artifact["cell"], "arch": artifact["arch"],
        "shape": artifact["shape"], "mesh": artifact["mesh"],
        "kind": artifact["kind"], "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "t_bound_s": t_bound,
        "model_flops": mf, "hlo_flops_per_dev": flops_dev,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "hbm_bytes_per_dev": hbm_per_dev,
        "fits_hbm": hbm_per_dev <= hw.hbm_bytes,
        "status": "ok",
    }


def load_artifacts(directory: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _fmt_t(t):
    if t is None:
        return "-"
    if t >= 1:
        return f"{t:7.2f}s "
    if t >= 1e-3:
        return f"{t*1e3:7.2f}ms"
    return f"{t*1e6:7.1f}us"


def render_table(rows: List[dict]) -> str:
    head = (f"{'cell':<46} {'tCOMP':>9} {'tMEM':>9} {'tCOLL':>9} "
            f"{'dom':<6} {'useful':>7} {'roofl%':>7} {'HBM/dev':>8} fits")
    lines = [head, "-" * len(head)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r.get('cell', '?'):<46} {r.get('status')}")
            continue
        u = f"{r['useful_flop_ratio']*100:6.1f}%" if r["useful_flop_ratio"] else "      -"
        fr = f"{r['roofline_fraction']*100:6.1f}%" if r["roofline_fraction"] else "      -"
        lines.append(
            f"{r['cell']:<46} {_fmt_t(r['t_compute_s'])} "
            f"{_fmt_t(r['t_memory_s'])} {_fmt_t(r['t_collective_s'])} "
            f"{r['dominant']:<6} {u} {fr} "
            f"{r['hbm_bytes_per_dev']/2**30:7.2f}G "
            f"{'y' if r['fits_hbm'] else 'N'}")
    return "\n".join(lines)
