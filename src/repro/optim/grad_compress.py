"""Error-feedback gradient compression for cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarcest bandwidth.  Two schemes,
both Seide-et-al.-style error feedback (the compression residual is kept
locally and added back before the next compression, so the *accumulated*
error stays bounded and convergence is preserved):

* ``compressed_psum`` — int8: each leaf is quantized to int8 with a
  per-leaf fp32 scale before the cross-pod reduction (4x fewer bytes).
* ``lowrank_psum`` — Gram-powered low-rank (PowerSGD-flavored): for tall
  2-D leaves the devices agree on a shared top-``rank`` right-singular
  basis Q by all-reducing the *Gram* of the gradient — `sum_i G_i^t G_i`,
  which is exactly ``core.distributed.gram_allreduce`` over the pod axis,
  i.e. the paper's A^tA as the service op inside a distributed reduction
  — then reduce only the rank-sized projection ``G_i Q``.  Wire payload
  per leaf: n^2 + rank*m words, vs m*n uncompressed — a win for tall
  leaves (m >> n + rank), e.g. embeddings and vocab projections; leaves
  where low-rank does not pay fall back to the int8 path.

Used by the trainer inside ``shard_map`` over the 'pod' axis only; the
intra-pod reduction stays full-precision (fast ICI).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: object            # pytree matching grads, fp32

    @staticmethod
    def init(grads_like):
        return ErrorFeedback(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def int8_quantize(x: jax.Array):
    """fp -> (int8 values, fp32 scale). Symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _axis_size(axis: str):
    # jax.lax.axis_size is missing on older jax and the size is only a
    # divisor here, so the traced psum(1) form is version-portable
    return getattr(jax.lax, "axis_size", lambda a: jax.lax.psum(1, a))(axis)


def _int8_leaf(g, r, axis: str, n):
    """One leaf of the int8 error-feedback reduction: (mean grad, residual).

    Wire payload is the int8 tensor (+one fp32 scale) per participant —
    an ``all_gather`` of int8 then a local dequantized sum, exact w.r.t.
    the quantized values (scales differ per pod, so a plain psum of int8
    would be wrong).
    """
    gf = g.astype(jnp.float32) + r
    q, scale = int8_quantize(gf)
    new_r = gf - int8_dequantize(q, scale)            # residual stays local
    qg = jax.lax.all_gather(q, axis)                  # (n, ...) int8 on wire
    sg = jax.lax.all_gather(scale, axis)              # (n,) fp32
    total = jnp.einsum("n,n...->...", sg, qg.astype(jnp.float32))
    return total / n, new_r


def compressed_psum(grads, axis: str, ef: ErrorFeedback):
    """Error-feedback int8 all-reduce over mesh axis ``axis``.

    Must run inside shard_map with ``axis`` in scope.  Returns
    (mean-reduced fp32 grads, new ErrorFeedback).
    """
    n = _axis_size(axis)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [_int8_leaf(g, r, axis, n) for g, r in zip(flat_g, flat_r)]
    reduced = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return reduced, ErrorFeedback(new_res)


def lowrank_basis(g2d: jax.Array, rank: int, *, levels=1, leaf: int = 256,
                  mode: str = "auto", axis=None) -> jax.Array:
    """Shared top-``rank`` right-singular basis of a (stacked) gradient.

    The basis is the top eigenvectors of the Gram ``sum_i G_i^t G_i`` —
    THE paper's operation, computed through the ATA pipeline: locally via
    ``core.ata.ata_full``, or (``axis`` given, inside shard_map) via
    ``core.distributed.gram_allreduce`` so every participant derives the
    *same* basis from the stacked-gradient Gram.
    """
    if axis is None:
        from ..core.ata import ata_full
        c = ata_full(g2d.astype(jnp.float32), levels=levels, leaf=leaf,
                     mode=mode, out_dtype=jnp.float32)
    else:
        from ..core.distributed import gram_allreduce
        c = gram_allreduce(g2d.astype(jnp.float32), axis, levels=levels,
                           leaf=leaf, mode=mode, out_dtype=jnp.float32)
    _, v = jnp.linalg.eigh(c)                  # ascending eigenvalues
    return v[:, -rank:]                        # (n, rank), orthonormal


def lowrank_psum(grads, axis: str, ef: ErrorFeedback, *, rank: int = 8,
                 levels=1, leaf: int = 256, mode: str = "auto",
                 min_rows: int = 0):
    """Gram-powered low-rank error-feedback all-reduce (module docstring).

    2-D leaves with ``m > max(min_rows, n + rank)`` (where low-rank beats
    shipping the leaf) are reduced as ``mean(G) Q Q^t`` with the shared
    basis Q from :func:`lowrank_basis`; everything else takes the int8
    path.  Must run inside shard_map with ``axis`` in scope.  Returns
    (mean-reduced fp32 grads, new ErrorFeedback).
    """
    n_dev = _axis_size(axis)

    def leaf_fn(g, r):
        m_n = g.shape
        if len(m_n) != 2 or m_n[0] <= max(min_rows, m_n[1] + rank) \
                or m_n[1] <= rank:
            return _int8_leaf(g, r, axis, n_dev)
        gf = g.astype(jnp.float32) + r
        q = lowrank_basis(gf, rank, levels=levels, leaf=leaf, mode=mode,
                          axis=axis)
        p = jax.lax.psum(gf @ q, axis) / n_dev     # (m, rank) on the wire
        approx = p @ q.T                           # mean(G) projected on Q
        new_r = gf - (gf @ q) @ q.T                # local reconstruction err
        return approx, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [leaf_fn(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return reduced, ErrorFeedback(new_res)
