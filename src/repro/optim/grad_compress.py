"""int8 error-feedback gradient compression for cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarcest bandwidth. ``compressed
psum`` quantizes each gradient leaf to int8 with a per-leaf fp32 scale
before the cross-pod reduction (4x fewer bytes on the slow links), keeps
the quantization residual in an error-feedback buffer (added back before
the next quantization — Seide et al. 1-bit-SGD style, so the *accumulated*
error stays bounded and convergence is preserved), and dequantizes after.

Used by the trainer inside ``shard_map`` over the 'pod' axis only; the
intra-pod reduction stays full-precision (fast ICI).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: object            # pytree matching grads, fp32

    @staticmethod
    def init(grads_like):
        return ErrorFeedback(jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def int8_quantize(x: jax.Array):
    """fp -> (int8 values, fp32 scale). Symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str, ef: ErrorFeedback):
    """Error-feedback int8 all-reduce over mesh axis ``axis``.

    Wire payload is the int8 tensor (+one fp32 scale) per participant —
    an ``all_gather`` of int8 then a local dequantized sum, exact w.r.t.
    the quantized values (scales differ per pod, so a plain psum of int8
    would be wrong). Must run inside shard_map with ``axis`` in scope.
    Returns (mean-reduced fp32 grads, new ErrorFeedback).
    """
    # axis length; jax.lax.axis_size is missing on older jax and n is only
    # a divisor here, so the traced psum(1) form is version-portable
    n = getattr(jax.lax, "axis_size", lambda a: jax.lax.psum(1, a))(axis)

    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale = int8_quantize(gf)
        new_r = gf - int8_dequantize(q, scale)        # residual stays local
        qg = jax.lax.all_gather(q, axis)              # (n, ...) int8 on wire
        sg = jax.lax.all_gather(scale, axis)          # (n,) fp32
        total = jnp.einsum("n,n...->...", sg, qg.astype(jnp.float32))
        return total / n, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return reduced, ErrorFeedback(new_res)
