"""AdamW (decoupled weight decay), functional, pytree-generic.

Moments are stored in fp32 regardless of param dtype (bf16 moments lose
too many bits at lr ~ 1e-4); the optional ``moment_dtype`` lets the giant
MoE configs trade precision for HBM (see DESIGN.md memory table).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable        # (grads, state, params, step) -> (updates, state)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def adamw(lr: Callable | float, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, grad_clip: Optional[float] = 1.0,
          moment_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        t = step + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g)
            .astype(moment_dtype), state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * g * g)
            .astype(moment_dtype), state["v"], grads)
        lr_t = lr_fn(step)

        def upd(p, m, v):
            mh = m.astype(jnp.float32) / bc1
            vh = v.astype(jnp.float32) / bc2
            u = mh / (jnp.sqrt(vh) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u

        updates = jax.tree.map(upd, params, new_m, new_v)
        state = {"m": new_m, "v": new_v}
        return updates, state, {"grad_norm": gnorm}

    return Optimizer(init, update)
