"""Blocked distributed Shampoo with ATA-powered gram statistics.

This is the paper's technique integrated as a first-class training feature:
the preconditioner statistics of every 2-D gradient block are exactly the
paper's operation —

    L = G G^t = ATA(G^t),    R = G^t G = ATA(G)

— computed with the Strassen-based ATA recursion, i.e. at (2/7) n^{log2 7}
multiplications instead of n^2(n+1)/2, and symmetric by construction (only
the lower triangle is computed, then mirrored).  The block stack goes
through the Gram service's batched path (``repro.gram.batched_gram``):
one vmapped mode-dispatched ATA over all blocks — the fused Pallas
schedule on TPU, the XLA reference recursion elsewhere — with
``ata_mode=`` exposed to force either.

Structure (after Anil et al.'s distributed Shampoo):
  * large dims are partitioned into blocks of <= block_size; each sub-block
    is preconditioned independently (block-diagonal Shampoo);
  * leading dims beyond the trailing 2 (layer stacks, expert stacks) are
    vmapped batch dims;
  * inverse-4th-roots via eigh, recomputed every ``precond_interval`` steps
    under lax.cond (kept OUTSIDE the block vmap so the skip branch really
    skips);
  * Adam grafting: the Shampoo direction is rescaled to the Adam update's
    norm; 1-D params (biases, norm scales) fall back to plain AdamW.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..gram.engine import batched_gram
from .adamw import Optimizer, clip_by_global_norm


def _plan(shape, block_size, max_blocks):
    """Static per-leaf plan: 'shampoo' (trailing 2-D preconditioned) or
    'adam'."""
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return None
    m, n = shape[-2], shape[-1]
    bsm, bsn = min(block_size, m), min(block_size, n)
    nbm, nbn = -(-m // bsm), -(-n // bsn)
    if nbm > max_blocks or nbn > max_blocks:
        return None
    return (nbm, bsm, nbn, bsn)


def _to_blocks(g, plan):
    """(..., M, N) -> (K, bsm, bsn) with K = prod(batch)*nbm*nbn."""
    nbm, bsm, nbn, bsn = plan
    batch = g.shape[:-2]
    m, n = g.shape[-2:]
    g = jnp.pad(g, [(0, 0)] * len(batch)
                + [(0, nbm * bsm - m), (0, nbn * bsn - n)])
    g = g.reshape(*batch, nbm, bsm, nbn, bsn)
    g = jnp.moveaxis(g, -2, -3)                       # (..., nbm, nbn, bsm, bsn)
    return g.reshape(-1, bsm, bsn)


def _from_blocks(blocks, plan, shape):
    nbm, bsm, nbn, bsn = plan
    batch = shape[:-2]
    m, n = shape[-2:]
    g = blocks.reshape(*batch, nbm, nbn, bsm, bsn)
    g = jnp.moveaxis(g, -2, -3).reshape(*batch, nbm * bsm, nbn * bsn)
    return g[..., :m, :n]


def _inv_4th_root(s, eps):
    """(bs, bs) symmetric PSD -> (s/trace_norm + eps I)^{-1/4} via eigh."""
    bs = s.shape[-1]
    # normalize for conditioning; the grafting rescale absorbs the factor
    tr = jnp.trace(s) / bs
    s = s / jnp.maximum(tr, 1e-30)
    w, u = jnp.linalg.eigh(s + eps * jnp.eye(bs, dtype=s.dtype))
    w = jnp.maximum(w, eps)
    return (u * (w ** -0.25)) @ u.T


def shampoo(lr, *, block_size: int = 1024, stat_interval: int = 1,
            precond_interval: int = 20, beta2_stat: float = 1.0,
            b1=0.9, b2=0.95, eps=1e-8, matrix_eps=1e-6,
            weight_decay=0.1, grad_clip: Optional[float] = 1.0,
            ata_levels: int = 1, ata_leaf: int = 128,
            max_blocks: int = 64,
            ata_variant: str = "strassen",
            ata_mode: str = "auto",
            ata_block: Optional[int] = None) -> Optimizer:
    """ATA-powered blocked Shampoo with Adam grafting.

    ``ata_mode`` ("auto" | "fused" | "reference") and ``ata_block`` are
    threaded to the batched Gram path — "auto" runs the fused Pallas
    schedule on TPU and the reference recursion elsewhere; ``ata_block=
    None`` consults the gram autotune cache for the tile size.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    gram = partial(batched_gram, levels=ata_levels, leaf=ata_leaf,
                   variant=ata_variant, mode=ata_mode, block=ata_block,
                   out_dtype=jnp.float32)

    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)

        def stats(p):
            plan = _plan(p.shape, block_size, max_blocks)
            if plan is None:
                return {"l": jnp.zeros((0,)), "r": jnp.zeros((0,)),
                        "pl": jnp.zeros((0,)), "pr": jnp.zeros((0,))}
            nbm, bsm, nbn, bsn = plan
            k = math.prod(p.shape[:-2] or (1,)) * nbm * nbn
            eye = lambda bs: jnp.broadcast_to(jnp.eye(bs, dtype=jnp.float32),
                                              (k, bs, bs))
            return {"l": jnp.zeros((k, bsm, bsm), jnp.float32),
                    "r": jnp.zeros((k, bsn, bsn), jnp.float32),
                    "pl": eye(bsm), "pr": eye(bsn)}

        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "gram": jax.tree.map(stats, params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            from .adamw import global_norm
            gnorm = global_norm(grads)
        t = step + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)
        lr_t = lr_fn(step)
        do_stat = (step % stat_interval) == 0
        do_precond = (step % precond_interval) == 0

        new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                             state["m"], grads)
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                             state["v"], grads)

        def leaf(p, g, m, v, gr):
            # Adam (grafting reference and 1-D fallback)
            mh, vh = m / bc1, v / bc2
            u_adam = mh / (jnp.sqrt(vh) + eps)
            plan = _plan(p.shape, block_size, max_blocks)
            if plan is None:
                u = u_adam
                new_gr = gr
            else:
                blk = _to_blocks(g, plan)              # (K, bsm, bsn)

                def upd_stats(_):
                    # THE paper's operation: block grams via the batched
                    # Strassen-ATA service path (mode/out_dtype threaded)
                    l_new = gram(jnp.swapaxes(blk, -1, -2))
                    r_new = gram(blk)
                    if beta2_stat >= 1.0:
                        return gr["l"] + l_new, gr["r"] + r_new
                    return (beta2_stat * gr["l"] + (1 - beta2_stat) * l_new,
                            beta2_stat * gr["r"] + (1 - beta2_stat) * r_new)

                sl, sr = jax.lax.cond(do_stat, upd_stats,
                                      lambda _: (gr["l"], gr["r"]), None)

                def recompute(_):
                    return (jax.vmap(lambda s: _inv_4th_root(s, matrix_eps))(sl),
                            jax.vmap(lambda s: _inv_4th_root(s, matrix_eps))(sr))

                pl, pr = jax.lax.cond(do_precond, recompute,
                                      lambda _: (gr["pl"], gr["pr"]), None)
                # precondition blocks of the *momentum* (common practice)
                mblk = _to_blocks(mh, plan)
                ublk = jnp.einsum("kab,kbc,kcd->kad", pl, mblk, pr)
                u_sh = _from_blocks(ublk, plan, p.shape)
                # Adam grafting: Shampoo direction at Adam magnitude
                ratio = (jnp.linalg.norm(u_adam)
                         / jnp.maximum(jnp.linalg.norm(u_sh), 1e-16))
                u = u_sh * ratio
                new_gr = {"l": sl, "r": sr, "pl": pl, "pr": pr}
            u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u, new_gr

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(new_m)
        flat_v = treedef.flatten_up_to(new_v)
        flat_gr = treedef.flatten_up_to(state["gram"])
        outs = [leaf(*args) for args in zip(flat_p, flat_g, flat_m,
                                            flat_v, flat_gr)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_gram = treedef.unflatten([o[1] for o in outs])
        new_state = {"m": new_m, "v": new_v, "gram": new_gram}
        return updates, new_state, {"grad_norm": gnorm}

    return Optimizer(init, update)
