"""Optimizers: AdamW + ATA-powered distributed Shampoo (+schedules,
gradient compression). Functional optax-like API:

    opt = adamw(cfg) | shampoo(cfg)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
"""
from .adamw import adamw, apply_updates, global_norm, clip_by_global_norm  # noqa: F401
from .shampoo import shampoo  # noqa: F401
from .schedules import warmup_cosine, warmup_linear, constant  # noqa: F401
from .grad_compress import (  # noqa: F401
    int8_quantize, int8_dequantize, compressed_psum, ErrorFeedback,
    lowrank_basis, lowrank_psum,
)
