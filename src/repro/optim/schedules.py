"""Learning-rate schedules (callables of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_linear(lr: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(s < warmup, warm, lr + (floor - lr) * frac)
    return fn


def warmup_cosine(lr: float, warmup: int, total: int, floor_ratio=0.1):
    floor = lr * floor_ratio

    def fn(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return fn
