"""whisper-small — enc-dec audio backbone [arXiv:2212.04356].

Conv frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, 768). LayerNorm, learned positions,
plain GELU MLP, MHA (kv=12), biases on projections.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,                   # decoder layers
    encoder_layers=12,
    encoder_decoder=True,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    norm="layernorm",
    pos_emb="learned",
    act="gelu_mlp",
    qkv_bias=True,
    o_bias=True,
    tie_embeddings=True,
)
