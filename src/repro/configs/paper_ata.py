"""The paper's own workload: C = A^t A gram multiplication.

Sizes from §6.2 (n = 5000, 10000, P in {6,12,18,38,76,114,250}) plus
production-scale cells for the TPU dry-run (the paper's technique as the
distributed Shampoo/normal-equations primitive at pod scale).
"""
from dataclasses import dataclass
from typing import Tuple, Union

# Paper experiment grid (CPU wall-clock reproduction, Figs 5-8)
PAPER_NS = (5000, 10000)
PAPER_PS = (6, 12, 18, 38, 76, 114, 250)
COMPLETE_LEVEL_PS = (6, 38, 250)        # P = npl(l): complete parallel levels
PAPER_MAX_SPEEDUP = 64.28               # Fig 6, n=10000, P=250
PAPER_EFFICIENCY_RANGE = (0.26, 0.66)   # Fig 7
PAPER_BASE_CASE = 32                    # Alg 1 leaf on CPU
PAPER_COMM_FRACTION = (0.0014, 0.0046)  # §6.3.2 (P=6 .. P=250)


@dataclass(frozen=True)
class GramCell:
    """One distributed-gram dry-run cell: A (m, n) sharded on the mesh.

    ``levels="auto"`` (the default) lets ``ata_levels_for`` /
    ``strassen_levels_for`` pick the natural per-shard recursion depth
    (capped at ``strassen.AUTO_MAX_LEVELS``) instead of a hard-coded 2.
    """
    name: str
    m: int
    n: int
    scheme: str = "allreduce"            # allreduce | reducescatter | ring
    levels: Union[int, str] = "auto"
    dtype: str = "bfloat16"


# Production-mesh gram cells (dry-run + roofline for the paper's technique).
# gram_64k* are one workload under four treatments — the §Perf cell-C
# hillclimb: paper-faithful allreduce -> reduce-scatter -> half-ring, and
# classical (levels=0) vs Strassen compute.
GRAM_CELLS = {
    "gram_64k": GramCell("gram_64k", m=262144, n=65536),
    "gram_64k_l0": GramCell("gram_64k_l0", m=262144, n=65536, levels=0),
    "gram_64k_rs": GramCell("gram_64k_rs", m=262144, n=65536,
                            scheme="reducescatter"),
    "gram_64k_ring": GramCell("gram_64k_ring", m=262144, n=65536,
                              scheme="ring"),
    "gram_16k_rs": GramCell("gram_16k_rs", m=1048576, n=16384,
                            scheme="reducescatter"),
}
