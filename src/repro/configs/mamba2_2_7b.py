"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060].

64 Mamba2 blocks (no MLP: d_ff=0), d_model=2560, ssm_state=128.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,                     # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256),
)
