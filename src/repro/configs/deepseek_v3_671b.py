"""deepseek-v3-671b — MLA + MoE 256e top-8 (1 shared), 3 leading dense
layers, aux-free router bias, MTP [arXiv:2412.19437]."""
from .base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,                # MLA: per-head latent KV
    d_ff=2048,                       # = d_expert
    vocab_size=129280,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_expert=2048,
        num_shared=1,
        first_dense_layers=3,
        dense_d_ff=18432,
        router_aux_free_bias=True,
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    mtp=True,
)
