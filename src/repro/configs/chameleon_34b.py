"""chameleon-34b — early-fusion VLM backbone: VQ image tokens share the
text vocabulary (65536); qk-norm for stability [arXiv:2405.09818].

Modality frontend is a stub per assignment: images arrive as discrete VQ
token ids inside the ordinary token stream (that is Chameleon's design).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,
)
