"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus the
per-cell input_specs (ShapeDtypeStruct stand-ins, never allocating)."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig, SHAPES, reduced
from . import (zamba2_2_7b, command_r_plus_104b, yi_9b, qwen2_5_3b,
               gemma2_9b, mamba2_2_7b, deepseek_v3_671b, arctic_480b,
               chameleon_34b, whisper_small)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        zamba2_2_7b.CONFIG,
        command_r_plus_104b.CONFIG,
        yi_9b.CONFIG,
        qwen2_5_3b.CONFIG,
        gemma2_9b.CONFIG,
        mamba2_2_7b.CONFIG,
        deepseek_v3_671b.CONFIG,
        arctic_480b.CONFIG,
        chameleon_34b.CONFIG,
        whisper_small.CONFIG,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def reduced_arch(name: str, **overrides) -> ModelConfig:
    return reduced(get_arch(name), **overrides)


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic (SSM/hybrid)
    families; no encoder-only archs, so decode runs everywhere else."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


def all_cells(runnable_only: bool = True) -> List[tuple]:
    cells = []
    for a, cfg in ARCHS.items():
        for s, shape in SHAPES.items():
            if not runnable_only or cell_runnable(cfg, shape):
                cells.append((a, s))
    return cells


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                for_init: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train / prefill: {"inputs": (B,S), "labels": (B,S)[, "enc_inputs"]}
    decode:          {"tokens": (B,1)} (+ the cache comes from
                     jax.eval_shape(init_cache, ...) in the launcher)
    """
    del for_init
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"inputs": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "audio":
            specs["enc_inputs"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "audio":
            specs["enc_inputs"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
