"""Config dataclasses for models, training, meshes and workload shapes."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    num_shared: int = 0              # always-on shared experts (DeepSeek)
    dense_residual: bool = False     # dense FFN in parallel (Arctic)
    dense_d_ff: int = 0              # hidden of the dense residual / first-dense layers
    first_dense_layers: int = 0      # leading dense layers (DeepSeek: 3)
    capacity_factor: float = 0.0     # 0 => dropless (sort + ragged_dot)
    router_aux_free_bias: bool = False  # DeepSeek aux-loss-free balancing bias


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block config."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # defaults to d_model // num_heads
    qkv_bias: bool = False           # Qwen2.5
    o_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"            # rmsnorm | layernorm (whisper)
    pos_emb: str = "rope"            # rope | learned (whisper)
    act: str = "silu"                # gated: silu->SwiGLU, gelu->GeGLU; "gelu_mlp" = plain
    rope_theta: float = 10000.0
    # gemma2
    sliding_window: Optional[int] = None
    alt_local_global: bool = False   # alternate sliding/global layers
    final_logit_softcap: Optional[float] = None
    attn_logit_softcap: Optional[float] = None
    post_norms: bool = False         # gemma2 post-block norms
    # chameleon
    qk_norm: bool = False
    # gemma2 scales embeddings by sqrt(d_model)
    scale_embed: bool = False
    # learned-position table size (whisper decoder)
    max_pos: int = 32768
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # zamba2 hybrid: one weight-shared attention block every k SSM blocks
    hybrid_attn_every: int = 0
    # whisper-style encoder-decoder
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frontend emits this many frames
    # deepseek multi-token prediction (one extra depth-1 module)
    mtp: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"              # full | dots | none
    attn_chunk_q: int = 2048         # chunked-attention block sizes (long seq)
    attn_chunk_kv: int = 2048
    # attention implementation: "xla" (chunked online-softmax, portable) |
    # "flash" (Pallas TPU kernel, kernels/flash_attention.py) | "stub"
    # (kernel-interface traffic only — used to measure the roofline of the
    # flash kernel by substitution: scores never in HBM)
    attn_impl: str = "xla"

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and sanity checks."""
        d, hd = self.d_model, self.head_dim_
        n_q, n_kv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params():
            if self.mla is not None:
                m = self.mla
                qk_head = m.qk_nope_dim + m.qk_rope_dim
                p = d * m.q_lora_rank + m.q_lora_rank * n_q * qk_head
                p += d * (m.kv_lora_rank + m.qk_rope_dim)
                p += m.kv_lora_rank * n_q * (m.qk_nope_dim + m.v_head_dim)
                p += n_q * m.v_head_dim * d
                return p
            return d * hd * (n_q + 2 * n_kv) + n_q * hd * d

        def mlp_params(dff, gated=True):
            return d * dff * (3 if gated else 2)

        def ssm_params():
            s = self.ssm
            d_in = s.expand * d
            p = d * (2 * d_in + 2 * s.n_groups * s.state_dim + d_in // s.head_dim)
            p += d_in * d  # out proj
            return p

        total = emb
        gated = self.act != "gelu_mlp"
        if self.family in ("ssm", "hybrid"):
            total += self.num_layers * ssm_params()
            if self.hybrid_attn_every:
                total += attn_params() + mlp_params(self.d_ff, gated)  # shared
        elif self.moe is not None:
            moe_layers = self.num_layers - self.moe.first_dense_layers
            per_expert = mlp_params(self.moe.d_expert, gated)
            total += self.num_layers * attn_params()
            total += moe_layers * (
                (self.moe.num_experts + self.moe.num_shared) * per_expert
                + d * self.moe.num_experts  # router
                + (mlp_params(self.moe.dense_d_ff, gated) if self.moe.dense_residual else 0)
            )
            total += self.moe.first_dense_layers * mlp_params(
                self.moe.dense_d_ff or self.d_ff, gated)
        else:
            layers = self.num_layers + (self.encoder_layers if self.encoder_decoder else 0)
            total += layers * (attn_params() + mlp_params(self.d_ff, gated))
            if self.encoder_decoder:  # cross-attention in decoder
                total += self.num_layers * attn_params()
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        gated = self.act != "gelu_mlp"
        per_expert = d * m.d_expert * (3 if gated else 2)
        inactive = (self.num_layers - m.first_dense_layers) * (
            (m.num_experts - m.top_k) * per_expert)
        return int(self.param_count() - inactive)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # adamw | shampoo
    shampoo_update_interval: int = 1  # gram-stat update cadence
    shampoo_precond_interval: int = 20
    shampoo_block_size: int = 1024
    ata_levels: int = 1               # Strassen levels inside Shampoo grams
    microbatch: int = 0               # 0 => no grad accumulation
    seed: int = 0
    grad_compress: bool = False       # int8 error-feedback all-reduce
    checkpoint_every: int = 50
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (small dims, same code
    paths). Full configs are exercised only via the dry-run."""
    small = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        attn_chunk_q=64,
        attn_chunk_kv=64,
    )
    if cfg.sliding_window:
        small["sliding_window"] = 64
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_expert=128,
            dense_d_ff=256 if (cfg.moe.dense_residual or cfg.moe.first_dense_layers) else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                 qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        small["head_dim"] = None
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32,
                                           chunk=32)
    if cfg.hybrid_attn_every:
        small["hybrid_attn_every"] = 2
    if cfg.encoder_decoder:
        small["encoder_layers"] = 2
        small["encoder_seq"] = 16
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
