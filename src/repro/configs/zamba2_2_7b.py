"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, one weight-shared full-attention block
(32H MHA, SwiGLU d_ff=10240) invoked every 6 SSM layers (9 invocations),
vocab 32000, ssm_state=64.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    hybrid_attn_every=6,
)
