"""gemma2-9b — alternating local/global attention, logit softcaps, GeGLU,
post-block norms, sqrt(d)-scaled embeddings [arXiv:2408.00118]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    act="gelu",                      # GeGLU
    sliding_window=4096,
    alt_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    scale_embed=True,
    tie_embeddings=True,
)
