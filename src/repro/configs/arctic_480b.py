"""arctic-480b — 128-expert top-2 MoE + dense residual branch
[hf:Snowflake/snowflake-arctic-base]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                       # = d_expert
    vocab_size=32000,
    head_dim=128,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_expert=4864,
        dense_residual=True,
        dense_d_ff=4864,
    ),
)
