"""Fault-injection registry for robustness drills (chaos testing).

The serving path's failure model (DESIGN.md §13) is exercised by
*injecting* the failures the ROADMAP's "millions of users" target implies:
poisoned operand/output tiles (NaN/Inf from a bad DMA or a low-precision
overflow), finite silent corruption (a wrong tile that only a Freivalds
probe can see), executables that raise or stall (a wedged device), a
corrupted autotune cache file, and a mesh that shrinks mid-run (a dead
replica group).

Two drivers, one registry:

* **Context manager** (tests)::

      from repro.runtime import faults
      with faults.inject(faults.FaultSpec("exec_fail", rate=1.0,
                                          site="gram.engine.exec*")):
          eng.step()          # every executable launch raises InjectedFault

* **Environment** (chaos CI / benchmarks)::

      REPRO_FAULTS="poison_output:rate=0.1,value=nan;exec_fail:rate=0.05"

  Profiles are ``;``-separated ``kind:key=val,key=val`` specs, parsed on
  first use and re-parsed whenever the variable's value changes.

Sites are dotted names matched with ``fnmatch`` globs (default ``*``), so
one profile can target a single bucket executable or the whole engine.
Every firing is appended to ``registry.events`` — tests assert on what
actually fired, not on probabilities.  Randomness is a seeded
``numpy`` generator: a chaos trace is reproducible.

The registry is *pull-based*: production code calls the narrow hooks
(``fire`` / ``poison`` / ``corrupt_file``) which are no-ops unless a
matching spec is armed — the fault-free hot path costs one attribute
check per hook.
"""
from __future__ import annotations

import itertools
import math
import os
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from fnmatch import fnmatch
from typing import List, Optional, Tuple

import numpy as np

from ..obs import trace as _trace

__all__ = [
    "FaultSpec", "FaultEvent", "FaultRegistry", "InjectedFault",
    "ENV_VAR", "KINDS", "active", "install", "inject", "reset",
    "fire", "poison", "check_exec", "corrupt_file", "parse_profile",
]

ENV_VAR = "REPRO_FAULTS"

KINDS = (
    "poison_operand",   # overwrite a tile of an operand array
    "poison_output",    # overwrite a tile of a result array
    "exec_fail",        # raise InjectedFault at an executable launch
    "exec_delay",       # stall an executable launch by ``delay`` seconds
    "cache_corrupt",    # truncate a cache file in place (half its bytes)
    "mesh_shrink",      # signal the serving layer to drop a replica group
)


class InjectedFault(RuntimeError):
    """Raised by an armed ``exec_fail`` spec (a crashed executable)."""


@dataclass
class FaultSpec:
    """One armed fault: what to break, how often, and how hard.

    kind:  one of ``KINDS``.
    rate:  firing probability per opportunity (1.0 = always).
    times: total firing budget (None = unlimited).
    site:  fnmatch glob over the hook's dotted site name.
    value: poison payload — ``nan``/``inf`` for guard-visible corruption,
           any finite float for *silent* corruption only a Freivalds
           probe catches.
    delay: seconds for ``exec_delay``.
    """
    kind: str
    rate: float = 1.0
    times: Optional[int] = None
    site: str = "*"
    value: float = math.nan
    delay: float = 0.0
    fired: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


@dataclass
class FaultEvent:
    kind: str
    site: str
    detail: str = ""
    t: float = 0.0        # time.perf_counter() at the firing — the same
    #                       clock the tracer uses, so fault events align
    #                       with request spans on one timeline
    seq: int = 0          # per-registry firing sequence (1-based): total
    #                       order even when perf_counter ties


@dataclass
class FaultRegistry:
    specs: List[FaultSpec] = field(default_factory=list)
    seed: int = 0
    events: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._seq = itertools.count(1)

    # -- core matching ----------------------------------------------------
    def match(self, kind: str, site: str) -> Optional[FaultSpec]:
        """The first armed spec firing for (kind, site) this opportunity,
        with its budget decremented and the event logged (timestamped +
        sequence-numbered, and mirrored onto the tracer timeline as an
        instant); else None."""
        for spec in self.specs:
            if spec.kind != kind or not fnmatch(site, spec.site):
                continue
            if spec.times is not None and spec.fired >= spec.times:
                continue
            if spec.rate < 1.0 and self._rng.random() >= spec.rate:
                continue
            spec.fired += 1
            ev = FaultEvent(kind=kind, site=site,
                            t=time.perf_counter(), seq=next(self._seq))
            self.events.append(ev)
            _trace.instant(f"fault:{kind}", site=site, seq=ev.seq)
            return spec
        return None

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    # -- hooks ------------------------------------------------------------
    def fire(self, kind: str, site: str) -> bool:
        """Generic boolean hook (used for ``mesh_shrink``); for
        ``exec_fail``/``exec_delay`` prefer the dedicated hooks below."""
        return self.match(kind, site) is not None

    def check_exec(self, site: str) -> None:
        """Executable-launch hook: stall on an armed ``exec_delay``, raise
        ``InjectedFault`` on an armed ``exec_fail``."""
        spec = self.match("exec_delay", site)
        if spec is not None and spec.delay > 0:
            time.sleep(spec.delay)
        if self.match("exec_fail", site) is not None:
            raise InjectedFault(f"injected executable failure at {site}")

    def poison(self, kind: str, site: str,
               arr: np.ndarray) -> Tuple[np.ndarray, bool]:
        """(possibly-poisoned copy, fired?) for an operand/output array.

        Overwrites one random tile (up to 8x8 on the trailing two axes)
        with ``spec.value`` — NaN/Inf for guard-visible faults, a finite
        value for silent corruption.  The input is never mutated in
        place: retries must start from clean data.
        """
        spec = self.match(kind, site)
        if spec is None or arr.ndim < 2 or arr.size == 0:
            return arr, False
        out = np.array(arr, copy=True)
        h, w = out.shape[-2], out.shape[-1]
        th, tw = min(8, h), min(8, w)
        i = int(self._rng.integers(0, h - th + 1))
        j = int(self._rng.integers(0, w - tw + 1))
        flat = out.reshape(-1, h, w)
        b = int(self._rng.integers(0, flat.shape[0]))
        flat[b, i:i + th, j:j + tw] = spec.value
        self.events[-1].detail = f"tile[{b},{i}:{i+th},{j}:{j+tw}]" \
                                 f"={spec.value}"
        return out, True

    def corrupt_file(self, site: str, path) -> bool:
        """Truncate ``path`` to half its bytes on an armed
        ``cache_corrupt`` (models a crash mid-write / bit-rotted cache).
        Returns whether it fired."""
        spec = self.match("cache_corrupt", site)
        if spec is None:
            return False
        try:
            with open(path, "rb") as f:
                raw = f.read()
            with open(path, "wb") as f:
                f.write(raw[:max(1, len(raw) // 2)])
            self.events[-1].detail = str(path)
        except OSError:
            pass
        return True


_NULL = FaultRegistry()          # armed with nothing: every hook a no-op
_installed: Optional[FaultRegistry] = None
_env_cache: Tuple[Optional[str], Optional[FaultRegistry]] = (None, None)


def parse_profile(profile: str, *, seed: int = 0) -> FaultRegistry:
    """Registry from a ``REPRO_FAULTS`` profile string (see module doc).

    ``"poison_output:rate=0.1,value=inf;exec_fail:rate=0.05,times=3"``
    """
    specs = []
    for part in profile.split(";"):
        part = part.strip()
        if not part:
            continue
        kind, _, kvs = part.partition(":")
        kw = {}
        for kv in filter(None, (s.strip() for s in kvs.split(","))):
            k, _, v = kv.partition("=")
            if k in ("rate", "delay", "value"):
                kw[k] = float(v)
            elif k == "times":
                kw[k] = int(v)
            elif k == "site":
                kw[k] = v
            elif k == "seed":
                seed = int(v)
            else:
                raise ValueError(f"unknown fault spec key {k!r} in {part!r}")
        specs.append(FaultSpec(kind.strip(), **kw))
    return FaultRegistry(specs=specs, seed=seed)


def active() -> FaultRegistry:
    """The live registry: an installed one (context manager), else one
    parsed from ``$REPRO_FAULTS`` (cached until the value changes), else
    a null registry with nothing armed."""
    global _env_cache
    if _installed is not None:
        return _installed
    profile = os.environ.get(ENV_VAR)
    if not profile:
        return _NULL
    if _env_cache[0] != profile:
        _env_cache = (profile, parse_profile(profile))
    return _env_cache[1]


def install(registry: Optional[FaultRegistry]) -> None:
    """Install (or, with None, remove) the process-wide registry —
    overrides the environment profile."""
    global _installed
    _installed = registry


def reset() -> None:
    """Drop the installed registry and the env-profile cache."""
    global _installed, _env_cache
    _installed = None
    _env_cache = (None, None)


@contextmanager
def inject(*specs: FaultSpec, seed: int = 0):
    """Arm ``specs`` for the duration of the block; yields the registry
    (inspect ``.events`` afterwards).  Nestable: restores the previous
    registry on exit."""
    prev = _installed
    reg = FaultRegistry(specs=list(specs), seed=seed)
    install(reg)
    try:
        yield reg
    finally:
        install(prev)


# -- module-level convenience hooks (call sites stay one-liners) ----------

def fire(kind: str, site: str) -> bool:
    return active().fire(kind, site)


def poison(kind: str, site: str, arr: np.ndarray) -> np.ndarray:
    return active().poison(kind, site, arr)[0]


def check_exec(site: str) -> None:
    active().check_exec(site)


def corrupt_file(site: str, path) -> bool:
    return active().corrupt_file(site, path)
