"""Batched KV-cache serving engine (slot-based continuous batching).

Fixed ``slots`` request slots, each owning a B=1 cache stacked on a leading
slot axis. Prefill runs per request at bucketed prompt lengths (bounded
recompiles); decode runs one vmapped step over all slots per tick —
requests at different positions decode together (per-slot index lives
inside its vmapped cache). Greedy or temperature sampling.

Requests carry the same SLO vocabulary as ``gram.engine`` —
``deadline_s`` / ``tenant`` / ``priority``: admission pops the waiting
list in (priority, deadline, FIFO) order and a request past its deadline
while still waiting is failed fast (``status="deadline"``) instead of
occupying a slot; the default path (no deadlines, no priorities) keeps
the exact old FIFO behavior.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import init_cache, prefill, decode_step


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    done: bool = False
    status: str = "pending"           # -> "ok" | "deadline"
    tenant: str = "default"
    priority: int = 0
    deadline_s: Optional[float] = None
    t_submit: float = 0.0
    t_deadline: Optional[float] = None


def _bucket(n: int) -> int:
    return 1 << max(4, math.ceil(math.log2(max(n, 1))))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.slots, self.max_seq = slots, max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._uid = itertools.count()
        # slot caches stacked on a leading axis: (slots, ...) of B=1 caches
        one = init_cache(cfg, 1, max_seq)
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (slots, *x.shape)).copy(), one)
        self.active: Dict[int, Optional[Request]] = {i: None
                                                     for i in range(slots)}
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self._done_now: List[Request] = []
        self._prefill_cache: Dict[int, object] = {}
        self._decode = jax.jit(
            jax.vmap(lambda t, c: decode_step(cfg, self.params, t, c),
                     in_axes=(0, 0)))

    # -- request intake ----------------------------------------------------
    def add_request(self, prompt: List[int], *, max_new_tokens: int = 16,
                    eos_id: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    tenant: str = "default", priority: int = 0) -> int:
        now = time.perf_counter()
        r = Request(next(self._uid), list(prompt), max_new_tokens, eos_id,
                    tenant=str(tenant), priority=int(priority),
                    deadline_s=deadline_s, t_submit=now,
                    t_deadline=None if deadline_s is None
                    else now + deadline_s)
        self.waiting.append(r)
        return r.uid

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            cfg = self.cfg
            from ..models import forward

            def pf(p, t, c):
                logits, c = forward(cfg, p, t, cache=c, mode="prefill")
                return logits, c                    # ALL positions' logits
            self._prefill_cache[plen] = jax.jit(pf)
        return self._prefill_cache[plen]

    def _sample(self, logits) -> np.ndarray:
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            return np.asarray(jax.random.categorical(
                sub, logits / self.temperature, axis=-1))
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _expire_waiting(self):
        """Fail waiting requests that are already past their deadline —
        they must not consume a prefill or a slot."""
        now = time.perf_counter()
        keep = []
        for r in self.waiting:
            if r.t_deadline is not None and now > r.t_deadline:
                r.done = True
                r.status = "deadline"
                self.finished.append(r)
                self._done_now.append(r)
            else:
                keep.append(r)
        self.waiting = keep

    def _admit(self):
        self._expire_waiting()
        # priority first, earliest deadline next, FIFO last — a stable
        # sort of (priority, deadline) leaves deadline-less same-priority
        # traffic in exactly the old FIFO order
        if any(r.priority or r.t_deadline is not None
               for r in self.waiting):
            self.waiting.sort(key=lambda r: (
                -r.priority,
                r.t_deadline if r.t_deadline is not None else math.inf,
                r.uid))
        for slot, occ in self.active.items():
            if occ is not None or not self.waiting:
                continue
            r = self.waiting.pop(0)
            plen = _bucket(len(r.prompt))
            toks = np.full((1, plen), 0, np.int32)
            toks[0, :len(r.prompt)] = r.prompt
            cache1 = jax.tree.map(lambda x: x[slot], self.cache)
            cache1 = jax.tree.map(jnp.zeros_like, cache1)
            logits, cache1 = self._prefill_fn(plen)(self.params,
                                                    jnp.asarray(toks), cache1)
            # bucket-padded on the RIGHT: the true last position is
            # len(prompt)-1; rewind index to the true length so decode
            # writes the next token at position len(prompt).
            cache1["index"] = jnp.asarray(len(r.prompt), jnp.int32)
            self.cache = jax.tree.map(
                lambda full, one: full.at[slot].set(one), self.cache, cache1)
            # first generated token comes from the prefill logits
            first = int(self._sample(logits[0, len(r.prompt) - 1][None])[0])
            r.generated = [first]
            self.active[slot] = r

    # -- decode tick ---------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine tick: admit waiting requests, decode all active slots,
        collect finished requests. Returns newly finished."""
        self._admit()
        self._collect()          # requests satisfied by prefill alone
        live = [s for s, r in self.active.items() if r is not None]
        if not live:
            return self._drain_done()
        # feed the latest generated token per slot at its cache position
        toks = np.zeros((self.slots, 1, 1), np.int32)
        for s, r in self.active.items():
            if r is not None:
                toks[s, 0, 0] = r.generated[-1]
        logits, new_cache = self._decode(jnp.asarray(toks), self.cache)
        nxt = self._sample(logits[:, 0])
        # only live slots advance their cache
        live_mask = np.zeros((self.slots,), bool)
        live_mask[live] = True
        mask = jnp.asarray(live_mask)

        def select(new, old):
            m = mask.reshape((self.slots,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)
        self.cache = jax.tree.map(select, new_cache, self.cache)

        for s in live:
            self.active[s].generated.append(int(nxt[s]))
        self._collect()
        return self._drain_done()

    def _collect(self):
        for s, r in self.active.items():
            if r is None:
                continue
            if (len(r.generated) >= r.max_new_tokens
                    or (r.eos_id is not None and r.generated
                        and r.generated[-1] == r.eos_id)):
                r.done = True
                r.status = "ok"
                self.finished.append(r)
                self._done_now.append(r)
                self.active[s] = None

    def _drain_done(self) -> List[Request]:
        out, self._done_now = self._done_now, []
        return out

    def run_to_completion(self, max_ticks: int = 1000) -> List[Request]:
        for _ in range(max_ticks):
            self.step()
            if not self.waiting and all(v is None
                                        for v in self.active.values()):
                break
        return self.finished
