"""Runtime: training loop, serving engines, fault injection.

Exports resolve lazily (PEP 562): ``trainer`` pulls in the full model /
optimizer / checkpoint stack, and eagerly importing it here would (a) tax
light consumers like the Gram service's fault hooks and (b) create an
import cycle ``runtime -> trainer -> optim.shampoo -> gram ->
runtime.faults``.  ``from repro.runtime import Trainer`` etc. work
unchanged.
"""
_EXPORTS = {
    "Trainer": "trainer", "TrainState": "trainer",
    "make_train_step": "trainer", "make_optimizer": "trainer",
    "StragglerWatchdog": "trainer", "FailureInjector": "trainer",
    "SimulatedFailure": "trainer",
    "ServingEngine": "serving", "Request": "serving",
}

__all__ = [*_EXPORTS, "faults"]


def __getattr__(name):
    import importlib
    if name in _EXPORTS:
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        return getattr(mod, name)
    if name == "faults":
        return importlib.import_module(".faults", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
