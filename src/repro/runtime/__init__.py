from .trainer import (  # noqa: F401
    Trainer, TrainState, make_train_step, make_optimizer,
    StragglerWatchdog, FailureInjector, SimulatedFailure,
)
from .serving import ServingEngine, Request  # noqa: F401
