"""Training runtime: jitted train step (grad accumulation, sharded),
fault-tolerant loop (checkpoint/restart, failure injection), straggler
watchdog.

The train step is a pure function of (state, batch); the Trainer owns the
impure parts — data stream position, checkpoint cadence, wall-clock
watchdog — all of which are reconstructed exactly on restart (the stream is
a pure function of the step, checkpoints carry the step).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..data.pipeline import DataConfig, get_batch
from ..checkpoint.manager import CheckpointManager
from ..models import init_params, loss_fn
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..optim import adamw, shampoo, apply_updates, warmup_cosine

log = logging.getLogger("repro.trainer")

TrainState = Dict[str, Any]          # {"step", "params", "opt_state"}


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/fault-drills)."""


@dataclass
class FailureInjector:
    at_step: int = -1

    def check(self, step: int):
        if step == self.at_step:
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor. At scale this signal triggers hot-spare
    swap / grouped restart; in-container we surface the detection."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3
    ewma: float = 0.0
    count: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            self.ewma = dt if self.ewma == 0 else 0.5 * (self.ewma + dt)
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((self.count, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        self.count, dt, self.ewma)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def make_optimizer(tc: TrainConfig):
    sched = warmup_cosine(tc.learning_rate, tc.warmup_steps, tc.total_steps)
    if tc.optimizer == "shampoo":
        return shampoo(sched, block_size=tc.shampoo_block_size,
                       stat_interval=tc.shampoo_update_interval,
                       precond_interval=tc.shampoo_precond_interval,
                       ata_levels=tc.ata_levels,
                       weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
    return adamw(sched, weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)


def make_train_step(cfg: ModelConfig, optimizer, *,
                    microbatch: int = 0) -> Callable:
    """(state, batch) -> (state, metrics). Pure; jit at the call site with
    shardings (or plain jit on one device)."""

    def compute_grads(params, batch):
        def lf(p, b):
            return loss_fn(cfg, p, b)
        if not microbatch:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
            return loss, metrics, grads

        # gradient accumulation: batch (B, ...) -> (k, B/k, ...), scan
        def resh(x):
            return x.reshape(microbatch, x.shape[0] // microbatch,
                             *x.shape[1:])
        mbatch = jax.tree.map(resh, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def body(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                lf, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), metrics

        (g_acc, l_sum), ms = jax.lax.scan(body, (zeros, jnp.float32(0)),
                                          mbatch)
        grads = jax.tree.map(lambda g: g / microbatch, g_acc)
        metrics = jax.tree.map(lambda m: m[-1], ms)
        return l_sum / microbatch, metrics, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        updates, opt_state, om = optimizer.update(
            grads, state["opt_state"], state["params"], state["step"])
        params = apply_updates(state["params"], updates)
        new_state = {"step": state["step"] + 1, "params": params,
                     "opt_state": opt_state}
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss_mean"] = loss
        return new_state, metrics

    return train_step


class Trainer:
    """Fault-tolerant training loop over the synthetic stream."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, dc: DataConfig,
                 workdir: str, *,
                 failure: Optional[FailureInjector] = None,
                 donate: bool = True):
        self.cfg, self.tc, self.dc = cfg, tc, dc
        self.opt = make_optimizer(tc)
        self.ckpt = CheckpointManager(workdir, keep=tc.keep_checkpoints)
        self.failure = failure or FailureInjector()
        self.watchdog = StragglerWatchdog()
        step_fn = make_train_step(cfg, self.opt, microbatch=tc.microbatch)
        self.step_fn = jax.jit(step_fn,
                               donate_argnums=(0,) if donate else ())
        self.state = self._init_or_restore()
        self.metrics_history: list = []

    def _init_or_restore(self) -> TrainState:
        state, meta = self.ckpt.restore()
        if state is not None:
            log.info("restored checkpoint at step %d", meta["step"])
            state["step"] = jnp.asarray(state["step"])
            return state
        params = jax.jit(lambda k: init_params(self.cfg, k))(
            jax.random.PRNGKey(self.tc.seed))
        return {"step": jnp.zeros((), jnp.int32), "params": params,
                "opt_state": self.opt.init(params)}

    @property
    def step(self) -> int:
        return int(self.state["step"])

    def run(self, num_steps: int):
        """Run until ``self.step == num_steps`` (absolute), checkpointing
        every tc.checkpoint_every; resumable after any crash."""
        step_s = obs_metrics.histogram(
            "trainer_step_s", "wall seconds per optimizer step")
        steps_total = obs_metrics.counter(
            "trainer_steps_total", "optimizer steps completed")
        loss_g = obs_metrics.gauge("trainer_loss", "last step's loss")
        while self.step < num_steps:
            step = self.step
            batch = get_batch(self.dc, step)   # pure fn of step: resumable
            t0 = time.perf_counter()
            with obs_trace.span("train_step", step=step):
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.watchdog.observe(dt)
            step_s.observe(dt)
            steps_total.inc()
            loss_g.set(float(metrics["loss"]))
            self.metrics_history.append(
                {k: float(v) for k, v in metrics.items()})
            new_step = step + 1
            if new_step % self.tc.checkpoint_every == 0 \
                    or new_step == num_steps:
                with obs_trace.span("checkpoint_save", step=new_step):
                    self.ckpt.save(new_step, self.state)
            # failure injection AFTER the optimizer step, BEFORE the next
            # checkpoint boundary — the worst-case crash point.
            self.failure.check(new_step)
        self.ckpt.wait()
        return self.metrics_history
