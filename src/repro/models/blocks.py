"""Transformer / MoE / SSM blocks: norms + residuals around the layer lib.

Every block fn has the shape-stable signature
    block(params, x, cfg, *, layer_idx, cache=None, pos_info, ...)
      -> (x, new_cache)
so stacks can run under ``lax.scan`` (params stacked on a leading L axis,
cache stacked likewise). ``cache`` is a dict or None; ``pos_info`` carries
(positions, q_pos, kv_pos, kv_len) so train/prefill/decode share one code
path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L


class PosInfo(NamedTuple):
    positions: jax.Array          # (B, S) or (S,) absolute positions of x
    q_pos: jax.Array              # (S,) query positions for masking
    kv_pos: jax.Array             # (Skv,) kv positions
    kv_len: Optional[jax.Array]   # scalar: valid kv slots (decode) or None


def _window_for_layer(cfg: ModelConfig, layer_idx):
    """Gemma-2 alternating local/global: even layers slide, odd are global.
    ``layer_idx`` may be traced (scan) — the window becomes a traced scalar.
    """
    if cfg.sliding_window is None:
        return None
    if not cfg.alt_local_global:
        return cfg.sliding_window
    big = jnp.int32(2**30)
    return jnp.where(layer_idx % 2 == 0, jnp.int32(cfg.sliding_window), big)


# ---------------------------------------------------------------------------
# Attention (+MLP) block — dense families, gemma2, chameleon, qwen, whisper
# ---------------------------------------------------------------------------

def init_attn_block(cfg: ModelConfig, key, *, cross: bool = False,
                    d_ff: Optional[int] = None):
    ks = jax.random.split(key, 6)
    p = {
        "ln_attn": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, ks[0]),
        "ln_mlp": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, ks[1], d_ff=d_ff),
    }
    if cfg.post_norms:
        p["post_attn"] = L.init_norm(cfg, cfg.d_model)
        p["post_mlp"] = L.init_norm(cfg, cfg.d_model)
    if cross:
        p["ln_cross"] = L.init_norm(cfg, cfg.d_model)
        p["cross"] = L.init_attention(cfg, ks[2], cross=True)
    return p


def attn_block(p, x, cfg: ModelConfig, *, layer_idx, pos: PosInfo,
               cache=None, enc_out=None, causal=True):
    """Pre-norm attention + MLP block (optional gemma2 post-norms, optional
    whisper cross-attention). cache: {"k","v"[,"ck","cv"]} or None."""
    window = _window_for_layer(cfg, layer_idx)

    h = L.apply_norm(p["ln_attn"], x, cfg)
    q, k, v = L.attention_qkv(p["attn"], h, cfg, positions=pos.positions)
    new_cache = None
    if cache is not None:
        if k.shape[1] == cache["k"].shape[1]:      # prefill fills the cache
            ck, cv = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        else:                                       # decode: write one slot
            idx = pos.q_pos[0]
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
    o = L.attention(q, k, v, q_pos=pos.q_pos, kv_pos=pos.kv_pos,
                    causal=causal, window=window, kv_len=pos.kv_len,
                    attn_softcap=cfg.attn_logit_softcap,
                    chunk_q=cfg.attn_chunk_q if x.shape[1] > cfg.attn_chunk_q
                    else 0,
                    chunk_kv=cfg.attn_chunk_kv, impl=cfg.attn_impl)
    o = L.attention_out(p["attn"], o, cfg)
    if cfg.post_norms:
        o = L.apply_norm(p["post_attn"], o, cfg)
    x = x + o

    if "cross" in p:
        h = L.apply_norm(p["ln_cross"], x, cfg)
        qc = L.attention_qkv(p["cross"], h, cfg)[0]   # q only (no rope: learned pos)
        if cache is not None and "ck" in cache and enc_out is None:
            kc, vc = cache["ck"], cache["cv"]          # decode: cached cross K/V
        else:
            _, kc, vc = L.attention_qkv(p["cross"], h, cfg, kv_src=enc_out)
        if new_cache is not None:
            new_cache["ck"], new_cache["cv"] = kc, vc
        enc_pos = jnp.arange(kc.shape[1])
        oc = L.attention(qc, kc, vc, q_pos=pos.q_pos, kv_pos=enc_pos,
                         causal=False)
        x = x + L.attention_out(p["cross"], oc, cfg)

    h = L.apply_norm(p["ln_mlp"], x, cfg)
    o = L.apply_mlp(p["mlp"], h, cfg)
    if cfg.post_norms:
        o = L.apply_norm(p["post_mlp"], o, cfg)
    x = x + o
    return x, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla_block(cfg: ModelConfig, key, *, moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln_attn": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_mla(cfg, k1),
        "ln_mlp": L.init_norm(cfg, cfg.d_model),
    }
    if moe:
        p["moe"] = L.init_moe(cfg, k2)
    else:
        p["mlp"] = L.init_mlp(cfg, k2, d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
    return p


def mla_block(p, x, cfg: ModelConfig, *, layer_idx, pos: PosInfo, cache=None):
    del layer_idx
    h = L.apply_norm(p["ln_attn"], x, cfg)
    c_kv = k_rope = None
    new_cache = None
    absorbed = False
    if cache is not None:
        if x.shape[1] == cache["ckv"].shape[1]:    # prefill
            c_kv, k_rope = L.mla_compress(p["attn"], h, cfg, pos.positions)
            new_cache = {"ckv": c_kv.astype(cache["ckv"].dtype),
                         "krope": k_rope.astype(cache["krope"].dtype)}
        else:                                       # decode (absorbed)
            absorbed = True
            c_new, kr_new = L.mla_compress(p["attn"], h, cfg, pos.positions)
            idx = pos.q_pos[0]
            ckv = jax.lax.dynamic_update_slice(
                cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, idx, 0))
            krope = jax.lax.dynamic_update_slice(
                cache["krope"], kr_new.astype(cache["krope"].dtype),
                (0, idx, 0))
            new_cache = {"ckv": ckv, "krope": krope}
            c_kv, k_rope = ckv, krope
    o, _ = L.mla_attention(p["attn"], h, cfg, positions=pos.positions,
                           q_pos=pos.q_pos, kv_pos=pos.kv_pos,
                           c_kv=c_kv, k_rope=k_rope, kv_len=pos.kv_len,
                           absorbed=absorbed,
                           chunk_q=cfg.attn_chunk_q if x.shape[1] > cfg.attn_chunk_q else 0,
                           chunk_kv=cfg.attn_chunk_kv, impl=cfg.attn_impl)
    x = x + o

    h = L.apply_norm(p["ln_mlp"], x, cfg)
    if "moe" in p:
        o, aux = L.apply_moe(p["moe"], h, cfg)
    else:
        o, aux = L.apply_mlp(p["mlp"], h, cfg), jnp.float32(0)
    return x + o, new_cache, aux


# ---------------------------------------------------------------------------
# MoE attention block (Arctic: GQA attn + 128e top-2 MoE + dense residual)
# ---------------------------------------------------------------------------

def init_moe_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    p = {
        "ln_attn": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, ks[0]),
        "ln_mlp": L.init_norm(cfg, cfg.d_model),
        "moe": L.init_moe(cfg, ks[1]),
    }
    if cfg.moe.dense_residual:
        p["ln_dense"] = L.init_norm(cfg, cfg.d_model)
        p["dense"] = L.init_mlp(cfg, ks[2], d_ff=cfg.moe.dense_d_ff)
    return p


def moe_block(p, x, cfg: ModelConfig, *, layer_idx, pos: PosInfo, cache=None):
    window = _window_for_layer(cfg, layer_idx)
    h = L.apply_norm(p["ln_attn"], x, cfg)
    q, k, v = L.attention_qkv(p["attn"], h, cfg, positions=pos.positions)
    new_cache = None
    if cache is not None:
        if k.shape[1] == cache["k"].shape[1]:
            ck, cv = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        else:
            idx = pos.q_pos[0]
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
    o = L.attention(q, k, v, q_pos=pos.q_pos, kv_pos=pos.kv_pos, causal=True,
                    window=window, kv_len=pos.kv_len,
                    chunk_q=cfg.attn_chunk_q if x.shape[1] > cfg.attn_chunk_q else 0,
                    chunk_kv=cfg.attn_chunk_kv, impl=cfg.attn_impl)
    x = x + L.attention_out(p["attn"], o, cfg)

    h = L.apply_norm(p["ln_mlp"], x, cfg)
    o, aux = L.apply_moe(p["moe"], h, cfg)
    if "dense" in p:   # Arctic: dense FFN residual in parallel with MoE
        o = o + L.apply_mlp(p["dense"], L.apply_norm(p["ln_dense"], x, cfg), cfg)
    return x + o, new_cache, aux


# ---------------------------------------------------------------------------
# SSM block (Mamba2) — norm + SSD + residual (no MLP when d_ff == 0)
# ---------------------------------------------------------------------------

def init_ssm_block(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = {"ln": L.init_norm(cfg, cfg.d_model), "ssm": L.init_ssm(cfg, k1)}
    if cfg.d_ff:
        p["ln_mlp"] = L.init_norm(cfg, cfg.d_model)
        p["mlp"] = L.init_mlp(cfg, k2)
    return p


def ssm_block(p, x, cfg: ModelConfig, *, layer_idx, cache=None, decode=False):
    del layer_idx
    h = L.apply_norm(p["ln"], x, cfg)
    conv_state = cache["conv"] if cache is not None else None
    ssm_state = cache["ssm"] if cache is not None else None
    o, (new_conv, new_ssm) = L.apply_ssm(p["ssm"], h, cfg,
                                         conv_state=conv_state,
                                         ssm_state=ssm_state, decode=decode)
    x = x + o
    new_cache = ({"conv": new_conv, "ssm": new_ssm}
                 if cache is not None else None)
    if "mlp" in p:
        x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln_mlp"], x, cfg), cfg)
    return x, new_cache
