"""Layer library: norms, rope, attention (GQA/MLA), MLPs, MoE, Mamba2-SSD.

Pure-JAX functional layers over parameter dicts. Conventions:
  * activations (B, S, D); attention heads layout (B, S, H, Dh);
  * params stored in ``cfg.dtype`` (bf16 default), matmuls accumulate fp32
    via ``preferred_element_type`` where it matters; norms/softmax/CE fp32;
  * every ``init_*`` returns a dict of arrays, every ``apply``-style fn is
    pure and jit/scan-safe.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, MoEConfig, MLAConfig, SSMConfig

# A large-but-finite mask value: big enough to zero softmax weight, small
# enough that (-MASK) + finite stays finite in bf16/fp32.
MASK_VALUE = -1e9


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), _dtype(cfg)),
                "bias": jnp.zeros((d,), _dtype(cfg))}
    return {"scale": jnp.ones((d,), _dtype(cfg))}


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps=1e-6):
    """Per-head qk-norm (Chameleon): RMS over the head dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def softcap(x, cap):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_table(positions, dim: int, theta: float):
    """(..., S) int positions -> cos/sin tables (..., S, dim//2), fp32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2). Pairs (even, odd)."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]  # (B,S,1,D/2)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (plain + chunked/online-softmax)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,Sq,Hkv,G,D), k: (B,Skv,Hkv,D) -> (B,Hkv,G,Sq,Skv) fp32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w, v):
    """w: (B,Hkv,G,Sq,Skv) fp32, v: (B,Skv,Hkv,D) -> (B,Sq,Hkv,G,D)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def _mask_bias(q_pos, kv_pos, *, causal, window, kv_len=None):
    """(Sq, Skv) additive fp32 mask from position vectors.

    window is a (possibly traced) scalar: number of positions attended
    (q - kv < window). kv_len masks invalid cache slots (decode).
    """
    valid = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), jnp.bool_)
    diff = q_pos[:, None] - kv_pos[None, :]
    if causal:
        valid &= diff >= 0
    if window is not None:
        valid &= diff < window
    if kv_len is not None:
        valid &= (kv_pos < kv_len)[None, :]
    return jnp.where(valid, 0.0, MASK_VALUE).astype(jnp.float32)


def attention_stub(q, k, v, scale):
    """Kernel-interface stand-in for roofline substitution: touches q, k, v
    once and writes an o-shaped result — exactly the HBM traffic of the
    Pallas flash kernel (kernels/flash_attention.py), whose FLOPs are added
    analytically by the dry-run. NEVER used for real computation."""
    dv = v.shape[-1]
    o = q[..., :dv].astype(jnp.float32) * scale
    o = o + jnp.mean(k.astype(jnp.float32), axis=(1, 2), keepdims=True)[..., :dv]
    o = o + jnp.mean(v.astype(jnp.float32), axis=(1, 2), keepdims=True)
    return o.astype(q.dtype)


def attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
              kv_len=None, attn_softcap=None, scale=None,
              chunk_q: int = 0, chunk_kv: int = 0, impl: str = "xla"):
    """General GQA attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    q_pos: (Sq,) int positions of queries; kv_pos: (Skv,).
    window: optional scalar (static or traced) sliding window size.
    kv_len: optional scalar — number of valid kv slots (decode caches).
    Chunked (online-softmax / FlashAttention-style, rematerialized by XLA)
    when chunk_q > 0 and Sq > chunk_q; otherwise one-shot.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if impl == "stub" and sq > 1:
        return attention_stub(q, k, v, scale)
    if impl == "flash" and sq > 1 and kv_len is None:
        from ..kernels import ops as _kops
        win = 0 if window is None or not isinstance(window, int) else window
        return _kops.flash_mha(q, k, v, causal=causal, window=win,
                               softcap=float(attn_softcap or 0.0))
    qg = q.reshape(b, sq, hkv, g, d)

    if not chunk_q or sq <= chunk_q or skv <= max(chunk_kv, 1):
        bias = _mask_bias(q_pos, kv_pos, causal=causal, window=window,
                          kv_len=kv_len)
        s = _gqa_scores(qg, k) * scale
        if attn_softcap is not None:
            s = softcap(s, attn_softcap)
        s = s + bias[None, None, None]
        w = jax.nn.softmax(s, axis=-1)
        o = _gqa_out(w, v)
        return o.reshape(b, sq, hq, dv).astype(q.dtype)

    # --- chunked path: scan q chunks; inner scan over kv chunks with an
    # online-softmax carry (m, l, acc). Exact, O(chunk^2) live memory.
    cq = chunk_q
    ckv = chunk_kv or chunk_q
    nq = -(-sq // cq) * cq
    nkv = -(-skv // ckv) * ckv
    qp = jnp.pad(qg, ((0, 0), (0, nq - sq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nkv - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nkv - skv), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, nq - sq), constant_values=-1)
    kpos = jnp.pad(kv_pos, (0, nkv - skv), constant_values=2**30)

    qc = qp.reshape(b, nq // cq, cq, hkv, g, d)
    kc = kp.reshape(b, nkv // ckv, ckv, hkv, d)
    vc = vp.reshape(b, nkv // ckv, ckv, hkv, dv)
    qpc = qpos.reshape(nq // cq, cq)
    kpc = kpos.reshape(nkv // ckv, ckv)

    def q_chunk(qi, qpi):
        # online softmax over kv chunks
        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, cq, hkv, g, dv), jnp.float32)

        @jax.checkpoint
        def body(carry, kv):
            # rematerialized: the backward recomputes this chunk's scores
            # instead of saving (cq, ckv) f32 residuals per kv chunk —
            # without this, scan's saved residuals defeat flash attention.
            m, l, acc = carry
            kj, vj, kpj = kv
            bias = _mask_bias(qpi, kpj, causal=causal, window=window,
                              kv_len=kv_len)
            s = _gqa_scores(qi, kj) * scale
            if attn_softcap is not None:
                s = softcap(s, attn_softcap)
            s = s + bias[None, None, None]          # (b,hkv,g,cq,ckv)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o = _gqa_out(p, vj)                      # (b,cq,hkv,g,d)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + o
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpc))
        l = jnp.maximum(l, 1e-30)
        return acc / l.transpose(0, 3, 1, 2)[..., None]

    out = jax.lax.map(lambda args: q_chunk(*args), (qc.swapaxes(0, 1), qpc))
    out = out.swapaxes(0, 1).reshape(b, nq, hq, dv)[:, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (covers dense/gemma2/chameleon/qwen/whisper self+cross)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    sc = 0.02
    dt = _dtype(cfg)
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq * hd)) * sc).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd)) * sc).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[3], (hq * hd, d))
               * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.o_bias:
        p["bo"] = jnp.zeros((d,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    del cross  # same parameter shapes; kv source differs at apply time
    return p


def attention_qkv(p, x, cfg: ModelConfig, *, kv_src=None, positions=None,
                  kv_positions=None):
    """Project to q, k, v (+bias, qk-norm, rope). Returns (q, k, v)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kv_src = x if kv_src is None else kv_src
    skv = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (kv_src @ p["wk"]).reshape(b, skv, cfg.num_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(b, skv, cfg.num_kv_heads, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(1, 1, cfg.num_heads, hd)
        k = k + p["bk"].reshape(1, 1, cfg.num_kv_heads, hd)
        v = v + p["bv"].reshape(1, 1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope" and positions is not None:
        cos_q, sin_q = rope_table(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        kv_positions = positions if kv_positions is None else kv_positions
        cos_k, sin_k = rope_table(kv_positions, hd, cfg.rope_theta)
        k = apply_rope(k, cos_k, sin_k)
    return q, k, v


def attention_out(p, o, cfg: ModelConfig):
    b, s = o.shape[:2]
    y = o.reshape(b, s, cfg.num_heads * cfg.head_dim_) @ p["wo"]
    if cfg.o_bias:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key):
    m: MLAConfig = cfg.mla
    d, hq = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    sc = 0.02
    dt = _dtype(cfg)
    return {
        "w_dq": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * sc).astype(dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "w_uq": (jax.random.normal(ks[1], (m.q_lora_rank, hq * qk_head)) * sc).astype(dt),
        "w_dkv": (jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)) * sc).astype(dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "w_uk": (jax.random.normal(ks[3], (m.kv_lora_rank, hq * m.qk_nope_dim)) * sc).astype(dt),
        "w_uv": (jax.random.normal(ks[4], (m.kv_lora_rank, hq * m.v_head_dim)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[5], (hq * m.v_head_dim, d))
               * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def mla_compress(p, x, cfg: ModelConfig, positions):
    """x -> (c_kv normed, k_rope roped): the MLA cache content."""
    m: MLAConfig = cfg.mla
    ckv_kr = x @ p["w_dkv"]
    c_kv = _norm_vec(ckv_kr[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_kr[..., m.kv_lora_rank:]               # (B, S, rope_dim)
    cos, sin = rope_table(positions, m.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_queries(p, x, cfg: ModelConfig, positions):
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    qk_head = m.qk_nope_dim + m.qk_rope_dim
    cq = _norm_vec(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, cfg.num_heads, qk_head)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    cos, sin = rope_table(positions, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _norm_vec(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def mla_attention(p, x, cfg: ModelConfig, *, positions, q_pos, kv_pos,
                  c_kv=None, k_rope=None, kv_len=None, absorbed=False,
                  chunk_q=0, chunk_kv=0, impl: str = "xla"):
    """Full MLA attention. If (c_kv, k_rope) given they are the (cached)
    compressed KV; else computed from x. ``absorbed=True`` (decode) runs
    attention in the compressed space — never expanding K/V per position.
    """
    m: MLAConfig = cfg.mla
    b, s, _ = x.shape
    hq = cfg.num_heads
    if c_kv is None:
        c_kv, k_rope = mla_compress(p, x, cfg, positions)
    skv = c_kv.shape[1]
    q_nope, q_rope = mla_queries(p, x, cfg, positions)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    if absorbed:
        # Absorb W_uk into q: scores = (q W_uk^T) c_kv + q_rope k_rope.
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, hq, m.qk_nope_dim)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        s_lat = jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv,
                           preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshd,bkd->bhsk", q_rope, k_rope,
                            preferred_element_type=jnp.float32)
        scores = (s_lat + s_rope) * scale
        bias = _mask_bias(q_pos, kv_pos, causal=True, window=None,
                          kv_len=kv_len)
        w = jax.nn.softmax(scores + bias[None, None], axis=-1)
        # (emit bhsr then transpose: the bshr output order is an
        #  unsupported transposed-GEMM on the XLA:CPU thunk runtime)
        o_lat = jnp.einsum("bhsk,bkr->bhsr", w.astype(x.dtype), c_kv,
                           preferred_element_type=jnp.float32)
        o_lat = o_lat.swapaxes(1, 2).astype(x.dtype)
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, hq, m.v_head_dim)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        k_nope = (c_kv @ p["w_uk"]).reshape(b, skv, hq, m.qk_nope_dim)
        v = (c_kv @ p["w_uv"]).reshape(b, skv, hq, m.v_head_dim)
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (b, skv, hq, m.qk_rope_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        o = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True,
                      kv_len=kv_len, scale=scale,
                      chunk_q=chunk_q, chunk_kv=chunk_kv, impl=impl)
    y = o.reshape(b, s, hq * m.v_head_dim) @ p["wo"]
    return y, (c_kv, k_rope)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    sc = 0.02
    if cfg.act == "gelu_mlp":                      # plain 2-matrix MLP
        k1, k2 = jax.random.split(key)
        return {
            "w_in": (jax.random.normal(k1, (d, f)) * sc).astype(dt),
            "b_in": jnp.zeros((f,), dt),
            "w_out": (jax.random.normal(k2, (f, d))
                      * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
            "b_out": jnp.zeros((d,), dt),
        }
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * sc).astype(dt),
        "w_up": (jax.random.normal(k2, (d, f)) * sc).astype(dt),
        "w_down": (jax.random.normal(k3, (f, d))
                   * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def _act(cfg: ModelConfig, x):
    if cfg.act in ("gelu", "gelu_mlp"):
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def apply_mlp(p, x, cfg: ModelConfig):
    if "w_in" in p:                                 # plain MLP
        h = _act(cfg, x @ p["w_in"] + p["b_in"])
        return h @ p["w_out"] + p["b_out"]
    h = _act(cfg, x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (top-k routing, sort + capacity scatter, EP-shardable expert einsums)
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key):
    mo: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, mo.d_expert, mo.num_experts
    dt = _dtype(cfg)
    sc = 0.02
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * sc).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * sc).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * sc).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d))
                   * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }
    if mo.router_aux_free_bias:
        p["router_bias"] = jnp.zeros((e,), jnp.float32)
    if mo.num_shared:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=mo.d_expert * mo.num_shared)
    return p


def moe_capacity(tokens: int, moe: MoEConfig) -> int:
    cf = moe.capacity_factor or 1.25
    cap = int(math.ceil(tokens * moe.top_k / moe.num_experts * cf))
    return max(min(cap, tokens), 1)


def _moe_dispatch_compute(p, xt, cfg: ModelConfig, *, e_offset=0,
                          e_count=None, psum_axis=None):
    """Sort-based capacity dispatch over LOCAL tokens xt (T, d), computing
    the expert range [e_offset, e_offset + e_count) (EP shard), psumming
    the combined output over ``psum_axis`` when expert-sharded.

    Routing (router logits/top-k) is computed over the FULL expert set on
    every rank (router weights replicated — they are tiny); only the expert
    FFN is sharded.
    """
    mo: MoEConfig = cfg.moe
    t, d = xt.shape
    e, k = mo.num_experts, mo.top_k
    e_count = e_count or e

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    sel = probs + p["router_bias"] if mo.router_aux_free_bias else probs
    _, top_idx = jax.lax.top_k(sel, k)                        # (T, k)
    gates = jnp.take_along_axis(probs, top_idx, axis=-1)      # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- sort token-expert assignments by (global) expert id
    flat_e = top_idx.reshape(t * k)
    sort_idx = jnp.argsort(flat_e)                            # (T*k,)
    e_sorted = flat_e[sort_idx]
    tok_sorted = sort_idx // k
    counts = jnp.bincount(flat_e, length=e)                   # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - offsets[e_sorted]
    cap = moe_capacity(t, mo)
    e_local = e_sorted - e_offset
    valid = (pos_in_e < cap) & (e_local >= 0) & (e_local < e_count)
    slot = jnp.where(valid, e_local * cap + pos_in_e, e_count * cap)

    buf = jnp.zeros((e_count * cap + 1, d), xt.dtype) \
        .at[slot].set(jnp.where(valid[:, None], xt[tok_sorted], 0))
    buf = buf[:e_count * cap].reshape(e_count, cap, d)

    # --- expert FFN (E_local batched einsum on this rank)
    h = _act(cfg, jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    y_flat = jnp.concatenate([y.reshape(e_count * cap, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    y_sorted = y_flat[slot]                       # dropped/remote -> 0
    inv = jnp.argsort(sort_idx)
    y_k = y_sorted[inv].reshape(t, k, d)
    out = jnp.sum(y_k * gates[..., None].astype(y_k.dtype), axis=1)
    if psum_axis is not None:
        # combine expert-shard partial outputs (each token's k experts may
        # live on different ranks; in stationary mode also the FFN-dim
        # partial sums) — ONE psum per MoE layer, the EP analogue of the
        # paper's per-level reduction.
        out = jax.lax.psum(out, psum_axis)
    aux = moe_load_aux(probs, top_idx, e)
    return out, aux


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D). Expert-parallel MoE:

    With a mesh policy installed (production), dispatch runs inside
    ``shard_map``: tokens stay local to their DP shard, expert weights are
    sharded over the TP axis (EP), every rank computes its expert subset
    for its row's tokens and one psum combines — no global sort/scatter
    ever materializes. Without a policy (single device / unit tests) the
    same math runs with the full expert set locally.
    """
    from ..parallel import act as _act_mod
    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    e = mo.num_experts
    pol = _act_mod.current_policy()
    tp = "model"
    use_ep = (pol is not None and tp in pol.mesh.axis_names
              and e % pol.mesh.shape[tp] == 0)

    if not use_ep:
        out, aux = _moe_dispatch_compute(
            {k_: v for k_, v in p.items() if k_ != "shared"},
            x.reshape(b * s, d), cfg)
    else:
        from ..core.distributed import shard_map_compat
        shard_map, unchecked = shard_map_compat()
        mesh = pol.mesh
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tp_size = mesh.shape[tp]
        e_loc = e // tp_size
        fsdp = tuple(a for a in pol.fsdp_axes if a in mesh.axis_names)
        fsdp_size = 1
        for a in fsdp:
            fsdp_size *= mesh.shape[a]
        stationary = (pol.moe_stationary and fsdp
                      and mo.d_expert % fsdp_size == 0)
        if stationary:
            # decode: weights stay put — experts over tp, FFN dim over the
            # fsdp axes; the (tiny) token set is replicated in and the
            # partial outputs psum over (tp + fsdp).
            pspecs = {
                "router": P(None, None),
                "w_gate": P(tp, None, fsdp),
                "w_up": P(tp, None, fsdp),
                "w_down": P(tp, fsdp, None),
            }
            x_spec = P(None, None, None)
            psum_axes = (tp,) + fsdp
        else:
            pspecs = {
                "router": P(None, None),
                "w_gate": P(tp, None, None),
                "w_up": P(tp, None, None),
                "w_down": P(tp, None, None),
            }
            x_spec = P(dp, None, None)
            psum_axes = (tp,)
        if "router_bias" in p:
            pspecs["router_bias"] = P(None)
        pl = {k_: p[k_] for k_ in pspecs}

        def body(xl, pw):
            tb, ts, _ = xl.shape
            tp_rank = jax.lax.axis_index(tp)
            out, aux = _moe_dispatch_compute(
                pw, xl.reshape(tb * ts, d), cfg,
                e_offset=tp_rank * e_loc, e_count=e_loc,
                psum_axis=psum_axes)
            if not stationary and dp:
                aux = jax.lax.pmean(aux, dp)
            return out.reshape(tb, ts, d), aux

        out, aux = shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, pspecs),
            out_specs=(x_spec, P()),
            **unchecked,
        )(x, pl)
        out = out.reshape(b * s, d)
        aux = aux.reshape(())

    if mo.num_shared:
        out = out + apply_mlp(p["shared"], x.reshape(b * s, d), cfg)
    return out.reshape(b, s, d), aux


def moe_load_aux(probs, top_idx, e):
    """Switch-style load-balance aux loss: E * sum_e f_e * p_e."""
    t, k = top_idx.shape
    hits = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    f = hits / (t * k)
    pbar = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pbar)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, chunked) — faithful to the SSD dual form of arXiv:2405.21060
# ---------------------------------------------------------------------------

def init_ssm(cfg: ModelConfig, key):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    sc = 0.02
    # in_proj emits [z, x, B, C, dt]
    zxbcdt = 2 * d_in + 2 * s.n_groups * s.state_dim + h
    return {
        "w_in": (jax.random.normal(ks[0], (d, zxbcdt)) * sc).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim)) * sc).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), dt),
        "w_out": (jax.random.normal(ks[3], (d_in, d))
                  * sc / math.sqrt(2 * cfg.num_layers)).astype(dt),
    }


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-tri cumulative sums over segments."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk, init_state=None):
    """SSD scan. xh: (B,S,H,P); dt: (B,S,H) fp32; a: (H,) fp32 (negative);
    bmat/cmat: (B,S,G,N). Returns (y: (B,S,H,P), final_state (B,H,P,N))."""
    b, s_len, h, p_dim = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    q = min(chunk, s_len)
    nc = -(-s_len // q)
    pad = nc * q - s_len
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rep = h // g
    # chunked views: (B, NC, Q, ...)
    xc = xh.reshape(b, nc, q, h, p_dim).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, g, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc, rep, axis=3)                  # (B,NC,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a[None, None, None, :]                 # (B,NC,Q,H) negative
    da_cum = jnp.cumsum(da, axis=2)                   # within-chunk cumsum
    da_total = da_cum[:, :, -1]                       # (B,NC,H)

    # 1) intra-chunk (dual quadratic form): Y_d = (C B^T . L) (dt x)
    l_mat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))        # (B,NC,H,Q,Q)
    cb = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh)
    att = cb * l_mat
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", att, dtc, xc)

    # 2) chunk states: S_c = sum_k exp(da_total - da_cum_k) dt_k B_k x_k
    decay = jnp.exp(da_total[:, :, None] - da_cum)            # (B,NC,Q,H)
    states = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn",
                        decay, dtc, bh, xc)                   # (B,NC,H,P,N)

    # 3) inter-chunk recurrence over chunk states
    def scan_fn(carry, inp):
        st, tot = inp                                  # (B,H,P,N), (B,H)
        new = st + carry * jnp.exp(tot)[:, :, None, None]
        return new, carry

    s0 = (jnp.zeros((b, h, p_dim, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        scan_fn, s0, (states.swapaxes(0, 1), da_total.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)           # state BEFORE chunk c

    # 4) inter-chunk output: Y_off = C . exp(da_cum) . prev_state
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                       ch, jnp.exp(da_cum), prev_states)

    y = (y_diag + y_off).reshape(b, nc * q, h, p_dim)[:, :s_len]
    return y, final


def apply_ssm(p, x, cfg: ModelConfig, *, conv_state=None, ssm_state=None,
              decode=False):
    """Mamba-2 block. Train/prefill: full sequence (chunked SSD). Decode:
    single-token recurrent update using (conv_state, ssm_state)."""
    s: SSMConfig = cfg.ssm
    b, seq, d = x.shape
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    g, n = s.n_groups, s.state_dim
    conv_dim = d_in + 2 * g * n

    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]            # (B,S,H)

    # causal depthwise conv over xbc
    w = p["conv_w"]                                    # (W, conv_dim)
    cw = s.conv_width
    if decode:
        # conv_state: (B, W-1, conv_dim) last inputs
        window = jnp.concatenate([conv_state, xbc], axis=1)   # (B, W, conv)
        new_conv_state = window[:, 1:]
        xbc = jnp.einsum("bwc,wc->bc", window, w)[:, None] + p["conv_b"]
    else:
        pad = jnp.zeros((b, cw - 1, conv_dim), xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
        new_conv_state = xp[:, -(cw - 1):] if cw > 1 else xp[:, :0]
        xbc = sum(xp[:, i:i + seq] * w[i] for i in range(cw)) + p["conv_b"]
    xbc = jax.nn.silu(xbc)

    xin = xbc[..., :d_in].reshape(b, -1, h, s.head_dim)
    bmat = xbc[..., d_in:d_in + g * n].reshape(b, -1, g, n)
    cmat = xbc[..., d_in + g * n:].reshape(b, -1, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                           # (H,) negative

    if decode:
        # recurrent: state' = exp(dt a) state + dt B x ; y = C state' + D x
        rep = h // g
        bh = jnp.repeat(bmat[:, 0], rep, axis=1)       # (B,H,N)
        ch = jnp.repeat(cmat[:, 0], rep, axis=1)
        xf = xin[:, 0].astype(jnp.float32)             # (B,H,P)
        dt0 = dt[:, 0]                                  # (B,H)
        decay = jnp.exp(dt0 * a[None, :])[:, :, None, None]
        upd = (dt0[:, :, None] * xf)[..., None] * bh[:, :, None, :].astype(jnp.float32)
        new_state = ssm_state.astype(jnp.float32) * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", new_state, ch.astype(jnp.float32))
        y = y + p["d_skip"][None, :, None] * xf
        y = y[:, None].reshape(b, 1, d_in)
    else:
        yh, new_state = _ssd_chunked(xin, dt, a, bmat, cmat, s.chunk,
                                     init_state=ssm_state)
        yh = yh + p["d_skip"][None, None, :, None] * xin.astype(jnp.float32)
        y = yh.reshape(b, seq, d_in)

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = _norm_vec(y, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    return out, (new_conv_state.astype(x.dtype), new_state)
