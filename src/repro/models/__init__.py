"""10-architecture model zoo (pure JAX, parameter pytrees, scan-over-layers).

Families: dense (GQA / sliding+softcap / qk-norm / QKV-bias), MoE (top-k +
shared + dense-residual), MLA (DeepSeek), SSM (Mamba2-SSD), hybrid (Zamba2),
enc-dec audio (Whisper backbone), early-fusion VLM backbone (Chameleon).
"""
from .model import (  # noqa: F401
    init_params,
    forward,
    loss_fn,
    init_cache,
    prefill,
    decode_step,
)
