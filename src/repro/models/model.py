"""Model assembly: init / forward / loss / cache for all 10 architectures.

One ``forward`` serves train, prefill and decode (mode-switched), so the
dry-run lowers exactly what the trainer/server run. Layer stacks run under
``lax.scan`` (stacked params) to keep HLO size independent of depth;
heterogeneous structures (DeepSeek first-dense, Zamba2 hybrid groups,
Whisper enc-dec) are small Python compositions of scanned stacks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.act import constrain
from . import blocks as B
from . import layers as L

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "ln_f": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(keys[1],
                                          (cfg.d_model, cfg.vocab_size))
                        * 0.02).astype(dt)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(lambda k: B.init_attn_block(cfg, k),
                                  keys[2], cfg.num_layers)
    elif fam == "moe" and cfg.mla is not None:        # DeepSeek-V3
        nd = cfg.moe.first_dense_layers
        if nd:
            p["mla_dense"] = _stack_init(
                lambda k: B.init_mla_block(cfg, k, moe=False), keys[2], nd)
        p["mla_moe"] = _stack_init(
            lambda k: B.init_mla_block(cfg, k, moe=True), keys[3],
            cfg.num_layers - nd)
        if cfg.mtp:
            p["mtp"] = {
                "proj": (jax.random.normal(keys[4],
                                           (2 * cfg.d_model, cfg.d_model))
                         * 0.02).astype(dt),
                "block": B.init_mla_block(cfg, keys[5], moe=False),
                "ln": L.init_norm(cfg, cfg.d_model),
            }
    elif fam == "moe":                                 # Arctic
        p["blocks"] = _stack_init(lambda k: B.init_moe_block(cfg, k),
                                  keys[2], cfg.num_layers)
    elif fam == "ssm":
        p["blocks"] = _stack_init(lambda k: B.init_ssm_block(cfg, k),
                                  keys[2], cfg.num_layers)
    elif fam == "hybrid":
        p["blocks"] = _stack_init(lambda k: B.init_ssm_block(cfg, k),
                                  keys[2], cfg.num_layers)
        p["shared_attn"] = B.init_attn_block(cfg, keys[3])   # ONE weight set
    elif fam == "audio":                               # Whisper backbone
        p["enc_blocks"] = _stack_init(lambda k: B.init_attn_block(cfg, k),
                                      keys[2], cfg.encoder_layers)
        p["blocks"] = _stack_init(
            lambda k: B.init_attn_block(cfg, k, cross=True), keys[3],
            cfg.num_layers)
        p["ln_enc"] = L.init_norm(cfg, cfg.d_model)
        p["enc_pos"] = (jax.random.normal(keys[4],
                                          (cfg.encoder_seq, cfg.d_model))
                        * 0.02).astype(dt)
        p["dec_pos"] = (jax.random.normal(keys[5], (cfg.max_pos, cfg.d_model))
                        * 0.02).astype(dt)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    """Zeroed decoding cache sized for ``max_seq`` context."""
    dt = jnp.dtype(cfg.dtype)
    hd, hkv = cfg.head_dim_, cfg.num_kv_heads

    def kv(layers, seq=max_seq, heads=hkv, dim=hd):
        return {"k": jnp.zeros((layers, batch, seq, heads, dim), dt),
                "v": jnp.zeros((layers, batch, seq, heads, dim), dt)}

    def ssm_state(layers):
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        h = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.state_dim
        return {
            "conv": jnp.zeros((layers, batch, s.conv_width - 1, conv_dim), dt),
            "ssm": jnp.zeros((layers, batch, h, s.head_dim, s.state_dim),
                             jnp.float32),
        }

    fam = cfg.family
    cache: Dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "vlm"):
        cache["blocks"] = kv(cfg.num_layers)
    elif fam == "moe" and cfg.mla is not None:
        m = cfg.mla
        nd = cfg.moe.first_dense_layers

        def mla(layers):
            return {"ckv": jnp.zeros((layers, batch, max_seq,
                                      m.kv_lora_rank), dt),
                    "krope": jnp.zeros((layers, batch, max_seq,
                                        m.qk_rope_dim), dt)}
        if nd:
            cache["mla_dense"] = mla(nd)
        cache["mla_moe"] = mla(cfg.num_layers - nd)
    elif fam == "moe":
        cache["blocks"] = kv(cfg.num_layers)
    elif fam == "ssm":
        cache["blocks"] = ssm_state(cfg.num_layers)
    elif fam == "hybrid":
        cache["blocks"] = ssm_state(cfg.num_layers)
        n_groups = cfg.num_layers // cfg.hybrid_attn_every
        cache["shared_attn"] = kv(n_groups)
    elif fam == "audio":
        cache["blocks"] = kv(cfg.num_layers)
        cache["blocks"]["ck"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.encoder_seq, hkv, hd), dt)
        cache["blocks"]["cv"] = jnp.zeros_like(cache["blocks"]["ck"])
    return cache


# ---------------------------------------------------------------------------
# Scanned stacks
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _scan_stack(block_fn, stack, x, cache, cfg, n_layers, idx0=0):
    """Run ``block_fn`` over a stacked param group under lax.scan.

    block_fn(lp, x, layer_idx, cache_l) -> (x, new_cache_l, aux)
    Returns (x, new_cache_stack, aux_sum).
    """
    idxs = jnp.arange(idx0, idx0 + n_layers)

    def body(carry, xs):
        x, aux = carry
        lp, li, cache_l = xs
        x = constrain(x, "residual")
        x, new_cache_l, a = block_fn(lp, x, li, cache_l)
        return (x, aux + a), new_cache_l

    body = _maybe_remat(body, cfg)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0)),
                                       (stack, idxs, cache))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _pos_info(cfg: ModelConfig, seq: int, max_seq: int, index=None) -> B.PosInfo:
    if index is None:                       # train / prefill: positions 0..S
        pos = jnp.arange(seq)
        return B.PosInfo(pos, pos, jnp.arange(max_seq), None)
    pos = jnp.full((seq,), index, jnp.int32)   # decode: one token at `index`
    return B.PosInfo(pos, pos, jnp.arange(max_seq), index + 1)


def _embed(cfg: ModelConfig, p, tokens):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    return x


def _unembed(cfg: ModelConfig, p, x):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = x @ w
    if cfg.final_logit_softcap:
        logits = L.softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, "logits")


def forward(cfg: ModelConfig, params: PyTree, tokens: jax.Array, *,
            enc_inputs: Optional[jax.Array] = None,
            cache: Optional[PyTree] = None,
            mode: str = "train"):
    """Run the model.

    mode="train":   tokens (B, S) -> logits (B, S, V). cache must be None.
    mode="prefill": tokens (B, S) -> (logits (B, S, V), filled cache).
    mode="decode":  tokens (B, 1) -> (logits (B, 1, V), updated cache);
                    position taken from cache["index"].
    enc_inputs: (B, S_enc, D) precomputed frame/patch embeddings
                (whisper stub frontend).
    """
    assert mode in ("train", "prefill", "decode")
    b, seq = tokens.shape
    decode = mode == "decode"
    use_cache = cache is not None
    max_seq = seq
    index = None
    if use_cache:
        index = cache["index"] if decode else None
        max_seq = _cache_seq(cfg, cache)
    pos = _pos_info(cfg, seq, max_seq, index)

    x = _embed(cfg, params, tokens)
    x = constrain(x, "residual")
    fam = cfg.family
    aux = jnp.float32(0)
    new_cache = dict(cache) if use_cache else None

    if fam in ("dense", "vlm"):
        def blk(lp, x, li, cache_l):
            x, nc = B.attn_block(lp, x, cfg, layer_idx=li, pos=pos,
                                 cache=cache_l)
            return x, nc, jnp.float32(0)
        x, nc, _ = _scan_stack(blk, params["blocks"], x,
                               cache["blocks"] if use_cache else None,
                               cfg, cfg.num_layers)
        if use_cache:
            new_cache["blocks"] = nc

    elif fam == "moe" and cfg.mla is not None:         # DeepSeek-V3
        nd = cfg.moe.first_dense_layers
        if nd:
            def blk_d(lp, x, li, cache_l):
                return B.mla_block(lp, x, cfg, layer_idx=li, pos=pos,
                                   cache=cache_l)
            x, nc, a = _scan_stack(blk_d, params["mla_dense"], x,
                                   cache["mla_dense"] if use_cache else None,
                                   cfg, nd)
            aux += a
            if use_cache:
                new_cache["mla_dense"] = nc

        def blk_m(lp, x, li, cache_l):
            return B.mla_block(lp, x, cfg, layer_idx=li, pos=pos,
                               cache=cache_l)
        x, nc, a = _scan_stack(blk_m, params["mla_moe"], x,
                               cache["mla_moe"] if use_cache else None,
                               cfg, cfg.num_layers - nd, idx0=nd)
        aux += a
        if use_cache:
            new_cache["mla_moe"] = nc

    elif fam == "moe":                                  # Arctic
        def blk(lp, x, li, cache_l):
            return B.moe_block(lp, x, cfg, layer_idx=li, pos=pos,
                               cache=cache_l)
        x, nc, a = _scan_stack(blk, params["blocks"], x,
                               cache["blocks"] if use_cache else None,
                               cfg, cfg.num_layers)
        aux += a
        if use_cache:
            new_cache["blocks"] = nc

    elif fam == "ssm":
        def blk(lp, x, li, cache_l):
            x, nc = B.ssm_block(lp, x, cfg, layer_idx=li, cache=cache_l,
                                decode=decode)
            return x, nc, jnp.float32(0)
        x, nc, _ = _scan_stack(blk, params["blocks"], x,
                               cache["blocks"] if use_cache else None,
                               cfg, cfg.num_layers)
        if use_cache:
            new_cache["blocks"] = nc

    elif fam == "hybrid":                               # Zamba2
        every = cfg.hybrid_attn_every
        n_groups = cfg.num_layers // every
        ssm_stack = params["blocks"]
        nc_ssm, nc_attn = [], []
        for g in range(n_groups):
            sl = lambda t: jax.tree.map(lambda a: a[g * every:(g + 1) * every], t)
            def blk(lp, x, li, cache_l):
                x, nc = B.ssm_block(lp, x, cfg, layer_idx=li, cache=cache_l,
                                    decode=decode)
                return x, nc, jnp.float32(0)
            x, nc, _ = _scan_stack(
                blk, sl(ssm_stack), x,
                sl(cache["blocks"]) if use_cache else None, cfg, every,
                idx0=g * every)
            if use_cache:
                nc_ssm.append(nc)
            # shared (weight-tied) attention block, per-group KV cache
            attn_cache = (jax.tree.map(lambda a: a[g], cache["shared_attn"])
                          if use_cache else None)
            shared = _maybe_remat(
                lambda px, ac: B.attn_block(params["shared_attn"], px, cfg,
                                            layer_idx=g, pos=pos, cache=ac),
                cfg)
            x, ac_new = shared(x, attn_cache)
            if use_cache:
                nc_attn.append(ac_new)
        if use_cache:
            new_cache["blocks"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *nc_ssm)
            new_cache["shared_attn"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *nc_attn)

    elif fam == "audio":                                # Whisper backbone
        assert enc_inputs is not None or (use_cache and decode), \
            "whisper needs enc_inputs (stub frontend) except in decode"
        start = jnp.int32(0) if index is None else index
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], start, seq, axis=0).astype(x.dtype)
        enc_out = None
        if enc_inputs is not None:
            e = enc_inputs.astype(x.dtype) + params["enc_pos"][None].astype(x.dtype)
            enc_pos_info = B.PosInfo(jnp.arange(cfg.encoder_seq),
                                     jnp.arange(cfg.encoder_seq),
                                     jnp.arange(cfg.encoder_seq), None)

            def eblk(lp, e, li, cache_l):
                e, _ = B.attn_block(lp, e, cfg, layer_idx=li,
                                    pos=enc_pos_info, cache=None,
                                    causal=False)
                return e, 0, jnp.float32(0)
            e, _, _ = _scan_stack(eblk, params["enc_blocks"], e,
                                  None, cfg, cfg.encoder_layers)
            enc_out = L.apply_norm(params["ln_enc"], e, cfg)

        def dblk(lp, x, li, cache_l):
            x, nc = B.attn_block(lp, x, cfg, layer_idx=li, pos=pos,
                                 cache=cache_l, enc_out=enc_out)
            return x, nc, jnp.float32(0)
        x, nc, _ = _scan_stack(dblk, params["blocks"], x,
                               cache["blocks"] if use_cache else None,
                               cfg, cfg.num_layers)
        if use_cache:
            new_cache["blocks"] = nc

    x = L.apply_norm(params["ln_f"], x, cfg)
    logits = _unembed(cfg, params, x)

    if use_cache:
        new_cache["index"] = (cache["index"] + seq) if decode else \
            jnp.asarray(seq, jnp.int32)
        return (logits, new_cache, aux) if mode == "train" else \
            (logits, new_cache)
    return logits, aux, x


def _cache_seq(cfg: ModelConfig, cache) -> int:
    if cfg.family in ("ssm",):
        return 0
    if cfg.mla is not None:
        return cache["mla_moe"]["ckv"].shape[2]
    if cfg.family == "hybrid":
        return cache["shared_attn"]["k"].shape[2]
    return cache["blocks"]["k"].shape[2]


# ---------------------------------------------------------------------------
# Loss / prefill / decode entry points
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """Token-mean CE in fp32 with z-loss; logits (B,S,V), labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    loss = jnp.mean(ce)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
            *, aux_weight: float = 1e-2, mtp_weight: float = 0.3):
    """Next-token CE (+ MoE aux + optional MTP). batch: inputs, labels
    (B, S) int32 [+ enc_inputs (B, S_enc, D)]."""
    logits, aux, h = forward(cfg, params, batch["inputs"],
                             enc_inputs=batch.get("enc_inputs"), mode="train")
    loss = cross_entropy(logits, batch["labels"])
    metrics = {"ce": loss, "moe_aux": aux}
    if cfg.moe is not None:
        loss = loss + aux_weight * aux
    if cfg.mtp and "mtp" in params:
        # depth-1 multi-token prediction: combine h_t with emb(x_{t+1})
        # to predict label_{t+1} (= token t+2).
        emb_next = _embed(cfg, params, batch["inputs"][:, 1:])
        hcat = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        hm = L.apply_norm(params["mtp"]["ln"],
                          hcat @ params["mtp"]["proj"], cfg)
        pos = _pos_info(cfg, hm.shape[1], hm.shape[1])
        hm, _, _ = B.mla_block(params["mtp"]["block"], hm, cfg,
                               layer_idx=0, pos=pos)
        mtp_logits = _unembed(cfg, params, hm)
        mtp_loss = cross_entropy(mtp_logits, batch["labels"][:, 1:])
        metrics["mtp_ce"] = mtp_loss
        loss = loss + mtp_weight * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def prefill(cfg: ModelConfig, params, tokens, cache, *, enc_inputs=None):
    """Fill ``cache`` from a (B, S) prompt; returns (last_logits, cache)."""
    logits, cache = forward(cfg, params, tokens, cache=cache,
                            enc_inputs=enc_inputs, mode="prefill")
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, tokens, cache):
    """One decode step: tokens (B, 1) at position cache["index"]."""
    logits, cache = forward(cfg, params, tokens, cache=cache, mode="decode")
    return logits[:, -1], cache
