"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes by zero-padding to block multiples (exact for
matmul/syrk/transpose/combine) and slicing back. ``interpret`` defaults to
True off-TPU so the same call sites validate on CPU and run compiled on TPU.

Block sizes default to ``None`` = "consult the gram autotune cache"
(``gram/autotune.py``; winners persisted per shape bucket under
``artifacts/autotune/``), falling back to 256 when untuned.  Explicit
block arguments bypass the cache entirely.
"""
from __future__ import annotations

import functools
import logging
import os

import jax
import jax.numpy as jnp

from . import matmul as _matmul
from . import syrk as _syrk
from . import combine as _combine
from . import transpose as _transpose
from ..core.symmetry import unpack_tril_blocks

_log = logging.getLogger(__name__)

# Backends with a native Pallas lowering for the pltpu primitives the
# kernels use (scalar prefetch, DMA semaphores).  GPU has no Triton port
# of those yet, so off-TPU backends run the interpreter.
_COMPILED_BACKENDS = ("tpu",)

# (site, backend, decision) triples already logged — the decision is
# per-call-site but only logs once per distinct combination, so hot
# serving loops don't spam.
_INTERPRET_LOGGED: set = set()


def _auto_interpret(interpret, site=None):
    """Resolve the ``interpret`` knob for one kernel call site.

    Explicit arguments always win.  Otherwise the ``REPRO_INTERPRET``
    env var ("1"/"true" forces interpret, "0"/"false" forces compiled)
    overrides, then the per-backend default applies: compiled on TPU,
    interpret on CPU/GPU where the kernels are unsupported.  Each
    distinct (site, backend) decision is logged once.
    """
    if interpret is not None:
        return interpret
    backend = jax.default_backend()
    env = os.environ.get("REPRO_INTERPRET", "").strip().lower()
    if env in ("1", "true", "yes", "on"):
        decision, why = True, "REPRO_INTERPRET override"
    elif env in ("0", "false", "no", "off"):
        decision, why = False, "REPRO_INTERPRET override"
    else:
        decision = backend not in _COMPILED_BACKENDS
        why = ("native pallas lowering" if not decision
               else "kernel unsupported off-TPU")
    key = (site, backend, decision)
    if key not in _INTERPRET_LOGGED:
        _INTERPRET_LOGGED.add(key)
        _log.info("pallas interpret=%s at %s [backend=%s: %s]",
                  decision, site or "<unnamed site>", backend, why)
    return decision


def _resolve_blocks(kind, m, n, dtype, **blocks):
    """Fill ``None`` block sizes from the gram autotune cache
    (``artifacts/autotune/gram_autotune.json``; see gram/autotune.py)
    instead of the historical hardcoded 256s.  Explicit values win; a
    missing/broken cache degrades to 256."""
    if all(v is not None for v in blocks.values()):
        return blocks
    try:
        from ..gram.autotune import resolve_block_defaults
        return resolve_block_defaults(kind, m, n, dtype, **blocks)
    except Exception:
        return {k: (256 if v is None else v) for k, v in blocks.items()}


def _pad_to(x, mults):
    pads = [(-d) % m for d, m in zip(x.shape, mults)]
    if any(pads):
        x = jnp.pad(x, [(0, p) for p in pads])
    return x


def matmul(a, b, *, bm=None, bk=None, bn=None, interpret=None):
    """``a @ b`` via the tiled MXU kernel; any shapes, any float dtype.
    Block sizes default to the autotune-cache winner for this shape
    bucket (256 when untuned)."""
    bs = _resolve_blocks("matmul", a.shape[0], b.shape[1], a.dtype,
                         bm=bm, bk=bk, bn=bn)
    return _matmul_jit(a, b, bm=bs["bm"], bk=bs["bk"], bn=bs["bn"],
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def _matmul_jit(a, b, *, bm, bk, bn, interpret=None):
    interpret = _auto_interpret(interpret)
    m, n = a.shape[0], b.shape[1]
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    out = _matmul.matmul_padded(ap, bp, bm=bm, bk=bk, bn=bn,
                                interpret=interpret)
    return out[:m, :n]


def syrk_packed(a, *, bk=None, bn=None, interpret=None):
    """Packed lower-tri block stack of ``a.T @ a`` (padded N -> caller keeps
    block layout; use :func:`syrk` for a dense result at original size)."""
    bs = _resolve_blocks("ata", a.shape[0], a.shape[1], a.dtype, bk=bk, bn=bn)
    return _syrk_packed_jit(a, bk=bs["bk"], bn=bs["bn"], interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "interpret"))
def _syrk_packed_jit(a, *, bk, bn, interpret=None):
    interpret = _auto_interpret(interpret)
    ap = _pad_to(a, (bk, bn))
    return _syrk.syrk_packed(ap, bk=bk, bn=bn, interpret=interpret)


def syrk(a, *, bk=None, bn=None, symmetrize=False, interpret=None):
    """Dense ``tril(a.T @ a)`` (or full symmetric) via the packed kernel."""
    bs = _resolve_blocks("ata", a.shape[0], a.shape[1], a.dtype, bk=bk, bn=bn)
    return _syrk_jit(a, bk=bs["bk"], bn=bs["bn"], symmetrize=symmetrize,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "symmetrize", "interpret"))
def _syrk_jit(a, *, bk, bn, symmetrize=False, interpret=None):
    interpret = _auto_interpret(interpret)
    n = a.shape[1]
    ap = _pad_to(a, (bk, bn))
    packed = _syrk.syrk_packed(ap, bk=bk, bn=bn, interpret=interpret)
    dense = unpack_tril_blocks(packed, ap.shape[1], bn, symmetrize=symmetrize)
    if not symmetrize:
        # diagonal blocks are computed full (bn x bn) — drop their upper halves
        dense = jnp.tril(dense)
    return dense[:n, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def strassen_combine(m1, m2, m3, m4, m5, m6, m7, *, bm=256, bn=256,
                     interpret=None):
    """Fused Strassen recombination -> (c11, c12, c21, c22).
    (No autotune-cache consultation: recombination blocking is not part
    of the tuned search space.)"""
    interpret = _auto_interpret(interpret)
    m, n = m1.shape
    ms = [_pad_to(x, (bm, bn)) for x in (m1, m2, m3, m4, m5, m6, m7)]
    outs = _combine.strassen_combine(*ms, bm=bm, bn=bn, interpret=interpret)
    return tuple(o[:m, :n] for o in outs)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def transpose(a, *, bm=256, bn=256, interpret=None):
    """``a.T`` via the tiled transpose kernel."""
    interpret = _auto_interpret(interpret)
    m, n = a.shape
    ap = _pad_to(a, (bm, bn))
    return _transpose.transpose_padded(ap, bm=bm, bn=bn,
                                       interpret=interpret)[:n, :m]


# ---------------------------------------------------------------------------
# Kernel-backed base cases for the core recursion (TPU hot path).
# ---------------------------------------------------------------------------

def pallas_base_matmul(bm=None, bk=None, bn=None, interpret=None):
    """base_matmul hook for repro.core.strassen_matmul."""
    def base(a, b):
        return matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=interpret)
    return base


def pallas_base_syrk(bk=None, bn=None, interpret=None):
    """base_syrk hook for repro.core.ata (lower-tri-only leaf gram)."""
    def base(a):
        return syrk(a, bk=bk, bn=bn, symmetrize=False, interpret=interpret)
    return base


# ---------------------------------------------------------------------------
# Fused schedule pipeline (core/schedule.py -> kernels/strassen_fused.py):
# the whole level-capped ATA / Strassen recursion in ONE pallas_call, no
# per-level HBM temporaries.  These are the jit'd public entry points; the
# core recursion routes here via ata(..., mode="fused").
# ---------------------------------------------------------------------------

def ata_fused(a, *, levels=2, variant="strassen", gram="strassen", bk=None,
              bn=None, out_dtype=None, interpret=None, bwd="fused",
              pipeline_depth=None, operand_dtype=None, acc_dtype=None,
              sr_seed=None):
    """Dense ``tril(a.T @ a)`` via the fused leaf-task schedule.
    ``bk``/``bn`` default to the autotune-cache winner for this shape
    bucket (256 when untuned).  ``gram`` picks the registered symmetric
    decomposition (``leaf_ir.registered_gram_algebras()``; ``"dps"`` is
    the 5-product scheme).  ``bwd`` picks the VJP engine: ``"fused"``
    (packed-cotangent symm schedule, the default) or ``"dense"`` (the
    classical dense-dot baseline).

    Perf/precision knobs (DESIGN.md §16): ``pipeline_depth`` (revolving
    DMA buffers, None = backend default), ``operand_dtype`` (fp8/bf16
    operand tiles, fp32 accumulation), ``acc_dtype`` (VMEM accumulator
    storage) and ``sr_seed`` (stochastic-rounded bf16 output)."""
    bs = _resolve_blocks("ata", a.shape[0], a.shape[1], a.dtype, bk=bk, bn=bn)
    return _ata_fused_jit(a, levels=levels, variant=variant, gram=gram,
                          bk=bs["bk"], bn=bs["bn"], out_dtype=out_dtype,
                          interpret=interpret, bwd=bwd,
                          pipeline_depth=pipeline_depth,
                          operand_dtype=operand_dtype, acc_dtype=acc_dtype,
                          sr_seed=sr_seed)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "gram", "bk", "bn", "out_dtype", "interpret",
    "bwd", "pipeline_depth", "operand_dtype", "acc_dtype", "sr_seed"))
def _ata_fused_jit(a, *, levels, variant, gram="strassen", bk, bn,
                   out_dtype=None, interpret=None, bwd="fused",
                   pipeline_depth=None, operand_dtype=None, acc_dtype=None,
                   sr_seed=None):
    from . import strassen_fused as _sf
    return _sf.fused_ata(a, levels=levels, variant=variant, gram=gram,
                         bk=bk, bn=bn, out_dtype=out_dtype,
                         interpret=_auto_interpret(interpret,
                                                   site="ops.ata_fused"),
                         bwd=bwd, pipeline_depth=pipeline_depth,
                         operand_dtype=operand_dtype, acc_dtype=acc_dtype,
                         sr_seed=sr_seed)


def ata_fused_packed(a, *, levels=2, variant="strassen", gram="strassen",
                     bk=None, bn=None, out_dtype=None, interpret=None,
                     bwd="fused", pipeline_depth=None, operand_dtype=None,
                     acc_dtype=None, sr_seed=None):
    """Packed lower-tri block stack of ``a.T @ a`` via the fused schedule
    (upper-triangular blocks are never computed or written).
    Differentiable: the custom VJP consumes the *packed* cotangent
    directly (``bwd="fused"``) — no dense n^2 buffer in the backward.
    Same perf/precision knobs as :func:`ata_fused`."""
    bs = _resolve_blocks("ata", a.shape[0], a.shape[1], a.dtype, bk=bk, bn=bn)
    return _ata_fused_packed_jit(a, levels=levels, variant=variant,
                                 gram=gram, bk=bs["bk"], bn=bs["bn"],
                                 out_dtype=out_dtype, interpret=interpret,
                                 bwd=bwd, pipeline_depth=pipeline_depth,
                                 operand_dtype=operand_dtype,
                                 acc_dtype=acc_dtype, sr_seed=sr_seed)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "gram", "bk", "bn", "out_dtype", "interpret",
    "bwd", "pipeline_depth", "operand_dtype", "acc_dtype", "sr_seed"))
def _ata_fused_packed_jit(a, *, levels, variant, gram="strassen", bk, bn,
                          out_dtype=None, interpret=None, bwd="fused",
                          pipeline_depth=None, operand_dtype=None,
                          acc_dtype=None, sr_seed=None):
    from . import strassen_fused as _sf
    packed, _ = _sf.fused_ata_packed(
        a, levels=levels, variant=variant, gram=gram, bk=bk, bn=bn,
        out_dtype=out_dtype,
        interpret=_auto_interpret(interpret, site="ops.ata_fused_packed"),
        bwd=bwd, pipeline_depth=pipeline_depth, operand_dtype=operand_dtype,
        acc_dtype=acc_dtype, sr_seed=sr_seed)
    return packed


def symm_matmul(x, s_packed, *, levels=2, variant="strassen", bm=None,
                diag_sym=False, out_dtype=None, interpret=None,
                pipeline_depth=None, operand_dtype=None, acc_dtype=None):
    """``x @ Sym`` where Sym is given only as its packed lower-triangular
    tile stack (``syrk_packed`` / ``ata_fused_packed`` layout; the tile
    edge is read off the stack) — the symm-schedule kernel that powers
    the fused Gram backward.  ``diag_sym=True`` computes
    ``x @ (S + S^t)`` instead (the VJP operand)."""
    bs = _resolve_blocks("ata", x.shape[0], x.shape[1], x.dtype, bm=bm)
    return _symm_matmul_jit(x, s_packed, levels=levels, variant=variant,
                            bm=bs["bm"], diag_sym=diag_sym,
                            out_dtype=out_dtype, interpret=interpret,
                            pipeline_depth=pipeline_depth,
                            operand_dtype=operand_dtype, acc_dtype=acc_dtype)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "bm", "diag_sym", "out_dtype", "interpret",
    "pipeline_depth", "operand_dtype", "acc_dtype"))
def _symm_matmul_jit(x, s_packed, *, levels, variant, bm, diag_sym,
                     out_dtype=None, interpret=None, pipeline_depth=None,
                     operand_dtype=None, acc_dtype=None):
    from . import strassen_fused as _sf
    return _sf.fused_symm_matmul(
        x, s_packed, levels=levels, variant=variant, bm=bm,
        diag_sym=diag_sym, out_dtype=out_dtype,
        interpret=_auto_interpret(interpret, site="ops.symm_matmul"),
        pipeline_depth=pipeline_depth, operand_dtype=operand_dtype,
        acc_dtype=acc_dtype)


def matmul_fused(a, b, *, levels=2, variant="strassen", bm=None, bk=None,
                 bn=None, trans_a=False, trans_b=False, out_dtype=None,
                 interpret=None, bwd="fused", pipeline_depth=None,
                 operand_dtype=None, acc_dtype=None):
    """``op(a) @ op(b)`` via the fused Strassen program kernel;
    ``trans_a``/``trans_b`` transpose an operand *through the index
    maps* — no transposed HBM copy (the distributed ring/2.5D block
    tasks route here).  ``bwd="fused"`` (default) runs both VJP products
    through the same program with the transposes likewise folded."""
    m = a.shape[1] if trans_a else a.shape[0]
    n = b.shape[0] if trans_b else b.shape[1]
    bs = _resolve_blocks("matmul", m, n, a.dtype, bm=bm, bk=bk, bn=bn)
    return _matmul_fused_jit(a, b, levels=levels, variant=variant,
                             bm=bs["bm"], bk=bs["bk"], bn=bs["bn"],
                             trans_a=trans_a, trans_b=trans_b,
                             out_dtype=out_dtype, interpret=interpret,
                             bwd=bwd, pipeline_depth=pipeline_depth,
                             operand_dtype=operand_dtype,
                             acc_dtype=acc_dtype)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "bm", "bk", "bn", "trans_a", "trans_b",
    "out_dtype", "interpret", "bwd", "pipeline_depth", "operand_dtype",
    "acc_dtype"))
def _matmul_fused_jit(a, b, *, levels, variant, bm, bk, bn, trans_a=False,
                      trans_b=False, out_dtype=None, interpret=None,
                      bwd="fused", pipeline_depth=None, operand_dtype=None,
                      acc_dtype=None):
    from . import strassen_fused as _sf
    return _sf.fused_matmul(a, b, levels=levels, variant=variant, bm=bm,
                            bk=bk, bn=bn, trans_a=trans_a, trans_b=trans_b,
                            out_dtype=out_dtype,
                            interpret=_auto_interpret(
                                interpret, site="ops.matmul_fused"),
                            bwd=bwd, pipeline_depth=pipeline_depth,
                            operand_dtype=operand_dtype,
                            acc_dtype=acc_dtype)


def aat_fused(a, *, levels=2, variant="strassen", gram="strassen", bm=None,
              bk=None, out_dtype=None, interpret=None, pipeline_depth=None,
              operand_dtype=None, acc_dtype=None, sr_seed=None):
    """Dense ``tril(a @ a.T)`` — the Arrigoni-Massini row gram
    (``ata(x, gram_of="rows")``) via the same leaf-program executor; the
    transpose of ``a`` never exists in HBM."""
    bs = _resolve_blocks("aat", a.shape[0], a.shape[1], a.dtype,
                         bm=bm, bk=bk)
    return _aat_fused_jit(a, levels=levels, variant=variant, gram=gram,
                          bm=bs["bm"], bk=bs["bk"], out_dtype=out_dtype,
                          interpret=interpret,
                          pipeline_depth=pipeline_depth,
                          operand_dtype=operand_dtype, acc_dtype=acc_dtype,
                          sr_seed=sr_seed)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "gram", "bm", "bk", "out_dtype", "interpret",
    "pipeline_depth", "operand_dtype", "acc_dtype", "sr_seed"))
def _aat_fused_jit(a, *, levels, variant, gram="strassen", bm, bk,
                   out_dtype=None, interpret=None, pipeline_depth=None,
                   operand_dtype=None, acc_dtype=None, sr_seed=None):
    from . import strassen_fused as _sf
    return _sf.fused_aat(a, levels=levels, variant=variant, gram=gram,
                         bm=bm, bk=bk, out_dtype=out_dtype,
                         interpret=_auto_interpret(interpret,
                                                   site="ops.aat_fused"),
                         pipeline_depth=pipeline_depth,
                         operand_dtype=operand_dtype, acc_dtype=acc_dtype,
                         sr_seed=sr_seed)


def aat_fused_packed(a, *, levels=2, variant="strassen", gram="strassen",
                     bm=None, bk=None, out_dtype=None, interpret=None,
                     pipeline_depth=None, operand_dtype=None,
                     acc_dtype=None, sr_seed=None):
    """Packed lower-tri block stack of ``a @ a.T`` (row-gram dual of
    :func:`ata_fused_packed`)."""
    bs = _resolve_blocks("aat", a.shape[0], a.shape[1], a.dtype,
                         bm=bm, bk=bk)
    return _aat_fused_packed_jit(a, levels=levels, variant=variant,
                                 gram=gram, bm=bs["bm"], bk=bs["bk"],
                                 out_dtype=out_dtype, interpret=interpret,
                                 pipeline_depth=pipeline_depth,
                                 operand_dtype=operand_dtype,
                                 acc_dtype=acc_dtype, sr_seed=sr_seed)


@functools.partial(jax.jit, static_argnames=(
    "levels", "variant", "gram", "bm", "bk", "out_dtype", "interpret",
    "pipeline_depth", "operand_dtype", "acc_dtype", "sr_seed"))
def _aat_fused_packed_jit(a, *, levels, variant, gram="strassen", bm, bk,
                          out_dtype=None, interpret=None,
                          pipeline_depth=None, operand_dtype=None,
                          acc_dtype=None, sr_seed=None):
    from . import strassen_fused as _sf
    packed, _ = _sf.fused_aat_packed(
        a, levels=levels, variant=variant, gram=gram, bm=bm, bk=bk,
        out_dtype=out_dtype,
        interpret=_auto_interpret(interpret, site="ops.aat_fused_packed"),
        pipeline_depth=pipeline_depth, operand_dtype=operand_dtype,
        acc_dtype=acc_dtype, sr_seed=sr_seed)
    return packed


def rank_k_update(c_stack, a, *, levels=2, variant="strassen",
                  gram="strassen", bk=None, out_dtype=None, interpret=None,
                  donate=True, pipeline_depth=None, operand_dtype=None,
                  acc_dtype=None):
    """``C += tril(a.T @ a)`` on a packed tile stack in ONE kernel — the
    accumulating (rank-k) program.  The stack seeds the kernel's VMEM
    accumulator, so a streamed Gram chunk materializes no delta stack
    and no unpack/gather; with ``donate`` (default) the state buffer is
    donated so XLA updates it in place at the jit boundary."""
    bs = _resolve_blocks("rank_k", a.shape[0], a.shape[1], a.dtype, bk=bk)
    fn = _rank_k_jit_donated if donate else _rank_k_jit
    return fn(c_stack, a, levels=levels, variant=variant, gram=gram,
              bk=bs["bk"], out_dtype=out_dtype, interpret=interpret,
              pipeline_depth=pipeline_depth, operand_dtype=operand_dtype,
              acc_dtype=acc_dtype)


def _rank_k_impl(c_stack, a, *, levels, variant, gram="strassen", bk,
                 out_dtype=None, interpret=None, pipeline_depth=None,
                 operand_dtype=None, acc_dtype=None):
    from . import strassen_fused as _sf
    return _sf.fused_rank_k_update(
        c_stack, a, levels=levels, variant=variant, gram=gram, bk=bk,
        out_dtype=out_dtype,
        interpret=_auto_interpret(interpret, site="ops.rank_k_update"),
        pipeline_depth=pipeline_depth, operand_dtype=operand_dtype,
        acc_dtype=acc_dtype)


_rank_k_static = ("levels", "variant", "gram", "bk", "out_dtype",
                  "interpret", "pipeline_depth", "operand_dtype",
                  "acc_dtype")
_rank_k_jit = jax.jit(_rank_k_impl, static_argnames=_rank_k_static)
_rank_k_jit_donated = jax.jit(_rank_k_impl, static_argnames=_rank_k_static,
                              donate_argnums=(0,))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "interpret"))
def flash_mha(q, k, v, *, causal=True, window=0, softcap=0.0,
              block_q=512, block_kv=512, interpret=None):
    """FlashAttention with (B, S, H, D) layout + arbitrary seq lengths
    (pads to block multiples; padded kv is masked by causality/neg-inf)."""
    from . import flash_attention as _fa
    interpret = _auto_interpret(interpret)
    b, sq, h, d = q.shape
    skv = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, max(sq, 16))
    bk = min(block_kv, max(skv, 16))
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # pad kv with zeros; give padded keys -inf via a window trick is
        # not needed: padded q rows are sliced away, and padded kv columns
        # are masked because causal q_pos < kv_pos for all real q ... only
        # true for causal; for non-causal we mask via window=skv when
        # padding. Handled by masking below through kv_len emulation:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        if not causal:
            raise NotImplementedError(
                "non-causal flash with ragged kv: pad kv to block multiple "
                "at the call site")
    o = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                            softcap=softcap, block_q=bq, block_kv=bk,
                            interpret=interpret)
    return o[:, :, :sq].transpose(0, 2, 1, 3)
