"""Tiled MXU matmul Pallas kernel — the ATA/HASA base case on TPU.

The paper's base case (classical multiplication below size 32) becomes an
explicitly VMEM-tiled MXU matmul: (bm, bk) x (bk, bn) tiles with an fp32
VMEM accumulator, K innermost in the grid so the accumulator lives across
the K sweep of one output tile. Block shapes default to 256 (multiples of
the 128x128 systolic array; 8x128 lane/sublane aligned).

Inputs must be padded to block multiples (done by ops.matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_padded(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``a @ b`` for shapes already padded to (bm, bk) / (bk, bn) multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and k % bk == 0 and n % bn == 0, (
        a.shape, b.shape, bm, bk, bn)
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
