"""Tiled matrix transpose Pallas kernel.

The paper transposes A12/A22 with the cache-oblivious transpose of
[Kumar 2003]. The TPU analogue is an explicitly tiled transpose: block
(i, j) of the output is the transpose of block (j, i) of the input; each
(bm, bn) tile is transposed in VMEM (VREG shuffles), giving sequential HBM
reads and writes — the same locality the cache-oblivious algorithm gets
implicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...].T


def transpose_padded(
    a: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``a.T`` for (m, n) padded to block multiples (ops.transpose pads)."""
    m, n = a.shape
    assert m % bm == 0 and n % bn == 0, (a.shape, bm, bn)
    grid = (n // bn, m // bm)  # grid over OUTPUT blocks
    return pl.pallas_call(
        _transpose_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=interpret,
    )(a)
