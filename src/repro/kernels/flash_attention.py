"""FlashAttention Pallas kernel (TPU target, interpret-mode validated).

Scores never leave VMEM: the kv sweep is the innermost grid dim with an
online-softmax carry (m, l, acc) in VMEM scratch, so HBM traffic per
(batch, head) is q + k + v read once and o written once — vs the XLA
lowering that materializes (Sq, Skv) fp32 score tensors in HBM (the
dominant memory term of every prefill/train cell in the baseline roofline).

Layout: q (B, H, Sq, D), k/v (B, Hkv, Skv, D) — GQA folds the group into
the head index map (h -> h // group). Causal + sliding-window masking via
block-position arithmetic; fully-masked kv blocks are SKIPPED (causal
halves the work, window makes it O(S*W)).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, n_kv: int,
                  bq: int, bk: int, softcap: float):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: causal blocks strictly above the diagonal and
    # blocks entirely below the sliding window do no work at all
    work = (not causal) or (kv_i * bk <= q_i * bq + bq - 1)
    if window > 0:
        work = jnp.logical_and(
            work, (q_i * bq) - (kv_i * bk + bk - 1) < window)

    @pl.when(work)
    def _work():
        qb = q_ref[0, 0]                                       # (bq, d)
        kb = k_ref[0, 0]                                       # (bk, d)
        s = jax.lax.dot_general(
            qb, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= kv_pos
        if window > 0:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                    # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                 # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                         # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, 1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D); H % Hkv == 0.
    Sq % block_q == 0, Skv % block_kv == 0 (ops.flash_mha pads).
    Returns (B, H, Sq, D) in q.dtype."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq, bk = min(block_q, sq), min(block_kv, skv)
    n_q, n_kv = sq // bq, skv // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window or 0,
        n_kv=n_kv, bq=bq, bk=bk, softcap=softcap or 0.0)

    return pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // h, (bh % h) // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // h, (bh % h) // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # m
            pltpu.VMEM((bq, 1), jnp.float32),     # l
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
