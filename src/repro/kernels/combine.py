"""Fused Strassen recombination kernel.

Strassen's recombination (C11 = M1+M4-M5+M7, C12 = M3+M5, C21 = M2+M4,
C22 = M1-M2+M3+M6) is 10 elementwise adds that XLA would otherwise emit as
separate HBM-bound ops (the "18 cheaper matrix additions" side of the
paper's trade). Fusing them into one kernel reads each M_i exactly once and
writes each C quadrant exactly once: 7 reads + 4 writes per tile instead of
up to 20 HBM round-trips.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(m1, m2, m3, m4, m5, m6, m7, c11, c12, c21, c22):
    t1 = m1[...] + m4[...]
    c11[...] = t1 - m5[...] + m7[...]
    c12[...] = m3[...] + m5[...]
    c21[...] = m2[...] + m4[...]
    c22[...] = m1[...] - m2[...] + m3[...] + m6[...]


def strassen_combine(
    m1: jax.Array, m2: jax.Array, m3: jax.Array, m4: jax.Array,
    m5: jax.Array, m6: jax.Array, m7: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
):
    """Fused (C11, C12, C21, C22) from the 7 Strassen products.

    All M_i share shape (m, n); m % bm == 0 and n % bn == 0 expected
    (ops.strassen_combine pads & slices).
    """
    m, n = m1.shape
    assert m % bm == 0 and n % bn == 0, (m1.shape, bm, bn)
    grid = (m // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    shp = jax.ShapeDtypeStruct((m, n), m1.dtype)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=[spec] * 4,
        out_shape=[shp] * 4,
        interpret=interpret,
    )(m1, m2, m3, m4, m5, m6, m7)
