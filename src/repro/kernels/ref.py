"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def syrk_packed_ref(a: jax.Array, bn: int, out_dtype=None) -> jax.Array:
    """Packed lower-triangular block stack of a.T @ a (row-major tri order)."""
    out_dtype = out_dtype or a.dtype
    c = jnp.dot(a.T, a, preferred_element_type=jnp.float32).astype(out_dtype)
    n = c.shape[0]
    t = n // bn
    blocks = [c[i * bn:(i + 1) * bn, j * bn:(j + 1) * bn]
              for i in range(t) for j in range(i + 1)]
    return jnp.concatenate(blocks, axis=0)


def strassen_combine_ref(m1, m2, m3, m4, m5, m6, m7):
    c11 = m1 + m4 - m5 + m7
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = m1 - m2 + m3 + m6
    return c11, c12, c21, c22


def transpose_ref(a: jax.Array) -> jax.Array:
    return a.T


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                        softcap=0.0):
    """Plain softmax attention; q (B,H,Sq,D), k/v (B,Hkv,Skv,D)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    kf = jnp.repeat(k, g, axis=1)
    vf = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vf.astype(jnp.float32)).astype(q.dtype)
