"""SYRK Pallas kernel: C = A^t A computing ONLY lower-triangular blocks.

This is the paper's central memory/work saving (store n(n+1)/2 instead of
n^2) realized at TPU block granularity: the grid enumerates the T(T+1)/2
lower-triangular (i, j) block pairs — upper blocks are never scheduled, so
both the MXU work and the HBM writes for them simply do not exist.

Output is the *packed triangular block stack* of shape (T(T+1)/2 * bn, bn)
(block t at rows [t*bn, (t+1)*bn)), matching
``repro.core.symmetry.pack_tril_blocks`` ordering; unpack with
``unpack_tril_blocks``.

The linear grid index t is decoded to (i, j) inside the index_maps with an
integer-corrected float sqrt (exact for t < 2^22, far beyond any real T).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tri_decode(t):
    """Linear lower-triangular index -> (i, j), i >= j, row-major."""
    tf = t.astype(jnp.float32)
    i = ((jnp.sqrt(8.0 * tf + 1.0) - 1.0) * 0.5).astype(jnp.int32)
    # float-precision correction (at most one step either way)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    i = jnp.where(i * (i + 1) // 2 > t, i - 1, i)
    j = t - i * (i + 1) // 2
    return i, j


def _syrk_kernel(ai_ref, aj_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bk, bn)^T @ (bk, bn) -> (bn, bn) on the MXU, fp32 accumulation.
    acc_ref[...] += jnp.dot(
        ai_ref[...].T, aj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def syrk_packed(
    a: jax.Array,
    *,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Packed lower-triangular block stack of ``a.T @ a``.

    ``a``: (M, N) with M % bk == 0, N % bn == 0 (ops.syrk pads).
    Returns (T(T+1)/2 * bn, bn) with T = N // bn.
    """
    m, n = a.shape
    assert m % bk == 0 and n % bn == 0, (a.shape, bk, bn)
    t_blocks = n // bn
    n_tri = t_blocks * (t_blocks + 1) // 2
    n_k = m // bk
    out_dtype = out_dtype or a.dtype

    def ai_map(t, k):
        i, _ = _tri_decode(t)
        return (k, i)

    def aj_map(t, k):
        _, j = _tri_decode(t)
        return (k, j)

    return pl.pallas_call(
        functools.partial(_syrk_kernel, n_k=n_k),
        grid=(n_tri, n_k),
        in_specs=[
            pl.BlockSpec((bk, bn), ai_map),
            pl.BlockSpec((bk, bn), aj_map),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda t, k: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tri * bn, bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        # output tiles (t) are independent -> megacore can partition them;
        # the K sweep carries the VMEM accumulator and stays sequential.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, a)
