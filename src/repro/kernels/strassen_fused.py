"""Fused Pallas executor for flattened ATA / Strassen schedules.

This is the single-kernel replacement for the materialize-everything
recursion (DESIGN.md §4): a ``pallas_call`` whose grid enumerates
``(output tile, contribution slot, K block)`` over the leaf-task plans from
``repro.core.schedule``.  Per grid step the kernel

  1. gathers up to ``max_terms`` (bk, bn) tiles of the *original* padded A
     straight from HBM (scalar-prefetched index tables drive the BlockSpec
     index maps — the per-level ``pad``/``concatenate`` copies of the
     reference recursion become index arithmetic),
  2. forms the +-1-signed Strassen operand sums tile-wise in VMEM (the
     ``S``/``T`` operand temporaries never exist in HBM),
  3. runs the leaf product on the MXU into an fp32 VMEM accumulator that
     lives across the whole (contribution, K) sweep of one output tile,
  4. writes each output tile to HBM exactly once, directly into the packed
     lower-triangular block stack of ``kernels/syrk.py`` — no ``M_i``
     product, no operand sum and no upper-triangular block ever touches
     HBM.

Contributions are sorted by destination (``schedule.Plan.contributions``),
so the accumulator hand-off needs no HBM read-modify-write and the TPU
grid's sequential execution guarantees a single store per tile.

Everything here is forward-only (no custom VJP yet); ``repro.core.ata``
keeps the reference recursion for autodiff and as a numerical oracle.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.ata import ata_levels_for
from ..core.schedule import plan_ata, plan_matmul
from ..core.strassen import strassen_levels_for
from ..core.symmetry import unpack_tril_blocks
from .ops import _auto_interpret
from .syrk import _tri_decode

__all__ = ["fused_ata", "fused_ata_packed", "fused_matmul",
           "ata_traffic_model"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# VMEM guard: the kernel gathers 2 * max_terms input tiles per grid step
# (double-buffered by the pipeline).  Each Strassen level doubles the
# operand fan-in (Winograd can quadruple it), so deep plans are clamped to
# keep the working set well under per-core VMEM: 2*8 tiles of 256x256 fp32
# = 4 MB single-buffered.
MAX_OPERAND_TERMS = 8


def _ata_geometry(m: int, n: int, levels: int, variant: str,
                  bk: int, bn: int):
    """Shared executor/traffic-model geometry (single source of truth).

    Clamps ``levels`` so (a) every leaf block holds at least one (bk, bn)
    tile of real data and (b) the operand fan-in fits VMEM, then derives
    leaf/padded shapes and grid extents.
    """
    levels = min(levels, ata_levels_for(m, n, max(bk, bn)))
    while levels > 0 and plan_ata(levels, variant).max_terms > \
            MAX_OPERAND_TERMS:
        levels -= 1
    plan = plan_ata(levels, variant)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bk) // B     # leaf rows (bk multiple)
    nb = _round_up(max(n, 1), B * bn) // B     # leaf cols (bn multiple)
    M, N = B * mb, B * nb
    t_blocks = N // bn
    return {
        "plan": plan, "levels": levels, "mb": mb, "nb": nb, "M": M, "N": N,
        "n_k": mb // bk, "nbt": nb // bn,
        "n_tri": t_blocks * (t_blocks + 1) // 2,
    }


# ---------------------------------------------------------------------------
# Scalar-prefetch tables: the plan lowered to int32 arrays indexed by
# (leaf destination, contribution slot[, term slot]).  Empty slots carry
# sign 0 (the kernel skips them) and index block (0, 0) (a harmless fetch).
# ---------------------------------------------------------------------------

def _lower_tables(plan, n_dest: int, dest_index):
    n_c, tmax = plan.max_contributions, plan.max_terms
    sign = np.zeros((n_dest, n_c), np.int32)
    lrow = np.zeros((n_dest, n_c, tmax), np.int32)
    lcol = np.zeros_like(lrow)
    lsgn = np.zeros_like(lrow)
    rrow = np.zeros_like(lrow)
    rcol = np.zeros_like(lrow)
    rsgn = np.zeros_like(lrow)
    for (di, dj), contribs in plan.by_dest().items():
        ld = dest_index(di, dj)
        for s, contrib in enumerate(contribs):
            sign[ld, s] = contrib.sign
            for p, (r, c, sg) in enumerate(contrib.left):
                lrow[ld, s, p], lcol[ld, s, p], lsgn[ld, s, p] = r, c, sg
            for q, (r, c, sg) in enumerate(contrib.right):
                rrow[ld, s, q], rcol[ld, s, q], rsgn[ld, s, q] = r, c, sg
    return sign, lrow, lcol, lsgn, rrow, rcol, rsgn


@functools.lru_cache(maxsize=None)
def _ata_tables(levels: int, variant: str):
    plan = plan_ata(levels, variant)
    n_dest = plan.blocks * (plan.blocks + 1) // 2
    return _lower_tables(plan, n_dest, lambda di, dj: di * (di + 1) // 2 + dj)


@functools.lru_cache(maxsize=None)
def _matmul_tables(levels: int, variant: str):
    plan = plan_matmul(levels, variant)
    b = plan.blocks
    return _lower_tables(plan, b * b, lambda di, dj: di * b + dj)


def _signed_sum(refs, sgn_ref, ld, c):
    """Sum[p] sgn[p] * refs[p], formed in fp32 in VMEM (never in HBM)."""
    acc = None
    for p, ref in enumerate(refs):
        term = ref[...].astype(jnp.float32) * sgn_ref[ld, c, p].astype(
            jnp.float32)
        acc = term if acc is None else acc + term
    return acc


# ---------------------------------------------------------------------------
# Fused ATA: C = tril(A^t A) into the packed triangular block stack.
# ---------------------------------------------------------------------------

def _fused_ata_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                      rrow_ref, rcol_ref, rsgn_ref, *refs,
                      tmax: int, nbt: int, n_c: int, n_k: int):
    a_refs = refs[:2 * tmax]
    o_ref, acc_ref = refs[2 * tmax], refs[2 * tmax + 1]
    t, c, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    gi, gj = _tri_decode(t)
    di = gi // nbt
    ld = di * (di + 1) // 2 + gj // nbt
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        left = _signed_sum(a_refs[:tmax], lsgn_ref, ld, c)
        right = _signed_sum(a_refs[tmax:], rsgn_ref, ld, c)
        acc_ref[...] += sgn.astype(jnp.float32) * jnp.dot(
            left.T, right, preferred_element_type=jnp.float32)

    @pl.when((c == n_c - 1) & (k == n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_ata_packed(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
):
    """Packed lower-triangular block stack of ``tril(a.T @ a)`` via the
    fused schedule executor.

    ``a`` is zero-padded so each of the ``2^levels`` leaf blocks is a
    (bk, bn)-tile multiple (exact: zero rows add nothing to A^tA, zero
    columns are sliced away by the dense wrapper).

    Returns ``(packed, n_padded)`` with packed of shape
    ``(T(T+1)/2 * bn, bn)``, ``T = n_padded // bn``, in the ordering of
    ``symmetry.pack_tril_blocks`` / ``kernels.syrk``.

    ``levels`` is a cap: the unroll depth is clamped (``_ata_geometry``)
    so every leaf block holds at least one (bk, bn) tile of real data —
    a (128, 128) input with 256-tiles runs as a single SYRK leaf rather
    than padding each empty leaf level 2x per dimension — and so the
    operand fan-in fits VMEM (``MAX_OPERAND_TERMS``).
    """
    interpret = _auto_interpret(interpret)
    m, n = a.shape
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    plan, levels = geo["plan"], geo["levels"]
    M, N = geo["M"], geo["N"]
    if (M, N) != (m, n):
        a = jnp.pad(a, ((0, M - m), (0, N - n)))
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))

    n_k, nbt, n_tri = geo["n_k"], geo["nbt"], geo["n_tri"]
    tmax, n_c = plan.max_terms, plan.max_contributions
    tables = _ata_tables(levels, variant)

    def _dest(t):
        gi, gj = _tri_decode(t)
        di = gi // nbt
        return gi, gj, di * (di + 1) // 2 + gj // nbt

    def left_map(p):
        def index_map(t, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            gi, _, ld = _dest(t)
            return (lrow[ld, c, p] * n_k + k, lcol[ld, c, p] * nbt + gi % nbt)
        return index_map

    def right_map(q):
        def index_map(t, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            _, gj, ld = _dest(t)
            return (rrow[ld, c, q] * n_k + k, rcol[ld, c, q] * nbt + gj % nbt)
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(n_tri, n_c, n_k),
        in_specs=[pl.BlockSpec((bk, bn), left_map(p)) for p in range(tmax)]
        + [pl.BlockSpec((bk, bn), right_map(q)) for q in range(tmax)],
        out_specs=pl.BlockSpec((bn, bn), lambda t, c, k, *_: (t, 0)),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
    )
    kernel = functools.partial(_fused_ata_kernel, tmax=tmax, nbt=nbt,
                               n_c=n_c, n_k=n_k)
    packed = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tri * bn, bn), out_dtype),
        interpret=interpret,
    )(*tables, *([a] * (2 * tmax)))
    return packed, N


def fused_ata(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """Dense ``tril(a.T @ a)`` at the original size via the fused pipeline.

    Differentiable: carries a custom VJP (``dA = A (S + S^t)`` with
    ``S = tril(cotangent)``), so ``mode="auto"`` -> fused on TPU keeps
    ``jax.grad`` working.  The packed entry point stays forward-only.
    """
    interpret = _auto_interpret(interpret)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    return _fused_ata_dense(a, levels, variant, bk, bn, out_dtype, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _fused_ata_dense(a, levels, variant, bk, bn, out_dtype, interpret):
    n = a.shape[1]
    packed, n_pad = fused_ata_packed(
        a, levels=levels, variant=variant, bk=bk, bn=bn,
        out_dtype=out_dtype, interpret=interpret)
    dense = unpack_tril_blocks(packed, n_pad, bn, symmetrize=False)
    # diagonal blocks are computed full — drop their upper halves
    return jnp.tril(dense)[:n, :n]


def _fused_ata_dense_fwd(a, levels, variant, bk, bn, out_dtype, interpret):
    return (_fused_ata_dense(a, levels, variant, bk, bn, out_dtype,
                             interpret), a)


def _fused_ata_dense_bwd(levels, variant, bk, bn, out_dtype, interpret,
                         a, g):
    # C = tril(A^t A) => dL/dA = A (S + S^t), S = tril(dL/dC); the factor
    # 2 on the diagonal of S + S^t is exactly the quadratic term's.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    s = jnp.tril(g).astype(acc)
    da = jnp.dot(a.astype(acc), s + s.T, preferred_element_type=acc)
    return (da.astype(a.dtype),)


_fused_ata_dense.defvjp(_fused_ata_dense_fwd, _fused_ata_dense_bwd)


# ---------------------------------------------------------------------------
# Analytic HBM traffic model for the fused ATA kernel.
#
# In interpret mode (CPU) the Pallas pipeline is *emulated* with XLA loops
# whose HLO carries full-array state buffers, so an HLO census of the
# interpret lowering measures the emulation, not the kernel.  On hardware
# the kernel's HBM behaviour is exact and simple by construction — grid
# DMA reads of A tiles, one write per packed output tile, and NO other
# HBM buffer (operand sums, M_i products and recombination temporaries
# live only in VMEM) — so we model it in closed form, the same way
# bench_roofline treats Pallas flash-attention FLOPs analytically.
# ---------------------------------------------------------------------------

def ata_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    bk: int = 256, bn: int = 256, in_bytes: int = 4, out_bytes: int = 4,
) -> dict:
    """HBM bytes of ``fused_ata_packed`` on an (m, n) input.

    Returns reads (streamed A-tile fetches, incl. padded null slots —
    the contribution axis is padded to ``max_contributions``, so the
    read term honestly reflects that amplification), writes (each packed
    output tile exactly once) and ``intermediate_bytes`` —
    HBM-materialized temporaries, which is just the zero-pad copy of A
    when the shape is not tile-aligned, and 0 otherwise.  Uses the same
    ``_ata_geometry`` as the executor, so the model cannot drift from
    the kernel's clamping/padding.
    """
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    plan, n_tri, n_k = geo["plan"], geo["n_tri"], geo["n_k"]
    M, N = geo["M"], geo["N"]
    grid = n_tri * plan.max_contributions * n_k
    reads = grid * 2 * plan.max_terms * bk * bn * in_bytes
    writes = n_tri * bn * bn * out_bytes
    pad_copy = M * N * in_bytes if (M, N) != (m, n) else 0
    return {
        "grid_steps": grid,
        "read_bytes": reads,
        "write_bytes": writes,
        "intermediate_bytes": pad_copy,
        "padded_shape": (M, N),
    }


# ---------------------------------------------------------------------------
# Fused Strassen matmul: C = A @ B, dense output.
# ---------------------------------------------------------------------------

def _fused_matmul_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                         rrow_ref, rcol_ref, rsgn_ref, *refs,
                         tmax: int, nbm: int, nbn: int, n_c: int, n_k: int,
                         blocks: int):
    a_refs = refs[:tmax]
    b_refs = refs[tmax:2 * tmax]
    o_ref, acc_ref = refs[2 * tmax], refs[2 * tmax + 1]
    i, j = pl.program_id(0), pl.program_id(1)
    c, k = pl.program_id(2), pl.program_id(3)
    ld = (i // nbm) * blocks + (j // nbn)
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        left = _signed_sum(a_refs, lsgn_ref, ld, c)
        right = _signed_sum(b_refs, rsgn_ref, ld, c)
        acc_ref[...] += sgn.astype(jnp.float32) * jnp.dot(
            left, right, preferred_element_type=jnp.float32)

    @pl.when((c == n_c - 1) & (k == n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """``a @ b`` via the flattened Strassen schedule, one fused kernel.

    Same fusion contract as :func:`fused_ata_packed`: operand sums live in
    VMEM only, every output tile is written once, no ``M_i`` in HBM; the
    same level/fan-in clamps keep leaves at tile granularity and the
    operand gather inside VMEM.  Differentiable via the standard matmul
    VJP.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes for matmul: {a.shape} x {b.shape}")
    interpret = _auto_interpret(interpret)
    out_dtype = (jnp.promote_types(jnp.promote_types(a.dtype, b.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    return _fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                              interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8))
def _fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                       interpret):
    m, k_dim = a.shape
    _, n = b.shape
    levels = min(levels, strassen_levels_for(m, k_dim, n, max(bm, bk, bn)))
    while levels > 0 and plan_matmul(levels, variant).max_terms > \
            MAX_OPERAND_TERMS:
        levels -= 1
    plan = plan_matmul(levels, variant)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bm) // B
    kb = _round_up(max(k_dim, 1), B * bk) // B
    nb = _round_up(max(n, 1), B * bn) // B
    M, K, N = B * mb, B * kb, B * nb
    if (M, K) != (m, k_dim):
        a = jnp.pad(a, ((0, M - m), (0, K - k_dim)))
    if (K, N) != (k_dim, n):
        b = jnp.pad(b, ((0, K - k_dim), (0, N - n)))

    n_k = kb // bk
    nbm, nbn = mb // bm, nb // bn
    tmax, n_c = plan.max_terms, plan.max_contributions
    tables = _matmul_tables(levels, variant)

    def left_map(p):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            ld = (i // nbm) * B + j // nbn
            return (lrow[ld, c, p] * nbm + i % nbm, lcol[ld, c, p] * n_k + k)
        return index_map

    def right_map(q):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            ld = (i // nbm) * B + j // nbn
            return (rrow[ld, c, q] * n_k + k, rcol[ld, c, q] * nbn + j % nbn)
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(M // bm, N // bn, n_c, n_k),
        in_specs=[pl.BlockSpec((bm, bk), left_map(p)) for p in range(tmax)]
        + [pl.BlockSpec((bk, bn), right_map(q)) for q in range(tmax)],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, c, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_fused_matmul_kernel, tmax=tmax, nbm=nbm,
                               nbn=nbn, n_c=n_c, n_k=n_k, blocks=B)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(*tables, *([a] * tmax), *([b] * tmax))
    return out[:m, :n]


def _fused_matmul_fwd(a, b, levels, variant, bm, bk, bn, out_dtype,
                      interpret):
    return (_fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                               interpret), (a, b))


def _fused_matmul_bwd(levels, variant, bm, bk, bn, out_dtype, interpret,
                      res, g):
    a, b = res
    acc = jnp.promote_types(jnp.promote_types(a.dtype, b.dtype), jnp.float32)
    gf = g.astype(acc)
    da = jnp.dot(gf, b.T.astype(acc), preferred_element_type=acc)
    db = jnp.dot(a.T.astype(acc), gf, preferred_element_type=acc)
    return da.astype(a.dtype), db.astype(b.dtype)


_fused_matmul_core.defvjp(_fused_matmul_fwd, _fused_matmul_bwd)
