"""The generic Pallas executor for compiled leaf programs.

One kernel, one ``pallas_call`` site, every fused variant.  PRs 1-4 grew
three hand-specialized executors (forward ATA, symm backward, trans_a /
trans_b matmul) that differed only in grid decode, index-map axis roles
and which side transposes in VMEM.  This module rewrites them as a
single executor driven by the :mod:`repro.core.leaf_ir` IR: a
``LeafProgram`` (kind x levels x algebra table) is bound to tile sizes
(:class:`_Spec`), lowered to int32 scalar-prefetch tables, and executed
by ONE scalar-prefetch ``pallas_call`` whose grid enumerates
``(output tile, contribution slot, K block)``.  Per grid step the kernel

  1. gathers up to ``max_terms`` stored tiles per side straight from HBM
     (the prefetched tables drive the BlockSpec index maps — pad /
     concatenate / transpose copies of the reference recursions become
     index arithmetic),
  2. forms the +-1-signed operand sums tile-wise in VMEM, applying the
     per-term tri-mirror transposes (packed symm operand) and the
     whole-side transposes (ATA's left, AAT's right, trans_a/trans_b),
  3. runs the leaf product on the MXU into an fp32 VMEM accumulator that
     lives across the whole (contribution, K) sweep of one output tile
     — seeded from the incoming packed stack for accumulating (rank-k)
     programs instead of zero,
  4. writes each output tile to HBM exactly once — packed
     lower-triangular stack for gram kinds, dense grid otherwise.

Because the planner/executor split is IR-shaped, the two programs the
old stacks could not express fall out of the same machinery:

* ``aat`` — C = tril(A A^t), the Arrigoni-Massini 2021 row-gram
  recursion (:func:`fused_aat` / :func:`fused_aat_packed`, surfaced as
  ``ata(x, gram_of="rows")``): the transpose of A never exists in HBM.
* ``rank_k`` — C += A^t A (:func:`fused_rank_k_update`): the running
  packed stack seeds the accumulator, so streamed Gram chunks
  (``gram/stream.py``) stop re-materializing a per-chunk delta.

Autodiff (DESIGN.md §11) is unchanged in spirit: custom VJPs route every
backward through the same executor (symm schedule for the gram kinds,
transpose-folded matmul programs for matmul), with ``bwd="dense"``
keeping the dense-dot baselines selectable for benchmarking.

The analytic HBM traffic model is likewise IR-driven: :func:`_traffic`
scores a bound :class:`_Spec` (reads = grid DMA tile fetches including
the padded contribution slots, writes = one store per output tile), and
the per-kind models (``ata_traffic_model`` etc.) are thin geometry
wrappers over it — the model shares the executor's binding code, so it
cannot drift from the kernel's clamping/padding.
"""
from __future__ import annotations

import functools
import math
import warnings
from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import leaf_ir
from ..core.ata import ata_levels_for
from ..core.leaf_ir import LeafProgram, compile_program
from ..core.symmetry import unpack_tril_blocks
from .ops import _auto_interpret
from .syrk import _tri_decode

__all__ = ["fused_ata", "fused_ata_packed", "fused_aat", "fused_aat_packed",
           "fused_matmul", "fused_symm_matmul", "fused_rank_k_update",
           "ata_traffic_model", "aat_traffic_model", "ata_bwd_traffic_model",
           "rank_k_traffic_model", "stochastic_round_bf16"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# VMEM guard: the kernel gathers 2 * max_terms input tiles per grid step
# (double-buffered by the pipeline).  Each Strassen level doubles the
# operand fan-in (Winograd can quadruple it), so deep programs are clamped
# to keep the working set well under per-core VMEM: 2*8 tiles of 256x256
# fp32 = 4 MB single-buffered.
MAX_OPERAND_TERMS = 8

# Revolving-buffer depth cap: each extra slot holds another 2*max_terms
# operand tiles in VMEM, so depth 4 at 256x256 fp32 tiles is already
# 16 MB of ring — past the point of diminishing overlap returns.
MAX_PIPELINE_DEPTH = 4

# Operand-tile storage dtypes the executor will quantize to.  fp8 tiles
# halve (vs bf16) / quarter (vs fp32) the DMA traffic per term while the
# accumulation stays in the fp32 VMEM scratch — the serving-grade Gram
# trade (DESIGN.md §16).
_SUPPORTED_OPERAND_DTYPES = ("float8_e4m3fn", "float8_e5m2", "bfloat16",
                             "float16", "float32", "float64")

# (kind, variant, requested, clamped) combinations already warned about —
# the clamp silently changing the schedule depth bit users before, so it
# warns exactly once per distinct clamp.
_CLAMP_WARNED: set = set()


def _canon_dtype(dt):
    """Optional dtype-like -> canonical name string (or None): the
    hashable form threaded through custom-VJP nondiff argnums."""
    return None if dt is None else jnp.dtype(dt).name


def _resolve_operand_dtype(operand_dtype):
    name = _canon_dtype(operand_dtype)
    if name is not None and name not in _SUPPORTED_OPERAND_DTYPES:
        raise ValueError(
            f"operand_dtype={name!r} is not a supported operand-tile "
            f"storage dtype; pick one of {_SUPPORTED_OPERAND_DTYPES}")
    return name


def _resolve_acc_dtype(acc_dtype):
    name = "float32" if acc_dtype is None else jnp.dtype(acc_dtype).name
    if name not in ("float32", "bfloat16", "float64"):
        raise ValueError(f"acc_dtype={name!r}: the VMEM accumulator must "
                         "be float32 (default), bfloat16 or float64")
    return name


def _resolve_pipeline_depth(pipeline_depth, interpret) -> int:
    """Resolve the ``pipeline_depth`` knob.

    ``None`` picks the backend default: 2 (double buffering — prefetch
    the next contribution's operand tiles while the current MXU work
    runs) for compiled kernels, 1 in interpret mode, where the emulator
    runs DMAs synchronously and revolving buffers only add bookkeeping.
    Explicit values are always honored (parity tests force 2/3 under
    interpret).
    """
    if pipeline_depth is None:
        return 1 if interpret else 2
    depth = int(pipeline_depth)
    if not 1 <= depth <= MAX_PIPELINE_DEPTH:
        raise ValueError(
            f"pipeline_depth must be in [1, {MAX_PIPELINE_DEPTH}], got "
            f"{pipeline_depth} (each slot rings 2*{MAX_OPERAND_TERMS} "
            "operand tiles in VMEM)")
    return depth


def _resolve_sr_seed(sr_seed, out_dtype):
    """Validate the stochastic-rounding knob: SR only targets bf16
    outputs (the fp32 accumulator is rounded once, on store)."""
    if sr_seed is None:
        return None
    if jnp.dtype(out_dtype) != jnp.bfloat16:
        raise ValueError(
            "sr_seed (stochastic rounding) requires out_dtype=bfloat16, "
            f"got {jnp.dtype(out_dtype).name}")
    return int(sr_seed)


def _warn_fan_in_clamp(kind: str, variant: str, gram: str, requested: int,
                       clamped: int) -> None:
    key = (kind, variant, gram, requested, clamped)
    if key in _CLAMP_WARNED:
        return
    _CLAMP_WARNED.add(key)
    warnings.warn(
        f"fused {kind} schedule: levels={requested} (variant={variant!r}, "
        f"gram={gram!r}) exceeds the MAX_OPERAND_TERMS={MAX_OPERAND_TERMS} "
        f"VMEM operand fan-in; clamped to levels={clamped}",
        stacklevel=3)


def _fan_in_clamp(kind: str, levels: int, variant: str,
                  gram: str = "strassen") -> int:
    """Clamp ``levels`` until the program's operand fan-in fits VMEM,
    warning once per distinct clamp (the shape-driven clamp above this is
    expected behaviour and stays silent).  ``rank_k`` shares the ``ata``
    program, ``symm`` warns under its own name as before."""
    prog_kind = "ata" if kind == "rank_k" else kind
    g = gram if prog_kind in ("ata", "aat") else "strassen"
    requested = levels
    while levels > 0 and compile_program(prog_kind, levels, variant,
                                         gram=g).max_terms \
            > MAX_OPERAND_TERMS:
        levels -= 1
    if levels < requested:
        _warn_fan_in_clamp(kind, variant, g, requested, levels)
    return levels


# ---------------------------------------------------------------------------
# Stochastic rounding: fp32 -> bf16 with probability proportional to the
# truncated fraction, so E[SR(x)] == x exactly.  Applied as a post-pass on
# the executor's fp32 output (one threefry draw per call, deterministic
# under a fixed seed); gradients pass straight through.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _sr_apply(xf, bits):
    u = jax.lax.bitcast_convert_type(xf, jnp.uint32)
    # adding uniform 16-bit noise below the bf16 mantissa boundary and
    # truncating rounds up with probability (low 16 bits) / 2^16 — the
    # unbiased rounding; carries ripple into the exponent exactly when
    # the mantissa overflows (that IS the round-up to the next binade)
    rounded = ((u + bits.astype(jnp.uint32)) >> 16).astype(jnp.uint16)
    sr = jax.lax.bitcast_convert_type(rounded, jnp.bfloat16)
    # non-finite values: the noise could walk a NaN payload or push a
    # large-magnitude carry across the inf boundary — pass them through
    # round-to-nearest instead
    return jnp.where(jnp.isfinite(xf), sr, xf.astype(jnp.bfloat16))


def _sr_fwd(xf, bits):
    return _sr_apply(xf, bits), None


def _sr_bwd(_, g):
    # straight-through: rounding is an unbiased identity in expectation
    return g.astype(jnp.float32), None


_sr_apply.defvjp(_sr_fwd, _sr_bwd)


def stochastic_round_bf16(x: jax.Array, key) -> jax.Array:
    """Stochastically round ``x`` to bfloat16 (unbiased, deterministic
    per threefry ``key``); non-finite entries round to nearest.  The
    executor applies this on its fp32 output when ``sr_seed`` is set."""
    xf = x.astype(jnp.float32)
    bits = jax.random.bits(key, xf.shape, jnp.uint16)
    return _sr_apply(xf, bits)


# ---------------------------------------------------------------------------
# Geometry: bind a program kind to concrete shapes/tiles (single source of
# truth shared by the executor and the traffic models).
# ---------------------------------------------------------------------------

def _ata_geometry(m: int, n: int, levels: int, variant: str,
                  bk: int, bn: int, kind: str = "ata",
                  gram: str = "strassen"):
    """Executor/traffic-model geometry for the column-gram kinds.

    Clamps ``levels`` so (a) every leaf block holds at least one (bk, bn)
    tile of real data and (b) the operand fan-in fits VMEM (warned once),
    then derives leaf/padded shapes and grid extents.
    """
    levels = min(levels, ata_levels_for(m, n, max(bk, bn)))
    levels = _fan_in_clamp(kind, levels, variant, gram)
    plan = compile_program("rank_k" if kind == "rank_k" else "ata",
                           levels, variant, gram=gram)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bk) // B     # leaf rows (bk multiple)
    nb = _round_up(max(n, 1), B * bn) // B     # leaf cols (bn multiple)
    M, N = B * mb, B * nb
    t_blocks = N // bn
    return {
        "plan": plan, "levels": levels, "mb": mb, "nb": nb, "M": M, "N": N,
        "n_k": mb // bk, "nbt": nb // bn,
        "n_tri": t_blocks * (t_blocks + 1) // 2,
    }


def _aat_geometry(m: int, n: int, levels: int, variant: str,
                  bm: int, bk: int, gram: str = "strassen"):
    """Geometry for the row-gram (A A^t) kind — the column-gram geometry
    with the roles of the two grids swapped: output tiles tile the *row*
    dimension, the contraction sweeps the columns."""
    levels = min(levels, ata_levels_for(m, n, max(bm, bk)))
    levels = _fan_in_clamp("aat", levels, variant, gram)
    plan = compile_program("aat", levels, variant, gram=gram)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bm) // B     # leaf rows (bm multiple)
    nb = _round_up(max(n, 1), B * bk) // B     # leaf cols (bk multiple)
    M, N = B * mb, B * nb
    t_blocks = M // bm
    return {
        "plan": plan, "levels": levels, "mb": mb, "nb": nb, "M": M, "N": N,
        "n_k": nb // bk, "nbt": mb // bm,
        "n_tri": t_blocks * (t_blocks + 1) // 2,
    }


def _symm_geometry(m: int, T: int, levels: int, variant: str, bm: int):
    """Level clamp + padded-row geometry for the symm executor (shared
    with ``ata_bwd_traffic_model``).  ``T`` is the packed stack's tile
    count per side; the column side cannot be padded (the stack layout is
    fixed), so levels clamp to divisors of T.  Rectangular variants pad
    rows to their own ``blocks_m`` grid while T divides ``blocks_n``."""
    dn = leaf_ir.algebra_dims(variant)[2]
    while levels > 0 and T % (dn ** levels):
        levels -= 1
    levels = _fan_in_clamp("symm", levels, variant)
    plan = compile_program("symm", levels, variant)
    bm_blocks = plan.blocks_m
    mb = _round_up(max(m, 1), bm_blocks * bm) // bm_blocks
    return {"plan": plan, "levels": levels, "M": bm_blocks * mb,
            "nbm": mb // bm, "q": T // plan.blocks_n}


def _rank_k_geometry(m: int, T: int, levels: int, variant: str, bk: int,
                     gram: str = "strassen"):
    """Geometry for C += A^t A against an existing packed (T-tile) stack:
    the ata geometry with the column side pinned to the stack layout, so
    levels clamp to divisors of T (like symm)."""
    while levels > 0 and T % (1 << levels):
        levels -= 1
    levels = min(levels, ata_levels_for(m, T, 1))   # never exceed the grid
    levels = _fan_in_clamp("rank_k", levels, variant, gram)
    plan = compile_program("rank_k", levels, variant, gram=gram)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bk) // B
    return {"plan": plan, "levels": levels, "M": B * mb, "mb": mb,
            "n_k": mb // bk, "nbt": T // B,
            "n_tri": T * (T + 1) // 2}


# ---------------------------------------------------------------------------
# Binding: a program + concrete tiles/grid, as a static (hashable) spec.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Spec:
    """Static binding of a LeafProgram to tiles and a flattened grid.

    Grid is uniformly ``(n_out, n_c, n_k)``: output tiles (tri-decoded
    for packed outputs, row-major ``divmod(t, n_tj)`` for dense), the
    padded contribution sweep, and the K sweep.  ``q_i``/``q_j`` are
    output tiles per leaf block along each output dim; ``bi``/``bj`` the
    output tile edges; ``bc`` the contraction tile edge.
    """
    kind: str
    levels: int
    variant: str
    gram: str                   # gram-algebra entry (gram kinds)
    trans_a: bool               # matmul-only operand-spec transposes
    trans_b: bool
    tmax: int
    n_c: int
    n_k: int
    n_out: int
    n_tj: int                   # dense outputs: tiles along j (0 for tri)
    q_i: int
    q_j: int
    blocks_j: int               # dense outputs: leaf blocks along j
    bi: int
    bj: int
    bc: int
    out_tri: bool
    left_trans: bool
    right_trans: bool
    right_tri: bool
    diag_sym: bool
    accumulate: bool
    pipeline_depth: int = 1     # revolving VMEM buffer slots (1 = grid walk)
    acc_dtype: str = "float32"  # VMEM accumulator storage dtype (name)

    @property
    def grid_steps(self) -> int:
        return self.n_out * self.n_c * self.n_k


def _bind(prog: LeafProgram, *, n_out, n_tj, q_i, q_j, n_k, bi, bj, bc,
          diag_sym=False, pipeline_depth=1,
          acc_dtype="float32") -> _Spec:
    ls, rs, os_ = prog.left_spec, prog.right_spec, prog.out_spec
    return _Spec(
        kind=prog.kind, levels=prog.levels, variant=prog.variant,
        gram=prog.gram,
        trans_a=ls.transpose if prog.kind == "matmul" else False,
        trans_b=rs.transpose if prog.kind == "matmul" else False,
        tmax=prog.max_terms, n_c=prog.max_contributions, n_k=n_k,
        n_out=n_out, n_tj=n_tj, q_i=q_i, q_j=q_j,
        blocks_j=prog.out_blocks[1],
        bi=bi, bj=bj, bc=bc,
        out_tri=os_.packing == "tri",
        left_trans=ls.transpose, right_trans=rs.transpose,
        right_tri=rs.layout == "tri",
        diag_sym=diag_sym, accumulate=os_.accumulate,
        pipeline_depth=pipeline_depth, acc_dtype=acc_dtype)


# ---------------------------------------------------------------------------
# Scalar-prefetch tables: the program lowered to arrays indexed by
# (leaf destination, contribution slot[, term slot]) — int32 index
# tables, float32 coefficient tables (rational gram-algebra coefficients
# like dps's +-1/2, +-1/4 must survive lowering).  Empty slots carry
# coefficient 0 (the kernel skips them) and index block (0, 0) (a
# harmless fetch).  Uniform across kinds: coeff + (row, col, coeff) per
# side + the right-side trans table (per-term mirrors only ever occur on
# tri-stored right operands; left per-term trans is asserted unused at
# lowering — the left side's transposes are whole-operand OperandSpec
# flags, and transposed gram destinations were normalized into
# side-swapped contributions at the IR layer).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _program_tables(kind: str, levels: int, variant: str,
                    gram: str = "strassen",
                    trans_a: bool = False, trans_b: bool = False):
    prog = compile_program(kind, levels, variant, gram=gram,
                           trans_a=trans_a, trans_b=trans_b)
    n_dest, n_c, tmax = prog.n_dests(), prog.max_contributions, \
        prog.max_terms
    sign = np.zeros((n_dest, n_c), np.float32)
    lrow = np.zeros((n_dest, n_c, tmax), np.int32)
    lcol = np.zeros_like(lrow)
    lsgn = np.zeros((n_dest, n_c, tmax), np.float32)
    rrow = np.zeros_like(lrow)
    rcol = np.zeros_like(lrow)
    rsgn = np.zeros_like(lsgn)
    rtrn = np.zeros_like(lrow)
    for (di, dj), contribs in prog.by_dest().items():
        ld = prog.dest_index(di, dj)
        for s, contrib in enumerate(contribs):
            sign[ld, s] = contrib.sign
            for p, (r, c, sg, tr) in enumerate(contrib.left):
                assert tr == 0, "per-term left transposes are not lowered"
                lrow[ld, s, p], lcol[ld, s, p], lsgn[ld, s, p] = r, c, sg
            for q, (r, c, sg, tr) in enumerate(contrib.right):
                rrow[ld, s, q], rcol[ld, s, q] = r, c
                rsgn[ld, s, q], rtrn[ld, s, q] = sg, tr
    return sign, lrow, lcol, lsgn, rrow, rcol, rsgn, rtrn


# a re-registered algebra table must invalidate the lowered tables too —
# compile_program.cache_clear() alone would leave these stale
leaf_ir.on_algebra_change(_program_tables.cache_clear)


# ---------------------------------------------------------------------------
# The ONE kernel + pallas_call site.
# ---------------------------------------------------------------------------

def _decode_out(t, spec: _Spec):
    """Flattened output-tile index -> (global tile i, global tile j)."""
    if spec.out_tri:
        return _tri_decode(t)
    return t // spec.n_tj, t % spec.n_tj


def _dest_ld(gi, gj, spec: _Spec):
    """Output tile coords -> leaf-destination table index."""
    di, dj = gi // spec.q_i, gj // spec.q_j
    if spec.out_tri:
        return di * (di + 1) // 2 + dj
    return di * spec.blocks_j + dj


def _tri_term_coords(rrow_ref, rcol_ref, rtrn_ref, ld, c, qt, spec, k, jq):
    """Conceptual global tile coords (gr, gc) of a tri-stored right term.

    Program-mirrored terms (rtrn == 1) store the transposed leaf, so
    their within-leaf offsets swap; diagonal leaves straddle the stored
    triangle at tile granularity, handled downstream by max/min +
    transpose."""
    t = rtrn_ref[ld, c, qt]
    gr = rrow_ref[ld, c, qt] * spec.q_j + jnp.where(t != 0, jq, k)
    gc = rcol_ref[ld, c, qt] * spec.q_j + jnp.where(t != 0, k, jq)
    return gr, gc


def _signed_sum(refs, sgn_ref, ld, c):
    """Sum[p] sgn[p] * refs[p], formed in fp32 in VMEM (never in HBM)."""
    acc = None
    for p, ref in enumerate(refs):
        term = ref[...].astype(jnp.float32) * sgn_ref[ld, c, p].astype(
            jnp.float32)
        acc = term if acc is None else acc + term
    return acc


def _leaf_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                 rrow_ref, rcol_ref, rsgn_ref, rtrn_ref, *refs,
                 spec: _Spec):
    tmax = spec.tmax
    l_refs = refs[:tmax]
    r_refs = refs[tmax:2 * tmax]
    cin_ref = refs[2 * tmax] if spec.accumulate else None
    o_ref, acc_ref = refs[-2], refs[-1]
    t, c, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    gi, gj = _decode_out(t, spec)
    ld = _dest_ld(gi, gj, spec)
    jq = gj % spec.q_j
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _init():
        if spec.accumulate:
            # rank-k: the running packed stack seeds the accumulator —
            # the incoming C is read once per tile, never re-materialized
            acc_ref[...] = cin_ref[...].astype(acc_ref.dtype)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        # whole-side transposes flip the gathered sum once —
        # (sum s_p X_p)^t = sum s_p X_p^t, one transpose per gather.
        left = _signed_sum(l_refs, lsgn_ref, ld, c)
        if spec.left_trans:
            left = left.T
        if spec.right_tri:
            right = None
            for qt, ref in enumerate(r_refs):
                gr, gc = _tri_term_coords(rrow_ref, rcol_ref, rtrn_ref,
                                          ld, c, qt, spec, k, jq)
                tile = ref[...].astype(jnp.float32)
                # the index map fetched the stored (max, min) tile;
                # transpose in VMEM whenever the conceptual read was
                # above the diagonal or the term itself was mirrored
                mirrored = (rtrn_ref[ld, c, qt] != 0) | (gr < gc)
                tile = jnp.where(mirrored, tile.T, tile)
                if spec.diag_sym:
                    # the S + S^t operand: diagonal tiles double
                    tile = jnp.where(gr == gc, tile + tile.T, tile)
                term = tile * rsgn_ref[ld, c, qt].astype(jnp.float32)
                right = term if right is None else right + term
        else:
            right = _signed_sum(r_refs, rsgn_ref, ld, c)
            if spec.right_trans:
                right = right.T
        contrib = sgn.astype(jnp.float32) * jnp.dot(
            left, right, preferred_element_type=jnp.float32)
        acc_ref[...] += contrib.astype(acc_ref.dtype)

    @pl.when((c == spec.n_c - 1) & (k == spec.n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pipelined_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                      rrow_ref, rcol_ref, rsgn_ref, rtrn_ref, *refs,
                      spec: _Spec, l_shape, r_shape):
    """Depth>=2 executor body: one grid step per output tile; the
    (contribution, K) sweep runs in-kernel behind a revolving-buffer
    manual-DMA pipeline (DESIGN.md §16).

    Slot protocol: step ``s`` computes out of slot ``s % depth`` while
    the copies for step ``s + depth - 1`` stream into slot
    ``(s + depth - 1) % depth`` — the slot whose compute retired at step
    ``s - 1`` (the sweep is sequential per tile), so a buffer is never
    overwritten while in use.  The flattened step order
    ``s = c * n_k + k`` reproduces the depth-1 grid walk (k fastest), so
    the accumulation order — and therefore the result — is bit-exact vs
    ``pipeline_depth=1``.  The epilogue contract is unchanged: the
    accumulator is (c_in-)seeded before the sweep and stored exactly
    once after it.
    """
    depth, tmax = spec.pipeline_depth, spec.tmax
    n_k = spec.n_k
    n_steps = spec.n_c * n_k
    left_hbm, right_hbm = refs[0], refs[1]
    cin_ref = refs[2] if spec.accumulate else None
    o_ref = refs[3] if spec.accumulate else refs[2]
    l_bufs, r_bufs, l_sems, r_sems, acc_ref = refs[-5:]

    t = pl.program_id(0)
    gi, gj = _decode_out(t, spec)
    ld = _dest_ld(gi, gj, spec)
    jq = gj % spec.q_j

    # the same index arithmetic as the depth-1 BlockSpec maps, evaluated
    # in-kernel on the scalar-prefetch tables (block indices -> element
    # offsets via the tile edges)
    def left_block(p, c, k):
        if spec.left_trans:
            return (lrow_ref[ld, c, p] * n_k + k,
                    lcol_ref[ld, c, p] * spec.q_i + gi % spec.q_i)
        return (lrow_ref[ld, c, p] * spec.q_i + gi % spec.q_i,
                lcol_ref[ld, c, p] * n_k + k)

    def right_block(q, c, k):
        if spec.right_tri:
            gr, gc = _tri_term_coords(rrow_ref, rcol_ref, rtrn_ref,
                                      ld, c, q, spec, k, jq)
            fr = jnp.maximum(gr, gc)
            fc = jnp.minimum(gr, gc)
            return (fr * (fr + 1) // 2 + fc, 0)
        if spec.right_trans:
            return (rrow_ref[ld, c, q] * spec.q_j + jq,
                    rcol_ref[ld, c, q] * n_k + k)
        return (rrow_ref[ld, c, q] * n_k + k,
                rcol_ref[ld, c, q] * spec.q_j + jq)

    def _copies(s):
        """The 2*tmax async tile copies of step ``s`` (start and wait
        must describe the identical transfers)."""
        slot = s % depth
        c, k = s // n_k, s % n_k
        cps = []
        for p in range(tmax):
            br, bc_ = left_block(p, c, k)
            cps.append(pltpu.make_async_copy(
                left_hbm.at[pl.ds(br * l_shape[0], l_shape[0]),
                            pl.ds(bc_ * l_shape[1], l_shape[1])],
                l_bufs.at[slot, p], l_sems.at[slot, p]))
        for q in range(tmax):
            br, bc_ = right_block(q, c, k)
            cps.append(pltpu.make_async_copy(
                right_hbm.at[pl.ds(br * r_shape[0], r_shape[0]),
                             pl.ds(bc_ * r_shape[1], r_shape[1])],
                r_bufs.at[slot, q], r_sems.at[slot, q]))
        return cps

    def _start(s):
        for cp in _copies(s):
            cp.start()

    def _wait(s):
        for cp in _copies(s):
            cp.wait()

    if spec.accumulate:
        acc_ref[...] = cin_ref[...].astype(acc_ref.dtype)
    else:
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for i in range(min(depth - 1, n_steps)):      # pipeline warm-up
        _start(i)

    def body(s, carry):
        slot = s % depth

        @pl.when(s + depth - 1 < n_steps)
        def _prefetch():
            _start(s + depth - 1)

        _wait(s)
        c, k = s // n_k, s % n_k
        sgn = sign_ref[ld, c]

        @pl.when(sgn != 0)
        def _accumulate():
            left = None
            for p in range(tmax):
                term = l_bufs[slot, p].astype(jnp.float32) \
                    * lsgn_ref[ld, c, p].astype(jnp.float32)
                left = term if left is None else left + term
            if spec.left_trans:
                left = left.T
            if spec.right_tri:
                right = None
                for qt in range(tmax):
                    gr, gc = _tri_term_coords(rrow_ref, rcol_ref, rtrn_ref,
                                              ld, c, qt, spec, k, jq)
                    tile = r_bufs[slot, qt].astype(jnp.float32)
                    mirrored = (rtrn_ref[ld, c, qt] != 0) | (gr < gc)
                    tile = jnp.where(mirrored, tile.T, tile)
                    if spec.diag_sym:
                        tile = jnp.where(gr == gc, tile + tile.T, tile)
                    term = tile * rsgn_ref[ld, c, qt].astype(jnp.float32)
                    right = term if right is None else right + term
            else:
                right = None
                for qt in range(tmax):
                    term = r_bufs[slot, qt].astype(jnp.float32) \
                        * rsgn_ref[ld, c, qt].astype(jnp.float32)
                    right = term if right is None else right + term
                if spec.right_trans:
                    right = right.T
            contrib = sgn.astype(jnp.float32) * jnp.dot(
                left, right, preferred_element_type=jnp.float32)
            acc_ref[...] += contrib.astype(acc_ref.dtype)

        return carry

    jax.lax.fori_loop(0, n_steps, body, 0)
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _operand_shapes(spec: _Spec):
    l_shape = (spec.bc, spec.bi) if spec.left_trans else (spec.bi, spec.bc)
    if spec.right_tri:
        r_shape = (spec.bj, spec.bj)
    elif spec.right_trans:
        r_shape = (spec.bj, spec.bc)
    else:
        r_shape = (spec.bc, spec.bj)
    return l_shape, r_shape


def _out_shape_struct(spec: _Spec, out_dtype):
    if spec.out_tri:
        return jax.ShapeDtypeStruct((spec.n_out * spec.bi, spec.bj),
                                    out_dtype)
    return jax.ShapeDtypeStruct(
        ((spec.n_out // spec.n_tj) * spec.bi, spec.n_tj * spec.bj),
        out_dtype)


def _execute_pipelined(spec: _Spec, tables, left, right, out_dtype,
                       interpret, c_in):
    """Depth>=2 ``pallas_call`` site: grid = output tiles only; the
    operands stay in HBM/ANY and the kernel streams their tiles through
    revolving VMEM buffers with manual async copies (DMA semaphores),
    overlapping the next step's fetch with the current MXU work."""
    n_tab = len(tables)
    depth, tmax = spec.pipeline_depth, spec.tmax
    l_shape, r_shape = _operand_shapes(spec)

    def out_map(t, *tabs):
        if spec.out_tri:
            return (t, 0)
        return (t // spec.n_tj, t % spec.n_tj)

    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [left, right]
    if spec.accumulate:
        in_specs.append(pl.BlockSpec((spec.bi, spec.bj), out_map))
        operands.append(c_in)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_tab,
        grid=(spec.n_out,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((spec.bi, spec.bj), out_map),
        scratch_shapes=[
            pltpu.VMEM((depth, tmax) + l_shape, left.dtype),
            pltpu.VMEM((depth, tmax) + r_shape, right.dtype),
            pltpu.SemaphoreType.DMA((depth, tmax)),
            pltpu.SemaphoreType.DMA((depth, tmax)),
            pltpu.VMEM((spec.bi, spec.bj), jnp.dtype(spec.acc_dtype)),
        ],
    )
    with jax.named_scope(
            f"fused:{spec.kind}:l{spec.levels}:{spec.variant}:{spec.gram}"
            f":pd{depth}"):
        return pl.pallas_call(
            functools.partial(_pipelined_kernel, spec=spec,
                              l_shape=l_shape, r_shape=r_shape),
            grid_spec=grid_spec,
            out_shape=_out_shape_struct(spec, out_dtype),
            # only the output-tile axis remains a grid axis and its
            # tiles are independent -> megacore partitions freely; the
            # sequential sweep lives inside the kernel body.
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(*tables, *operands)


def _execute(spec: _Spec, left: jax.Array, right: jax.Array,
             out_dtype, interpret, c_in: Optional[jax.Array] = None):
    """Run a bound program — the single ``pallas_call`` site.

    ``left``/``right`` are the padded operand arrays (the same array for
    the one-input gram kinds); ``c_in`` the incoming packed stack for
    accumulating programs.  Returns the raw output buffer: the packed
    tri stack for tri-packed programs, the dense (padded) grid otherwise.

    ``spec.pipeline_depth >= 2`` routes to the revolving-buffer DMA
    pipeline (one grid step per output tile, the (contribution, K) sweep
    in-kernel); depth 1 keeps the classic 3-axis grid walk.  Both paths
    accumulate in the same order, so they are bit-exact for a fixed
    ``acc_dtype``.
    """
    tables = _program_tables(spec.kind, spec.levels, spec.variant,
                             spec.gram, spec.trans_a, spec.trans_b)
    if spec.pipeline_depth > 1:
        return _execute_pipelined(spec, tables, left, right, out_dtype,
                                  interpret, c_in)
    n_tab = len(tables)

    def left_map(p):
        def index_map(t, c, k, *tabs):
            lrow, lcol = tabs[1], tabs[2]
            gi, gj = _decode_out(t, spec)
            ld = _dest_ld(gi, gj, spec)
            if spec.left_trans:
                # stored leaf is (contraction, out_i)
                return (lrow[ld, c, p] * spec.n_k + k,
                        lcol[ld, c, p] * spec.q_i + gi % spec.q_i)
            return (lrow[ld, c, p] * spec.q_i + gi % spec.q_i,
                    lcol[ld, c, p] * spec.n_k + k)
        return index_map

    def right_map(q):
        def index_map(t, c, k, *tabs):
            rrow, rcol, rtrn = tabs[4], tabs[5], tabs[7]
            gi, gj = _decode_out(t, spec)
            ld = _dest_ld(gi, gj, spec)
            if spec.right_tri:
                gr, gc = _tri_term_coords(rrow, rcol, rtrn, ld, c, q,
                                          spec, k, gj % spec.q_j)
                # the mirror, folded into the index map: always fetch
                # the stored lower-triangle tile
                fr = jnp.maximum(gr, gc)
                fc = jnp.minimum(gr, gc)
                return (fr * (fr + 1) // 2 + fc, 0)
            if spec.right_trans:
                # stored leaf is (out_j, contraction)
                return (rrow[ld, c, q] * spec.q_j + gj % spec.q_j,
                        rcol[ld, c, q] * spec.n_k + k)
            return (rrow[ld, c, q] * spec.n_k + k,
                    rcol[ld, c, q] * spec.q_j + gj % spec.q_j)
        return index_map

    def out_map(t, c, k, *tabs):
        if spec.out_tri:
            return (t, 0)
        return (t // spec.n_tj, t % spec.n_tj)

    l_shape, r_shape = _operand_shapes(spec)

    in_specs = [pl.BlockSpec(l_shape, left_map(p)) for p in range(spec.tmax)]
    in_specs += [pl.BlockSpec(r_shape, right_map(q))
                 for q in range(spec.tmax)]
    operands = [left] * spec.tmax + [right] * spec.tmax
    if spec.accumulate:
        # the incoming stack: same tile walk as the output
        in_specs.append(pl.BlockSpec((spec.bi, spec.bj), out_map))
        operands.append(c_in)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_tab,
        grid=(spec.n_out, spec.n_c, spec.n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((spec.bi, spec.bj), out_map),
        scratch_shapes=[pltpu.VMEM((spec.bi, spec.bj),
                                   jnp.dtype(spec.acc_dtype))],
    )
    # named_scope: the bound program's identity (kind/levels/variant)
    # lands in the HLO metadata of the pallas_call, so profiler traces
    # and HLO censuses attribute kernel time/traffic to the schedule
    # that produced it (DESIGN.md §14)
    with jax.named_scope(
            f"fused:{spec.kind}:l{spec.levels}:{spec.variant}:{spec.gram}"):
        return pl.pallas_call(
            functools.partial(_leaf_kernel, spec=spec),
            grid_spec=grid_spec,
            out_shape=_out_shape_struct(spec, out_dtype),
            # output tiles (t) are independent -> megacore partitions
            # them; the (contribution, K) sweep carries the VMEM
            # accumulator and must stay sequential per tile.
            compiler_params=pltpu.TPUCompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(*tables, *operands)


# ---------------------------------------------------------------------------
# Fused ATA: C = tril(A^t A) into the packed triangular block stack.
# ---------------------------------------------------------------------------

def fused_ata_packed(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
    sr_seed=None,
):
    """Packed lower-triangular block stack of ``tril(a.T @ a)`` via the
    leaf-program executor.

    ``a`` is zero-padded so each of the ``2^levels`` leaf blocks is a
    (bk, bn)-tile multiple (exact: zero rows add nothing to A^tA, zero
    columns are sliced away by the dense wrapper).

    Returns ``(packed, n_padded)`` with packed of shape
    ``(T(T+1)/2 * bn, bn)``, ``T = n_padded // bn``, in the ordering of
    ``symmetry.pack_tril_blocks`` / ``kernels.syrk``.

    ``levels`` is a cap: the unroll depth is clamped (``_ata_geometry``)
    so every leaf block holds at least one (bk, bn) tile of real data
    and so the operand fan-in fits VMEM (``MAX_OPERAND_TERMS``, warned
    once).

    Differentiable: the custom VJP consumes the *packed* cotangent
    directly through :func:`fused_symm_matmul` (``bwd="fused"``, the
    default) — ``dA = A (S + S^t)`` with S the block-lower cotangent,
    no dense n^2 buffer ever materialized.  ``bwd="dense"`` selects the
    classical dense-dot baseline (unpack + ``A @ (S + S^t)``) for
    benchmarking.

    Perf/precision knobs (DESIGN.md §16): ``pipeline_depth`` revolving
    DMA buffer slots (None = backend default: 2 compiled, 1 interpret);
    ``operand_dtype`` quantizes the stored operand tiles (fp8/bf16)
    while accumulation stays in ``acc_dtype`` (fp32 default);
    ``sr_seed`` stochastically rounds a bf16 output (deterministic per
    seed, unbiased in expectation).
    """
    interpret = _auto_interpret(interpret, site="fused_ata_packed")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    m, n = a.shape
    geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    sr = _resolve_sr_seed(sr_seed, out_dtype)
    core_out = jnp.dtype(jnp.float32) if sr is not None else out_dtype
    packed = _fused_ata_packed_core(a, levels, variant, gram, bk, bn,
                                    core_out, interpret, bwd, depth,
                                    op_dt, acc_dt)
    if sr is not None:
        packed = stochastic_round_bf16(packed, jax.random.PRNGKey(sr))
    return packed, geo["N"]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11))
def _fused_ata_packed_core(a, levels, variant, gram, bk, bn, out_dtype,
                           interpret, bwd, pipeline_depth, operand_dtype,
                           acc_dtype):
    return _fused_ata_packed_exec(a, levels, variant, gram, bk, bn,
                                  out_dtype, interpret, pipeline_depth,
                                  operand_dtype, acc_dtype)[0]


def _fused_ata_packed_fwd(a, levels, variant, gram, bk, bn, out_dtype,
                          interpret, bwd, pipeline_depth, operand_dtype,
                          acc_dtype):
    return (_fused_ata_packed_core(a, levels, variant, gram, bk, bn,
                                   out_dtype, interpret, bwd,
                                   pipeline_depth, operand_dtype,
                                   acc_dtype), a)


def _fused_ata_packed_bwd(levels, variant, gram, bk, bn, out_dtype,
                          interpret, bwd, pipeline_depth, operand_dtype,
                          acc_dtype, a, gp):
    # vdot(gp, packed(A)) has S = block-lower cotangent (diagonal tiles
    # full — the forward computes them full), so dA = A (S + S^t): the
    # packed stack *is* S and feeds the symm executor directly.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    m, n = a.shape
    if bwd == "dense":
        geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
        M, N = geo["M"], geo["N"]
        s = unpack_tril_blocks(gp.astype(acc), N, bn, symmetrize=False)
        ap = jnp.pad(a.astype(acc), ((0, M - m), (0, N - n)))
        da = jnp.dot(ap, s + s.T, preferred_element_type=acc)[:m, :n]
    else:
        da = fused_symm_matmul(a, gp, levels=levels, variant=variant,
                               bm=bk, diag_sym=True, out_dtype=acc,
                               interpret=interpret,
                               pipeline_depth=pipeline_depth)[:, :n]
    return (da.astype(a.dtype),)


_fused_ata_packed_core.defvjp(_fused_ata_packed_fwd, _fused_ata_packed_bwd)


def _fused_ata_packed_exec(
    a: jax.Array,
    levels: int,
    variant: str,
    gram: str,
    bk: int,
    bn: int,
    out_dtype,
    interpret,
    pipeline_depth: int = 1,
    operand_dtype=None,
    acc_dtype: str = "float32",
):
    """Forward executor (no autodiff surface — see the custom VJP above)."""
    m, n = a.shape
    geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
    plan = geo["plan"]
    M, N = geo["M"], geo["N"]
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    if (M, N) != (m, n):
        a = jnp.pad(a, ((0, M - m), (0, N - n)))
    if operand_dtype is not None:
        # the quantization step: operand tiles are STORED (and DMA'd) at
        # the low precision; every compute upcasts tile-wise to fp32
        a = a.astype(jnp.dtype(operand_dtype))
    spec = _bind(plan, n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bn, bj=bn, bc=bk,
                 pipeline_depth=pipeline_depth, acc_dtype=acc_dtype)
    return _execute(spec, a, a, out_dtype, interpret), N


def fused_ata(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
    sr_seed=None,
) -> jax.Array:
    """Dense ``tril(a.T @ a)`` at the original size via the fused pipeline.

    Differentiable: ``dA = A (S + S^t)`` with ``S = tril(cotangent)``.
    ``bwd="fused"`` (default) runs the backward through the symm program
    executor (:func:`fused_symm_matmul`): the cotangent is gathered
    straight into the packed lower-triangular tile stack (n(n+1)/2
    storage, per-tile slices — no dense S + S^t or padded-S buffer) and
    the product runs the same leaf-program pipeline as the forward.
    ``bwd="dense"`` keeps the classical ``jnp.dot(a, s + s.T)`` baseline.

    Accepts the same perf/precision knobs as :func:`fused_ata_packed`:
    ``pipeline_depth``, ``operand_dtype``, ``acc_dtype``, ``sr_seed``.
    """
    interpret = _auto_interpret(interpret, site="fused_ata")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    sr = _resolve_sr_seed(sr_seed, out_dtype)
    core_out = jnp.dtype(jnp.float32) if sr is not None else out_dtype
    out = _fused_ata_dense(a, levels, variant, gram, bk, bn, core_out,
                           interpret, bwd, depth, op_dt, acc_dt)
    if sr is not None:
        out = stochastic_round_bf16(out, jax.random.PRNGKey(sr))
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11))
def _fused_ata_dense(a, levels, variant, gram, bk, bn, out_dtype, interpret,
                     bwd, pipeline_depth, operand_dtype, acc_dtype):
    n = a.shape[1]
    packed, n_pad = _fused_ata_packed_exec(
        a, levels, variant, gram, bk, bn, out_dtype, interpret,
        pipeline_depth, operand_dtype, acc_dtype)
    dense = unpack_tril_blocks(packed, n_pad, bn, symmetrize=False)
    # diagonal blocks are computed full — drop their upper halves
    return jnp.tril(dense)[:n, :n]


def _fused_ata_dense_fwd(a, levels, variant, gram, bk, bn, out_dtype,
                         interpret, bwd, pipeline_depth, operand_dtype,
                         acc_dtype):
    return (_fused_ata_dense(a, levels, variant, gram, bk, bn, out_dtype,
                             interpret, bwd, pipeline_depth, operand_dtype,
                             acc_dtype), a)


def _pack_cotangent(g: jax.Array, n: int, n_pad: int, bn: int) -> jax.Array:
    """Packed lower-triangular (bn, bn) tile stack of ``S = tril(g)``,
    zero-padded to ``n_pad`` — built from per-tile slices of ``g``, so the
    padded dense S (and a fortiori S + S^t) never materializes in HBM;
    the stack is the only n(n+1)/2-sized temporary."""
    t = n_pad // bn
    blocks = []
    for i in range(t):
        r0 = i * bn
        for j in range(i + 1):
            c0 = j * bn
            if r0 >= n or c0 >= n:
                blocks.append(jnp.zeros((bn, bn), g.dtype))
                continue
            blk = g[r0:min(r0 + bn, n), c0:min(c0 + bn, n)]
            pr, pc = bn - blk.shape[0], bn - blk.shape[1]
            if pr or pc:
                blk = jnp.pad(blk, ((0, pr), (0, pc)))
            if i == j:
                blk = jnp.tril(blk)
            blocks.append(blk)
    return jnp.concatenate(blocks, axis=0)


def _fused_ata_dense_bwd(levels, variant, gram, bk, bn, out_dtype, interpret,
                         bwd, pipeline_depth, operand_dtype, acc_dtype,
                         a, g):
    # C = tril(A^t A) => dL/dA = A (S + S^t), S = tril(dL/dC); the factor
    # 2 on the diagonal of S + S^t is exactly the quadratic term's.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    m, n = a.shape
    if bwd == "dense":
        s = jnp.tril(g).astype(acc)
        da = jnp.dot(a.astype(acc), s + s.T, preferred_element_type=acc)
    else:
        geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
        sp = _pack_cotangent(g.astype(acc), n, geo["N"], bn)
        da = fused_symm_matmul(a, sp, levels=geo["levels"], variant=variant,
                               bm=bk, diag_sym=True, out_dtype=acc,
                               interpret=interpret,
                               pipeline_depth=pipeline_depth)[:, :n]
    return (da.astype(a.dtype),)


_fused_ata_dense.defvjp(_fused_ata_dense_fwd, _fused_ata_dense_bwd)


# ---------------------------------------------------------------------------
# Fused AAT: C = tril(A A^t) — the Arrigoni-Massini (2021) row-gram
# recursion, compiled from the same IR.  The transpose of A never exists
# in HBM: the right side reads the SAME stored A tiles mirrored through
# the index maps and flips the gathered sum in VMEM.
# ---------------------------------------------------------------------------

def fused_aat_packed(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    gram: str = "strassen",
    bm: int = 256,
    bk: int = 256,
    out_dtype=None,
    interpret=None,
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
    sr_seed=None,
):
    """Packed lower-triangular block stack of ``tril(a @ a.T)``.

    Returns ``(packed, m_padded)`` with packed of shape
    ``(T(T+1)/2 * bm, bm)``, ``T = m_padded // bm``.  Zero-padding is
    exact: zero columns add nothing to A A^t, zero rows add zero
    rows/columns to C that the dense wrapper slices away.

    Accepts the same perf/precision knobs as :func:`fused_ata_packed`.
    """
    interpret = _auto_interpret(interpret, site="fused_aat_packed")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    m, n = a.shape
    geo = _aat_geometry(m, n, levels, variant, bm, bk, gram=gram)
    plan = geo["plan"]
    M, N = geo["M"], geo["N"]
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    sr = _resolve_sr_seed(sr_seed, out_dtype)
    core_out = jnp.dtype(jnp.float32) if sr is not None else out_dtype
    if (M, N) != (m, n):
        a = jnp.pad(a, ((0, M - m), (0, N - n)))
    if op_dt is not None:
        a = a.astype(jnp.dtype(op_dt))
    spec = _bind(plan, n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bm, bj=bm, bc=bk,
                 pipeline_depth=depth, acc_dtype=acc_dt)
    packed = _execute(spec, a, a, core_out, interpret)
    if sr is not None:
        packed = stochastic_round_bf16(packed, jax.random.PRNGKey(sr))
    return packed, M


def fused_aat(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    gram: str = "strassen",
    bm: int = 256,
    bk: int = 256,
    out_dtype=None,
    interpret=None,
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
    sr_seed=None,
) -> jax.Array:
    """Dense ``tril(a @ a.T)`` at the original size via the fused
    pipeline — ``ata(x, gram_of="rows")``.

    Differentiable: ``dA = (S + S^t) A`` with ``S = tril(cotangent)``
    (the dense-dot VJP; the row-gram backward is symmetric-left rather
    than symmetric-right, which the symm program does not yet express).

    Accepts the same perf/precision knobs as :func:`fused_ata_packed`.
    """
    interpret = _auto_interpret(interpret, site="fused_aat")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    sr = _resolve_sr_seed(sr_seed, out_dtype)
    core_out = jnp.dtype(jnp.float32) if sr is not None else out_dtype
    out = _fused_aat_dense(a, levels, variant, gram, bm, bk, core_out,
                           interpret, depth, op_dt, acc_dt)
    if sr is not None:
        out = stochastic_round_bf16(out, jax.random.PRNGKey(sr))
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(1, 2, 3, 4, 5, 6, 7, 8, 9, 10))
def _fused_aat_dense(a, levels, variant, gram, bm, bk, out_dtype, interpret,
                     pipeline_depth, operand_dtype, acc_dtype):
    m = a.shape[0]
    packed, m_pad = fused_aat_packed(a, levels=levels, variant=variant,
                                     gram=gram, bm=bm, bk=bk,
                                     out_dtype=out_dtype,
                                     interpret=interpret,
                                     pipeline_depth=pipeline_depth,
                                     operand_dtype=operand_dtype,
                                     acc_dtype=acc_dtype)
    dense = unpack_tril_blocks(packed, m_pad, bm, symmetrize=False)
    return jnp.tril(dense)[:m, :m]


def _fused_aat_dense_fwd(a, levels, variant, gram, bm, bk, out_dtype,
                         interpret, pipeline_depth, operand_dtype,
                         acc_dtype):
    return (_fused_aat_dense(a, levels, variant, gram, bm, bk, out_dtype,
                             interpret, pipeline_depth, operand_dtype,
                             acc_dtype), a)


def _fused_aat_dense_bwd(levels, variant, gram, bm, bk, out_dtype, interpret,
                         pipeline_depth, operand_dtype, acc_dtype, a, g):
    # C = tril(A A^t) => dA = (S + S^t) A, S = tril(g)
    acc = jnp.promote_types(a.dtype, jnp.float32)
    s = jnp.tril(g).astype(acc)
    da = jnp.dot(s + s.T, a.astype(acc), preferred_element_type=acc)
    return (da.astype(a.dtype),)


_fused_aat_dense.defvjp(_fused_aat_dense_fwd, _fused_aat_dense_bwd)


# ---------------------------------------------------------------------------
# Fused rank-k update: C += A^t A against an existing packed stack — the
# accumulating ata program.  The incoming stack seeds the VMEM
# accumulator tile-wise, so a streamed Gram update is ONE kernel with no
# per-chunk delta stack and no unpack/gather in HBM.
# ---------------------------------------------------------------------------

def fused_rank_k_update(
    c_stack: jax.Array,
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256,
    out_dtype=None,
    interpret=None,
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
) -> jax.Array:
    """``C += tril(a.T @ a)`` on a packed lower-triangular tile stack.

    ``c_stack`` is a ``(T(T+1)/2 * bn, bn)`` stack (``fused_ata_packed``
    / ``kernels.syrk`` ordering — the tile edge is read off the stack's
    trailing dim); ``a`` is an (m, n) chunk with ``n <= T * bn`` (columns
    zero-padded to the stack span, exact for the Gram).  Returns the
    updated stack, same shape/dtype discipline as the input.

    ``levels`` is clamped to depths dividing the (fixed) stack layout,
    like :func:`fused_symm_matmul`.  Differentiable in both arguments:
    the stack cotangent passes through packed, and ``dA`` runs the symm
    program on the packed cotangent (DESIGN.md §11) — no dense n^2
    buffer in either direction.

    ``pipeline_depth``/``operand_dtype``/``acc_dtype`` as in
    :func:`fused_ata_packed`; ``operand_dtype`` quantizes only the
    incoming chunk ``a`` — the running stack seeds the accumulator at
    its own precision, so streamed state never degrades.
    """
    interpret = _auto_interpret(interpret, site="fused_rank_k_update")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    if c_stack.ndim != 2 or a.ndim != 2:
        raise ValueError(f"bad ranks: stack {c_stack.shape} x {a.shape}")
    bn = c_stack.shape[1]
    if c_stack.shape[0] % bn:
        raise ValueError(f"packed stack {c_stack.shape} not a (bn, bn) "
                         "tile stack")
    n_tri = c_stack.shape[0] // bn
    T = (math.isqrt(8 * n_tri + 1) - 1) // 2
    if T * (T + 1) // 2 != n_tri:
        raise ValueError(f"stack of {n_tri} tiles is not triangular")
    N = T * bn
    if a.shape[1] > N:
        raise ValueError(f"chunk has {a.shape[1]} cols but the stack "
                         f"spans {N}")
    out_dtype = (c_stack.dtype if out_dtype is None
                 else jnp.dtype(out_dtype))
    return _fused_rank_k_core(c_stack, a, levels, variant, gram, bk, bn,
                              out_dtype, jnp.dtype(c_stack.dtype),
                              interpret, depth, op_dt, acc_dt)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12))
def _fused_rank_k_core(c_stack, a, levels, variant, gram, bk, bn, out_dtype,
                       stack_dtype, interpret, pipeline_depth,
                       operand_dtype, acc_dtype):
    return _fused_rank_k_exec(c_stack, a, levels, variant, gram, bk, bn,
                              out_dtype, interpret, pipeline_depth,
                              operand_dtype, acc_dtype)


def _fused_rank_k_exec(c_stack, a, levels, variant, gram, bk, bn, out_dtype,
                       interpret, pipeline_depth=1, operand_dtype=None,
                       acc_dtype="float32"):
    n_tri = c_stack.shape[0] // bn
    T = (math.isqrt(8 * n_tri + 1) - 1) // 2
    N = T * bn
    m, n = a.shape
    geo = _rank_k_geometry(m, T, levels, variant, bk, gram=gram)
    plan, M = geo["plan"], geo["M"]
    if (M, N) != (m, n):
        a = jnp.pad(a, ((0, M - m), (0, N - n)))
    if operand_dtype is not None:
        a = a.astype(jnp.dtype(operand_dtype))
    spec = _bind(plan, n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bn, bj=bn, bc=bk,
                 pipeline_depth=pipeline_depth, acc_dtype=acc_dtype)
    return _execute(spec, a, a, out_dtype, interpret, c_in=c_stack)


def _fused_rank_k_fwd(c_stack, a, levels, variant, gram, bk, bn, out_dtype,
                      stack_dtype, interpret, pipeline_depth, operand_dtype,
                      acc_dtype):
    return (_fused_rank_k_core(c_stack, a, levels, variant, gram, bk, bn,
                               out_dtype, stack_dtype, interpret,
                               pipeline_depth, operand_dtype, acc_dtype), a)


def _fused_rank_k_bwd(levels, variant, gram, bk, bn, out_dtype, stack_dtype,
                      interpret, pipeline_depth, operand_dtype, acc_dtype,
                      a, g):
    # C_out = C_in + tril(A^t A): dC_in = g (packed pass-through, cast
    # back to the stack primal's dtype); dA = A (S + S^t) with S the
    # block-lower cotangent stack.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    n = a.shape[1]
    T = (math.isqrt(8 * (g.shape[0] // bn) + 1) - 1) // 2
    lv = _rank_k_geometry(a.shape[0], T, levels, variant, bk,
                          gram=gram)["levels"]
    da = fused_symm_matmul(a, g, levels=lv, variant=variant, bm=bk,
                           diag_sym=True, out_dtype=acc,
                           interpret=interpret,
                           pipeline_depth=pipeline_depth)[:, :n]
    return g.astype(stack_dtype), da.astype(a.dtype)


_fused_rank_k_core.defvjp(_fused_rank_k_fwd, _fused_rank_k_bwd)


# ---------------------------------------------------------------------------
# Fused symm matmul: D = X @ Sym where Sym is given ONLY as the packed
# lower-triangular (bs, bs) tile stack of S (syrk / fused-ATA layout).
# The executor binding of the ``symm`` program — and the engine of the
# Gram backward: dA = A (S + S^t) with S the (packed) cotangent.
# ---------------------------------------------------------------------------

def fused_symm_matmul(
    x: jax.Array,
    s_packed: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bm: int = 256,
    diag_sym: bool = False,
    out_dtype=None,
    interpret=None,
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
) -> jax.Array:
    """``x @ Sym`` via the flattened symm program, one fused kernel.

    ``s_packed`` is the packed lower-triangular tile stack of S —
    shape (T(T+1)/2 * bs, bs) in ``kernels.syrk`` / ``fused_ata_packed``
    ordering (the tile edge ``bs`` is read off the stack's trailing dim).

    * ``diag_sym=False``: Sym is the symmetric completion of the stack
      (diagonal tiles stored full); computes ``x @ Sym``.
    * ``diag_sym=True``: Sym = S + S^t with S the block-lower matrix the
      stack represents — the Gram-VJP operand.  Identical mirrored reads;
      diagonal tiles contribute ``tile + tile^t``.

    ``x`` is zero-padded on the right to the stack's T*bs columns (exact:
    the padded columns multiply rows of Sym that padded-A gradients
    discard) and on the bottom to leaf multiples.  Returns
    ``(x.shape[0], T*bs)``.

    Same fusion contract as the forward: operand sums and mirrored
    transposes live in VMEM only, fp32 VMEM accumulation, one HBM write
    per output tile, no dense Sym (or S + S^t) buffer ever exists.

    ``pipeline_depth``/``operand_dtype``/``acc_dtype`` as in
    :func:`fused_ata_packed` (``operand_dtype`` quantizes both ``x`` and
    the packed stack).
    """
    interpret = _auto_interpret(interpret, site="fused_symm_matmul")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    if x.ndim != 2 or s_packed.ndim != 2:
        raise ValueError(f"bad ranks: {x.shape} x packed {s_packed.shape}")
    bs = s_packed.shape[1]
    if s_packed.shape[0] % bs:
        raise ValueError(f"packed stack {s_packed.shape} not a (bs, bs) "
                         "tile stack")
    n_tri = s_packed.shape[0] // bs
    T = (math.isqrt(8 * n_tri + 1) - 1) // 2
    if T * (T + 1) // 2 != n_tri:
        raise ValueError(f"stack of {n_tri} tiles is not triangular")
    N = T * bs
    m, nx = x.shape
    if nx > N:
        raise ValueError(f"x has {nx} cols but the stack spans {N}")
    if nx < N:
        x = jnp.pad(x, ((0, 0), (0, N - nx)))
    out_dtype = (jnp.promote_types(jnp.promote_types(x.dtype,
                                                     s_packed.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))

    geo = _symm_geometry(m, T, levels, variant, bm)
    plan = geo["plan"]
    M, nbm, q = geo["M"], geo["nbm"], geo["q"]
    if M != m:
        x = jnp.pad(x, ((0, M - m), (0, 0)))
    if op_dt is not None:
        x = x.astype(jnp.dtype(op_dt))
        s_packed = s_packed.astype(jnp.dtype(op_dt))
    spec = _bind(plan, n_out=(M // bm) * T, n_tj=T, q_i=nbm, q_j=q,
                 n_k=q, bi=bm, bj=bs, bc=bs, diag_sym=diag_sym,
                 pipeline_depth=depth, acc_dtype=acc_dt)
    out = _execute(spec, x, s_packed, out_dtype, interpret)
    return out[:m]


# ---------------------------------------------------------------------------
# Analytic HBM traffic model — IR-driven, one core shared by every kind.
#
# In interpret mode (CPU) the Pallas pipeline is *emulated* with XLA loops
# whose HLO carries full-array state buffers, so an HLO census of the
# interpret lowering measures the emulation, not the kernel.  On hardware
# the kernel's HBM behaviour is exact and simple by construction — grid
# DMA reads of operand tiles, one write per output tile, and NO other
# HBM buffer — so we model it in closed form over the bound _Spec, the
# same way bench_roofline treats Pallas flash-attention FLOPs
# analytically.
# ---------------------------------------------------------------------------

def _traffic(spec: _Spec, *, left_bytes: int, right_bytes: int,
             out_bytes: int, cin_bytes: int = 0) -> dict:
    """Core HBM model of one bound program: streamed tile fetches
    (incl. padded null contribution slots — the contribution axis is
    padded to ``max_contributions``, so the read term honestly reflects
    that amplification), one write per output tile, plus the incoming
    stack read for accumulating programs."""
    grid = spec.grid_steps
    l_tile = spec.bi * spec.bc
    r_tile = (spec.bj * spec.bj) if spec.right_tri else spec.bj * spec.bc
    reads = grid * spec.tmax * (l_tile * left_bytes + r_tile * right_bytes)
    if spec.accumulate:
        reads += spec.n_out * spec.bi * spec.bj * cin_bytes
    writes = spec.n_out * spec.bi * spec.bj * out_bytes
    # MXU work per grid step: one (bi, bc) x (bc, bj) leaf product (the
    # VPU gather adds are second-order) — feeds the pipelined occupancy
    # term in cost_model.pipelined_bytes_score
    flops = 2 * grid * spec.bi * spec.bc * spec.bj
    return {"grid_steps": grid, "read_bytes": reads, "write_bytes": writes,
            "flops": flops}


def ata_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256, bn: int = 256, in_bytes: int = 4, out_bytes: int = 4,
) -> dict:
    """HBM bytes of ``fused_ata_packed`` on an (m, n) input.

    Reads/writes from the shared IR traffic core; ``intermediate_bytes``
    is HBM-materialized temporaries — just the zero-pad copy of A when
    the shape is not tile-aligned, 0 otherwise.  Uses the same
    ``_ata_geometry`` as the executor, so the model cannot drift from
    the kernel's clamping/padding.
    """
    geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
    M, N = geo["M"], geo["N"]
    spec = _bind(geo["plan"], n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bn, bj=bn, bc=bk)
    t = _traffic(spec, left_bytes=in_bytes, right_bytes=in_bytes,
                 out_bytes=out_bytes)
    t["intermediate_bytes"] = M * N * in_bytes if (M, N) != (m, n) else 0
    t["padded_shape"] = (M, N)
    return t


def aat_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    gram: str = "strassen",
    bm: int = 256, bk: int = 256, in_bytes: int = 4, out_bytes: int = 4,
) -> dict:
    """HBM bytes of ``fused_aat_packed`` (row gram) — same core model,
    the row-gram geometry."""
    geo = _aat_geometry(m, n, levels, variant, bm, bk, gram=gram)
    M, N = geo["M"], geo["N"]
    spec = _bind(geo["plan"], n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bm, bj=bm, bc=bk)
    t = _traffic(spec, left_bytes=in_bytes, right_bytes=in_bytes,
                 out_bytes=out_bytes)
    t["intermediate_bytes"] = M * N * in_bytes if (M, N) != (m, n) else 0
    t["padded_shape"] = (M, N)
    return t


def rank_k_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256, bn: int = 256, state_bytes: int = 4, in_bytes: int = 4,
) -> dict:
    """HBM bytes of one ``fused_rank_k_update`` chunk vs the status-quo
    streamed update it replaces (ata kernel + delta stack + gather-add:
    the delta stack is written and re-read, and the state is read and
    rewritten)."""
    T = _round_up(max(n, 1), bn) // bn
    # the stack layout fixes T; mirror the executor's divisibility clamp
    geo = _rank_k_geometry(m, T, levels, variant, bk, gram=gram)
    M, N = geo["M"], T * bn
    spec = _bind(geo["plan"], n_out=geo["n_tri"], n_tj=0, q_i=geo["nbt"],
                 q_j=geo["nbt"], n_k=geo["n_k"], bi=bn, bj=bn, bc=bk)
    t = _traffic(spec, left_bytes=in_bytes, right_bytes=in_bytes,
                 out_bytes=state_bytes, cin_bytes=state_bytes)
    stack_bytes = geo["n_tri"] * bn * bn * state_bytes
    t["intermediate_bytes"] = (M * N * in_bytes if (M, N) != (m, n) else 0)
    t["padded_shape"] = (M, N)
    t["state_bytes"] = stack_bytes
    # status quo (PR 2-4 stream updater): fused ata writes a delta stack,
    # the gather reads it, and the add reads + writes the state.
    t["baseline"] = {
        "read_bytes": (t["read_bytes"] - stack_bytes) + 2 * stack_bytes,
        "write_bytes": 2 * stack_bytes,
        "intermediate_bytes": t["intermediate_bytes"] + stack_bytes,
    }
    return t


def ata_bwd_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    gram: str = "strassen",
    bk: int = 256, bn: int = 256, in_bytes: int = 4, cot_bytes: int = 4,
    cotangent: str = "packed",
) -> dict:
    """HBM bytes of the Gram *backward* ``dA = A (S + S^t)`` on an (m, n)
    forward problem — the fused symm-program kernel vs the dense-dot
    baseline it replaces.  Shares ``_ata_geometry`` / ``_symm_geometry``
    with the executors, so the model cannot drift from their clamping.

    ``cotangent="packed"``: the cotangent arrives as the packed stack
    (``fused_ata_packed``'s VJP) and feeds the kernel directly — zero
    HBM intermediates beyond an optional pad copy of A.
    ``cotangent="dense"``: the dense entry point first gathers tril(g)
    into the packed stack (the stack — n(n+1)/2-ish bytes — is the only
    temporary).

    The baseline models what the dense-dot backward materializes
    semantically: ``tril(g)`` (select), ``S^t`` (transpose) and
    ``S + S^t`` (add) — three dense N^2 buffers.  An
    ``hbm_intermediate_census`` of its compiled HLO lands near this
    (XLA fusion may materialize fewer; the packed entry's unpack scatter
    adds more).  The fused read term honestly includes the
    contribution-slot padding amplification, same as the forward model.
    """
    geo = _ata_geometry(m, n, levels, variant, bk, bn, gram=gram)
    M, N = geo["M"], geo["N"]
    T = N // bn
    sgeo = _symm_geometry(M, T, geo["levels"], variant, bk)
    plan, q = sgeo["plan"], sgeo["q"]
    assert sgeo["M"] == M, (sgeo["M"], M)   # bwd reuses the forward padding
    spec = _bind(plan, n_out=(M // bk) * T, n_tj=T, q_i=sgeo["nbm"],
                 q_j=q, n_k=q, bi=bk, bj=bn, bc=bn, diag_sym=True)
    t = _traffic(spec, left_bytes=in_bytes, right_bytes=cot_bytes,
                 out_bytes=4)            # dA in the fp32 accum dtype
    stack_bytes = T * (T + 1) // 2 * bn * bn * cot_bytes
    pad_copy = M * N * in_bytes if (M, N) != (m, n) else 0
    fused_inter = pad_copy + (stack_bytes if cotangent == "dense" else 0)
    dense_inter = 3 * N * N * cot_bytes
    t.update({
        "intermediate_bytes": fused_inter,
        "packed_stack_bytes": stack_bytes,
        "padded_shape": (M, N),
        "levels": sgeo["levels"],
        "dense_baseline": {
            "read_bytes": M * N * in_bytes + N * N * cot_bytes,
            "write_bytes": M * N * 4,
            "intermediate_bytes": dense_inter,
        },
        "intermediate_ratio_dense_over_fused": (
            dense_inter / fused_inter if fused_inter else None),
    })
    return t


# ---------------------------------------------------------------------------
# Fused Strassen matmul: C = op(A) @ op(B), dense output.
# ---------------------------------------------------------------------------

def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    trans_a: bool = False,
    trans_b: bool = False,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
    pipeline_depth=None,
    operand_dtype=None,
    acc_dtype=None,
) -> jax.Array:
    """``op(a) @ op(b)`` via the flattened Strassen program, one fused
    kernel; ``op`` transposes when the flag is set — folded into the
    BlockSpec index maps (mirrored tile fetches), so no transposed copy
    of an operand ever exists in HBM.  The engine of the distributed
    ring/2.5D block tasks (``core.distributed``), which are all
    ``A_loc^t @ A_perm`` products.

    Same fusion contract as :func:`fused_ata_packed`: operand sums live
    in VMEM only, every output tile is written once, no ``M_i`` in HBM;
    the same level/fan-in clamps keep leaves at tile granularity and the
    operand gather inside VMEM.

    Differentiable: ``bwd="fused"`` (default) runs both VJP products
    through the same program executor with the transposes folded into
    the index maps, so the backward costs what the forward costs.
    ``bwd="dense"`` keeps the classical ``jnp.dot`` VJP.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"bad shapes for matmul: {a.shape} x {b.shape}")
    k_a = a.shape[0] if trans_a else a.shape[1]
    k_b = b.shape[1] if trans_b else b.shape[0]
    if k_a != k_b:
        raise ValueError(
            f"bad shapes for matmul: {a.shape} x {b.shape} "
            f"(trans_a={trans_a}, trans_b={trans_b})")
    interpret = _auto_interpret(interpret, site="fused_matmul")
    depth = _resolve_pipeline_depth(pipeline_depth, interpret)
    op_dt = _resolve_operand_dtype(operand_dtype)
    acc_dt = _resolve_acc_dtype(acc_dtype)
    out_dtype = (jnp.promote_types(jnp.promote_types(a.dtype, b.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    return _fused_matmul_core(a, b, levels, variant, bm, bk, bn, trans_a,
                              trans_b, out_dtype, interpret, bwd, depth,
                              op_dt, acc_dt)


def _fused_matmul_exec(a, b, levels, variant, bm, bk, bn, out_dtype,
                       interpret, trans_a=False, trans_b=False,
                       pipeline_depth=1, operand_dtype=None,
                       acc_dtype="float32"):
    """Executor binding for C = op(a) @ op(b)."""
    m, k_dim = a.shape[::-1] if trans_a else a.shape
    n, _ = b.shape if trans_b else b.shape[::-1]
    # generic per-axis level clamp (== strassen_levels_for at (2,2,2)):
    # stop splitting once the smallest leaf axis reaches tile size
    dm, dk, dn = leaf_ir.algebra_dims(variant)
    leaf, lv = max(bm, bk, bn), 0
    cm, ck, cn = m, k_dim, n
    while min(cm, ck, cn) > leaf:
        cm, ck, cn = cm // dm, ck // dk, cn // dn
        lv += 1
    levels = min(levels, lv)
    levels = _fan_in_clamp("matmul", levels, variant)
    plan = compile_program("matmul", levels, variant,
                           trans_a=trans_a, trans_b=trans_b)
    Bm, Bk, Bn = plan.blocks_m, plan.blocks_k, plan.blocks_n
    mb = _round_up(max(m, 1), Bm * bm) // Bm
    kb = _round_up(max(k_dim, 1), Bk * bk) // Bk
    nb = _round_up(max(n, 1), Bn * bn) // Bn
    M, K, N = Bm * mb, Bk * kb, Bn * nb
    a_shape = (K, M) if trans_a else (M, K)
    b_shape = (N, K) if trans_b else (K, N)
    if a.shape != a_shape:
        a = jnp.pad(a, [(0, t - s) for s, t in zip(a.shape, a_shape)])
    if b.shape != b_shape:
        b = jnp.pad(b, [(0, t - s) for s, t in zip(b.shape, b_shape)])
    if operand_dtype is not None:
        a = a.astype(jnp.dtype(operand_dtype))
        b = b.astype(jnp.dtype(operand_dtype))

    nbm, nbn = mb // bm, nb // bn
    spec = _bind(plan, n_out=(M // bm) * (N // bn), n_tj=N // bn,
                 q_i=nbm, q_j=nbn, n_k=kb // bk, bi=bm, bj=bn, bc=bk,
                 pipeline_depth=pipeline_depth, acc_dtype=acc_dtype)
    out = _execute(spec, a, b, out_dtype, interpret)
    return out[:m, :n]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13,
                                    14))
def _fused_matmul_core(a, b, levels, variant, bm, bk, bn, trans_a, trans_b,
                       out_dtype, interpret, bwd, pipeline_depth,
                       operand_dtype, acc_dtype):
    return _fused_matmul_exec(a, b, levels, variant, bm, bk, bn, out_dtype,
                              interpret, trans_a=trans_a, trans_b=trans_b,
                              pipeline_depth=pipeline_depth,
                              operand_dtype=operand_dtype,
                              acc_dtype=acc_dtype)


def _fused_matmul_fwd(a, b, levels, variant, bm, bk, bn, trans_a, trans_b,
                      out_dtype, interpret, bwd, pipeline_depth,
                      operand_dtype, acc_dtype):
    return (_fused_matmul_core(a, b, levels, variant, bm, bk, bn, trans_a,
                               trans_b, out_dtype, interpret, bwd,
                               pipeline_depth, operand_dtype, acc_dtype),
            (a, b))


def _fused_matmul_bwd(levels, variant, bm, bk, bn, trans_a, trans_b,
                      out_dtype, interpret, bwd, pipeline_depth,
                      operand_dtype, acc_dtype, res, g):
    a, b = res
    acc = jnp.promote_types(jnp.promote_types(a.dtype, b.dtype), jnp.float32)
    gf = g.astype(acc)
    if bwd == "dense":
        op_a = (lambda x: x.T.astype(acc)) if trans_a else \
            (lambda x: x.astype(acc))
        op_b = (lambda x: x.T.astype(acc)) if trans_b else \
            (lambda x: x.astype(acc))
        ca, cb = op_a(a), op_b(b)
        da = jnp.dot(gf, cb.T, preferred_element_type=acc)
        db = jnp.dot(ca.T, gf, preferred_element_type=acc)
        if trans_a:
            da = da.T
        if trans_b:
            db = db.T
    else:
        # the VJP products are themselves matmul programs with the
        # transposes folded into the index maps (the kernel upcasts
        # tile-wise in VMEM, so bf16 residuals feed the backward
        # without an HBM-wide fp32 copy):
        ex = functools.partial(_fused_matmul_exec, levels=levels,
                               variant=variant, out_dtype=acc,
                               interpret=interpret,
                               pipeline_depth=pipeline_depth)
        if not trans_a and not trans_b:
            # da = g b^t; db = a^t g
            da = ex(gf, b, bm=bm, bk=bn, bn=bk, trans_b=True)
            db = ex(a, gf, bm=bk, bk=bm, bn=bn, trans_a=True)
        elif trans_a and trans_b:
            # C = a^t b^t: da = b^t g^t (stored (k, m));
            #              db = g^t a^t (stored (n, k))
            da = ex(b, gf, bm=bk, bk=bn, bn=bm, trans_a=True, trans_b=True)
            db = ex(gf, a, bm=bn, bk=bm, bn=bk, trans_a=True, trans_b=True)
        elif trans_a:
            # C = a^t b: da = b g^t (stored (k, m)); db = a g
            da = ex(b, gf, bm=bk, bk=bn, bn=bm, trans_b=True)
            db = ex(a, gf, bm=bk, bk=bm, bn=bn)
        else:
            # C = a b^t: da = g b (b stored (n, k)); db = g^t a
            da = ex(gf, b, bm=bm, bk=bn, bn=bk)
            db = ex(gf, a, bm=bn, bk=bm, bn=bk, trans_a=True)
    return da.astype(a.dtype), db.astype(b.dtype)


_fused_matmul_core.defvjp(_fused_matmul_fwd, _fused_matmul_bwd)
