"""Fused Pallas executor for flattened ATA / Strassen schedules.

This is the single-kernel replacement for the materialize-everything
recursion (DESIGN.md §4): a ``pallas_call`` whose grid enumerates
``(output tile, contribution slot, K block)`` over the leaf-task plans from
``repro.core.schedule``.  Per grid step the kernel

  1. gathers up to ``max_terms`` (bk, bn) tiles of the *original* padded A
     straight from HBM (scalar-prefetched index tables drive the BlockSpec
     index maps — the per-level ``pad``/``concatenate`` copies of the
     reference recursion become index arithmetic),
  2. forms the +-1-signed Strassen operand sums tile-wise in VMEM (the
     ``S``/``T`` operand temporaries never exist in HBM),
  3. runs the leaf product on the MXU into an fp32 VMEM accumulator that
     lives across the whole (contribution, K) sweep of one output tile,
  4. writes each output tile to HBM exactly once, directly into the packed
     lower-triangular block stack of ``kernels/syrk.py`` — no ``M_i``
     product, no operand sum and no upper-triangular block ever touches
     HBM.

Contributions are sorted by destination (``schedule.Plan.contributions``),
so the accumulator hand-off needs no HBM read-modify-write and the TPU
grid's sequential execution guarantees a single store per tile.

Autodiff (DESIGN.md §11): every entry point carries a custom VJP that runs
the *backward* through the same leaf-task machinery.  The Gram backward
``dA = A (S + S^t)`` has a symmetric right operand, so it executes a
``plan_symm`` schedule (:func:`fused_symm_matmul`) that reads the packed
lower-triangular cotangent directly — upper-triangle tiles are mirrored
``(j, i)`` reads with the transpose folded into the index maps, and the
dense n^2 cotangent buffer of the old dense-dot backward never exists in
HBM.  ``bwd="dense"`` keeps the dense-dot baseline selectable for
benchmarking (``benchmarks/bench_grads.py``).
"""
from __future__ import annotations

import functools
import math
import warnings

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.ata import ata_levels_for
from ..core.schedule import plan_ata, plan_matmul, plan_symm
from ..core.strassen import strassen_levels_for
from ..core.symmetry import unpack_tril_blocks
from .ops import _auto_interpret
from .syrk import _tri_decode

__all__ = ["fused_ata", "fused_ata_packed", "fused_matmul",
           "fused_symm_matmul", "ata_traffic_model",
           "ata_bwd_traffic_model"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# VMEM guard: the kernel gathers 2 * max_terms input tiles per grid step
# (double-buffered by the pipeline).  Each Strassen level doubles the
# operand fan-in (Winograd can quadruple it), so deep plans are clamped to
# keep the working set well under per-core VMEM: 2*8 tiles of 256x256 fp32
# = 4 MB single-buffered.
MAX_OPERAND_TERMS = 8

# (kind, variant, requested, clamped) combinations already warned about —
# the clamp silently changing the schedule depth bit users before, so it
# warns exactly once per distinct clamp.
_CLAMP_WARNED: set = set()


def _warn_fan_in_clamp(kind: str, variant: str, requested: int,
                       clamped: int) -> None:
    key = (kind, variant, requested, clamped)
    if key in _CLAMP_WARNED:
        return
    _CLAMP_WARNED.add(key)
    warnings.warn(
        f"fused {kind} schedule: levels={requested} (variant={variant!r}) "
        f"exceeds the MAX_OPERAND_TERMS={MAX_OPERAND_TERMS} VMEM operand "
        f"fan-in; clamped to levels={clamped}",
        stacklevel=3)


def _fan_in_clamp(kind: str, plan_fn, levels: int, variant: str) -> int:
    """Clamp ``levels`` until the plan's operand fan-in fits VMEM,
    warning once per distinct clamp (the shape-driven clamp above this is
    expected behaviour and stays silent)."""
    requested = levels
    while levels > 0 and plan_fn(levels, variant).max_terms > \
            MAX_OPERAND_TERMS:
        levels -= 1
    if levels < requested:
        _warn_fan_in_clamp(kind, variant, requested, levels)
    return levels


def _ata_geometry(m: int, n: int, levels: int, variant: str,
                  bk: int, bn: int):
    """Shared executor/traffic-model geometry (single source of truth).

    Clamps ``levels`` so (a) every leaf block holds at least one (bk, bn)
    tile of real data and (b) the operand fan-in fits VMEM (warned once),
    then derives leaf/padded shapes and grid extents.
    """
    levels = min(levels, ata_levels_for(m, n, max(bk, bn)))
    levels = _fan_in_clamp("ata", plan_ata, levels, variant)
    plan = plan_ata(levels, variant)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bk) // B     # leaf rows (bk multiple)
    nb = _round_up(max(n, 1), B * bn) // B     # leaf cols (bn multiple)
    M, N = B * mb, B * nb
    t_blocks = N // bn
    return {
        "plan": plan, "levels": levels, "mb": mb, "nb": nb, "M": M, "N": N,
        "n_k": mb // bk, "nbt": nb // bn,
        "n_tri": t_blocks * (t_blocks + 1) // 2,
    }


# ---------------------------------------------------------------------------
# Scalar-prefetch tables: the plan lowered to int32 arrays indexed by
# (leaf destination, contribution slot[, term slot]).  Empty slots carry
# sign 0 (the kernel skips them) and index block (0, 0) (a harmless fetch).
# ---------------------------------------------------------------------------

def _lower_tables(plan, n_dest: int, dest_index):
    n_c, tmax = plan.max_contributions, plan.max_terms
    sign = np.zeros((n_dest, n_c), np.int32)
    lrow = np.zeros((n_dest, n_c, tmax), np.int32)
    lcol = np.zeros_like(lrow)
    lsgn = np.zeros_like(lrow)
    rrow = np.zeros_like(lrow)
    rcol = np.zeros_like(lrow)
    rsgn = np.zeros_like(lrow)
    for (di, dj), contribs in plan.by_dest().items():
        ld = dest_index(di, dj)
        for s, contrib in enumerate(contribs):
            sign[ld, s] = contrib.sign
            for p, (r, c, sg) in enumerate(contrib.left):
                lrow[ld, s, p], lcol[ld, s, p], lsgn[ld, s, p] = r, c, sg
            for q, (r, c, sg) in enumerate(contrib.right):
                rrow[ld, s, q], rcol[ld, s, q], rsgn[ld, s, q] = r, c, sg
    return sign, lrow, lcol, lsgn, rrow, rcol, rsgn


@functools.lru_cache(maxsize=None)
def _ata_tables(levels: int, variant: str):
    plan = plan_ata(levels, variant)
    n_dest = plan.blocks * (plan.blocks + 1) // 2
    return _lower_tables(plan, n_dest, lambda di, dj: di * (di + 1) // 2 + dj)


@functools.lru_cache(maxsize=None)
def _matmul_tables(levels: int, variant: str):
    plan = plan_matmul(levels, variant)
    b = plan.blocks
    return _lower_tables(plan, b * b, lambda di, dj: di * b + dj)


def _signed_sum(refs, sgn_ref, ld, c):
    """Sum[p] sgn[p] * refs[p], formed in fp32 in VMEM (never in HBM)."""
    acc = None
    for p, ref in enumerate(refs):
        term = ref[...].astype(jnp.float32) * sgn_ref[ld, c, p].astype(
            jnp.float32)
        acc = term if acc is None else acc + term
    return acc


# ---------------------------------------------------------------------------
# Fused ATA: C = tril(A^t A) into the packed triangular block stack.
# ---------------------------------------------------------------------------

def _fused_ata_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                      rrow_ref, rcol_ref, rsgn_ref, *refs,
                      tmax: int, nbt: int, n_c: int, n_k: int):
    a_refs = refs[:2 * tmax]
    o_ref, acc_ref = refs[2 * tmax], refs[2 * tmax + 1]
    t, c, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    gi, gj = _tri_decode(t)
    di = gi // nbt
    ld = di * (di + 1) // 2 + gj // nbt
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        left = _signed_sum(a_refs[:tmax], lsgn_ref, ld, c)
        right = _signed_sum(a_refs[tmax:], rsgn_ref, ld, c)
        acc_ref[...] += sgn.astype(jnp.float32) * jnp.dot(
            left.T, right, preferred_element_type=jnp.float32)

    @pl.when((c == n_c - 1) & (k == n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_ata_packed(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
):
    """Packed lower-triangular block stack of ``tril(a.T @ a)`` via the
    fused schedule executor.

    ``a`` is zero-padded so each of the ``2^levels`` leaf blocks is a
    (bk, bn)-tile multiple (exact: zero rows add nothing to A^tA, zero
    columns are sliced away by the dense wrapper).

    Returns ``(packed, n_padded)`` with packed of shape
    ``(T(T+1)/2 * bn, bn)``, ``T = n_padded // bn``, in the ordering of
    ``symmetry.pack_tril_blocks`` / ``kernels.syrk``.

    ``levels`` is a cap: the unroll depth is clamped (``_ata_geometry``)
    so every leaf block holds at least one (bk, bn) tile of real data —
    a (128, 128) input with 256-tiles runs as a single SYRK leaf rather
    than padding each empty leaf level 2x per dimension — and so the
    operand fan-in fits VMEM (``MAX_OPERAND_TERMS``, warned once).

    Differentiable: the custom VJP consumes the *packed* cotangent
    directly through :func:`fused_symm_matmul` (``bwd="fused"``, the
    default) — ``dA = A (S + S^t)`` with S the block-lower cotangent,
    no dense n^2 buffer ever materialized.  ``bwd="dense"`` selects the
    classical dense-dot baseline (unpack + ``A @ (S + S^t)``) for
    benchmarking.
    """
    interpret = _auto_interpret(interpret)
    m, n = a.shape
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    packed = _fused_ata_packed_core(a, levels, variant, bk, bn, out_dtype,
                                    interpret, bwd)
    return packed, geo["N"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7))
def _fused_ata_packed_core(a, levels, variant, bk, bn, out_dtype, interpret,
                           bwd):
    return _fused_ata_packed_exec(a, levels, variant, bk, bn, out_dtype,
                                  interpret)[0]


def _fused_ata_packed_fwd(a, levels, variant, bk, bn, out_dtype, interpret,
                          bwd):
    return (_fused_ata_packed_core(a, levels, variant, bk, bn, out_dtype,
                                   interpret, bwd), a)


def _fused_ata_packed_bwd(levels, variant, bk, bn, out_dtype, interpret,
                          bwd, a, gp):
    # vdot(gp, packed(A)) has S = block-lower cotangent (diagonal tiles
    # full — the forward computes them full), so dA = A (S + S^t): the
    # packed stack *is* S and feeds the symm executor directly.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    m, n = a.shape
    if bwd == "dense":
        geo = _ata_geometry(m, n, levels, variant, bk, bn)
        M, N = geo["M"], geo["N"]
        s = unpack_tril_blocks(gp.astype(acc), N, bn, symmetrize=False)
        ap = jnp.pad(a.astype(acc), ((0, M - m), (0, N - n)))
        da = jnp.dot(ap, s + s.T, preferred_element_type=acc)[:m, :n]
    else:
        da = fused_symm_matmul(a, gp, levels=levels, variant=variant,
                               bm=bk, diag_sym=True, out_dtype=acc,
                               interpret=interpret)[:, :n]
    return (da.astype(a.dtype),)


_fused_ata_packed_core.defvjp(_fused_ata_packed_fwd, _fused_ata_packed_bwd)


def _fused_ata_packed_exec(
    a: jax.Array,
    levels: int,
    variant: str,
    bk: int,
    bn: int,
    out_dtype,
    interpret,
):
    """Forward executor (no autodiff surface — see the custom VJP above)."""
    m, n = a.shape
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    plan, levels = geo["plan"], geo["levels"]
    M, N = geo["M"], geo["N"]
    if (M, N) != (m, n):
        a = jnp.pad(a, ((0, M - m), (0, N - n)))
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))

    n_k, nbt, n_tri = geo["n_k"], geo["nbt"], geo["n_tri"]
    tmax, n_c = plan.max_terms, plan.max_contributions
    tables = _ata_tables(levels, variant)

    def _dest(t):
        gi, gj = _tri_decode(t)
        di = gi // nbt
        return gi, gj, di * (di + 1) // 2 + gj // nbt

    def left_map(p):
        def index_map(t, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            gi, _, ld = _dest(t)
            return (lrow[ld, c, p] * n_k + k, lcol[ld, c, p] * nbt + gi % nbt)
        return index_map

    def right_map(q):
        def index_map(t, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            _, gj, ld = _dest(t)
            return (rrow[ld, c, q] * n_k + k, rcol[ld, c, q] * nbt + gj % nbt)
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(n_tri, n_c, n_k),
        in_specs=[pl.BlockSpec((bk, bn), left_map(p)) for p in range(tmax)]
        + [pl.BlockSpec((bk, bn), right_map(q)) for q in range(tmax)],
        out_specs=pl.BlockSpec((bn, bn), lambda t, c, k, *_: (t, 0)),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
    )
    kernel = functools.partial(_fused_ata_kernel, tmax=tmax, nbt=nbt,
                               n_c=n_c, n_k=n_k)
    packed = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tri * bn, bn), out_dtype),
        # output tiles (t) are independent -> megacore partitions them;
        # the (contribution, K) sweep carries the VMEM accumulator and
        # must stay sequential per tile.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*tables, *([a] * (2 * tmax)))
    return packed, N


def fused_ata(
    a: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
) -> jax.Array:
    """Dense ``tril(a.T @ a)`` at the original size via the fused pipeline.

    Differentiable: ``dA = A (S + S^t)`` with ``S = tril(cotangent)``.
    ``bwd="fused"`` (default) runs the backward through the symm schedule
    executor (:func:`fused_symm_matmul`): the cotangent is gathered
    straight into the packed lower-triangular tile stack (n(n+1)/2
    storage, per-tile slices — no dense S + S^t or padded-S buffer) and
    the product runs the same leaf-task Strassen pipeline as the forward.
    ``bwd="dense"`` keeps the classical ``jnp.dot(a, s + s.T)`` baseline.
    """
    interpret = _auto_interpret(interpret)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    return _fused_ata_dense(a, levels, variant, bk, bn, out_dtype, interpret,
                            bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6, 7))
def _fused_ata_dense(a, levels, variant, bk, bn, out_dtype, interpret, bwd):
    n = a.shape[1]
    packed, n_pad = _fused_ata_packed_exec(
        a, levels, variant, bk, bn, out_dtype, interpret)
    dense = unpack_tril_blocks(packed, n_pad, bn, symmetrize=False)
    # diagonal blocks are computed full — drop their upper halves
    return jnp.tril(dense)[:n, :n]


def _fused_ata_dense_fwd(a, levels, variant, bk, bn, out_dtype, interpret,
                         bwd):
    return (_fused_ata_dense(a, levels, variant, bk, bn, out_dtype,
                             interpret, bwd), a)


def _pack_cotangent(g: jax.Array, n: int, n_pad: int, bn: int) -> jax.Array:
    """Packed lower-triangular (bn, bn) tile stack of ``S = tril(g)``,
    zero-padded to ``n_pad`` — built from per-tile slices of ``g``, so the
    padded dense S (and a fortiori S + S^t) never materializes in HBM;
    the stack is the only n(n+1)/2-sized temporary."""
    t = n_pad // bn
    blocks = []
    for i in range(t):
        r0 = i * bn
        for j in range(i + 1):
            c0 = j * bn
            if r0 >= n or c0 >= n:
                blocks.append(jnp.zeros((bn, bn), g.dtype))
                continue
            blk = g[r0:min(r0 + bn, n), c0:min(c0 + bn, n)]
            pr, pc = bn - blk.shape[0], bn - blk.shape[1]
            if pr or pc:
                blk = jnp.pad(blk, ((0, pr), (0, pc)))
            if i == j:
                blk = jnp.tril(blk)
            blocks.append(blk)
    return jnp.concatenate(blocks, axis=0)


def _fused_ata_dense_bwd(levels, variant, bk, bn, out_dtype, interpret,
                         bwd, a, g):
    # C = tril(A^t A) => dL/dA = A (S + S^t), S = tril(dL/dC); the factor
    # 2 on the diagonal of S + S^t is exactly the quadratic term's.
    acc = jnp.promote_types(a.dtype, jnp.float32)
    m, n = a.shape
    if bwd == "dense":
        s = jnp.tril(g).astype(acc)
        da = jnp.dot(a.astype(acc), s + s.T, preferred_element_type=acc)
    else:
        geo = _ata_geometry(m, n, levels, variant, bk, bn)
        sp = _pack_cotangent(g.astype(acc), n, geo["N"], bn)
        da = fused_symm_matmul(a, sp, levels=geo["levels"], variant=variant,
                               bm=bk, diag_sym=True, out_dtype=acc,
                               interpret=interpret)[:, :n]
    return (da.astype(a.dtype),)


_fused_ata_dense.defvjp(_fused_ata_dense_fwd, _fused_ata_dense_bwd)


# ---------------------------------------------------------------------------
# Fused symm matmul: D = X @ Sym where Sym is given ONLY as the packed
# lower-triangular (bs, bs) tile stack of S (syrk / fused-ATA layout).
# The executor for ``core.schedule.plan_symm`` — and the engine of the
# Gram backward: dA = A (S + S^t) with S the (packed) cotangent.
#
# Upper-triangle tile reads (gr < gc) are mirrored (gc, gr) reads of the
# stored stack with the transpose folded into the index maps; plan-level
# mirrored leaves (the 4th element of symm right terms) swap their
# within-leaf tile offsets the same way.  With ``diag_sym`` the diagonal
# tiles contribute S_ii + S_ii^t — the packed cotangent IS the right
# operand, and the dense n^2 cotangent never exists in HBM.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _symm_tables(levels: int, variant: str):
    """plan_symm lowered to int32 scalar-prefetch tables; the extra
    ``rtrn`` table carries the per-term mirror flag."""
    plan = plan_symm(levels, variant)
    b = plan.blocks
    n_c, tmax = plan.max_contributions, plan.max_terms
    sign = np.zeros((b * b, n_c), np.int32)
    lrow = np.zeros((b * b, n_c, tmax), np.int32)
    lcol = np.zeros_like(lrow)
    lsgn = np.zeros_like(lrow)
    rrow = np.zeros_like(lrow)
    rcol = np.zeros_like(lrow)
    rsgn = np.zeros_like(lrow)
    rtrn = np.zeros_like(lrow)
    for (di, dj), contribs in plan.by_dest().items():
        ld = di * b + dj
        for s, contrib in enumerate(contribs):
            sign[ld, s] = contrib.sign
            for p, (r, c, sg) in enumerate(contrib.left):
                lrow[ld, s, p], lcol[ld, s, p], lsgn[ld, s, p] = r, c, sg
            for q, (r, c, sg, tr) in enumerate(contrib.right):
                rrow[ld, s, q], rcol[ld, s, q] = r, c
                rsgn[ld, s, q], rtrn[ld, s, q] = sg, tr
    return sign, lrow, lcol, lsgn, rrow, rcol, rsgn, rtrn


def _symm_coords(rrow_ref, rcol_ref, rtrn_ref, ld, c, qt, q, k, jq):
    """Conceptual global tile coords (gr, gc) of Sym for right term ``qt``.

    Plan-mirrored leaves (rtrn == 1) store the transposed leaf, so their
    within-leaf offsets swap; diagonal leaves straddle the stored triangle
    at tile granularity, handled downstream by max/min + transpose."""
    t = rtrn_ref[ld, c, qt]
    gr = rrow_ref[ld, c, qt] * q + jnp.where(t != 0, jq, k)
    gc = rcol_ref[ld, c, qt] * q + jnp.where(t != 0, k, jq)
    return gr, gc


def _fused_symm_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                       rrow_ref, rcol_ref, rsgn_ref, rtrn_ref, *refs,
                       tmax: int, nbm: int, q: int, n_c: int, n_k: int,
                       blocks: int, diag_sym: bool):
    x_refs = refs[:tmax]
    s_refs = refs[tmax:2 * tmax]
    o_ref, acc_ref = refs[2 * tmax], refs[2 * tmax + 1]
    i, j = pl.program_id(0), pl.program_id(1)
    c, k = pl.program_id(2), pl.program_id(3)
    ld = (i // nbm) * blocks + j // q
    jq = j % q
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        left = _signed_sum(x_refs, lsgn_ref, ld, c)
        right = None
        for qt, ref in enumerate(s_refs):
            gr, gc = _symm_coords(rrow_ref, rcol_ref, rtrn_ref, ld, c, qt,
                                  q, k, jq)
            tile = ref[...].astype(jnp.float32)
            # the index map fetched the stored (max, min) tile; transpose
            # in VMEM whenever the conceptual read was above the diagonal
            # or the leaf itself was plan-mirrored
            mirrored = (rtrn_ref[ld, c, qt] != 0) | (gr < gc)
            tile = jnp.where(mirrored, tile.T, tile)
            if diag_sym:
                # the S + S^t operand: diagonal tiles double symmetrically
                tile = jnp.where(gr == gc, tile + tile.T, tile)
            term = tile * rsgn_ref[ld, c, qt].astype(jnp.float32)
            right = term if right is None else right + term
        acc_ref[...] += sgn.astype(jnp.float32) * jnp.dot(
            left, right, preferred_element_type=jnp.float32)

    @pl.when((c == n_c - 1) & (k == n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _symm_geometry(m: int, T: int, levels: int, variant: str, bm: int):
    """Level clamp + padded-row geometry for the symm executor (shared
    with ``ata_bwd_traffic_model``).  ``T`` is the packed stack's tile
    count per side; the column side cannot be padded (the stack layout is
    fixed), so levels clamp to divisors of T."""
    while levels > 0 and T % (1 << levels):
        levels -= 1
    levels = _fan_in_clamp("symm", plan_symm, levels, variant)
    plan = plan_symm(levels, variant)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bm) // B
    return {"plan": plan, "levels": levels, "M": B * mb,
            "nbm": mb // bm, "q": T // B}


def fused_symm_matmul(
    x: jax.Array,
    s_packed: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bm: int = 256,
    diag_sym: bool = False,
    out_dtype=None,
    interpret=None,
) -> jax.Array:
    """``x @ Sym`` via the flattened symm schedule, one fused kernel.

    ``s_packed`` is the packed lower-triangular tile stack of S —
    shape (T(T+1)/2 * bs, bs) in ``kernels.syrk`` / ``fused_ata_packed``
    ordering (the tile edge ``bs`` is read off the stack's trailing dim).

    * ``diag_sym=False``: Sym is the symmetric completion of the stack
      (diagonal tiles stored full); computes ``x @ Sym``.
    * ``diag_sym=True``: Sym = S + S^t with S the block-lower matrix the
      stack represents — the Gram-VJP operand.  Identical mirrored reads;
      diagonal tiles contribute ``tile + tile^t``.

    ``x`` is zero-padded on the right to the stack's T*bs columns (exact:
    the padded columns multiply rows of Sym that padded-A gradients
    discard) and on the bottom to leaf multiples.  Returns
    ``(x.shape[0], T*bs)``.

    Same fusion contract as the forward: operand sums and mirrored
    transposes live in VMEM only, fp32 VMEM accumulation, one HBM write
    per output tile, no dense Sym (or S + S^t) buffer ever exists.
    """
    interpret = _auto_interpret(interpret)
    if x.ndim != 2 or s_packed.ndim != 2:
        raise ValueError(f"bad ranks: {x.shape} x packed {s_packed.shape}")
    bs = s_packed.shape[1]
    if s_packed.shape[0] % bs:
        raise ValueError(f"packed stack {s_packed.shape} not a (bs, bs) "
                         "tile stack")
    n_tri = s_packed.shape[0] // bs
    T = (math.isqrt(8 * n_tri + 1) - 1) // 2
    if T * (T + 1) // 2 != n_tri:
        raise ValueError(f"stack of {n_tri} tiles is not triangular")
    N = T * bs
    m, nx = x.shape
    if nx > N:
        raise ValueError(f"x has {nx} cols but the stack spans {N}")
    if nx < N:
        x = jnp.pad(x, ((0, 0), (0, N - nx)))
    out_dtype = (jnp.promote_types(jnp.promote_types(x.dtype,
                                                     s_packed.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))

    geo = _symm_geometry(m, T, levels, variant, bm)
    plan, levels = geo["plan"], geo["levels"]
    B, M, nbm, q = plan.blocks, geo["M"], geo["nbm"], geo["q"]
    if M != m:
        x = jnp.pad(x, ((0, M - m), (0, 0)))
    n_k = q
    tmax, n_c = plan.max_terms, plan.max_contributions
    tables = _symm_tables(levels, variant)

    def left_map(p):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn,
                      rrow, rcol, rsgn, rtrn):
            ld = (i // nbm) * B + j // q
            return (lrow[ld, c, p] * nbm + i % nbm, lcol[ld, c, p] * q + k)
        return index_map

    def right_map(qt):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn,
                      rrow, rcol, rsgn, rtrn):
            ld = (i // nbm) * B + j // q
            gr, gc = _symm_coords(rrow, rcol, rtrn, ld, c, qt, q, k, j % q)
            # the mirror, folded into the index map: always fetch the
            # stored lower-triangle tile
            fr = jnp.maximum(gr, gc)
            fc = jnp.minimum(gr, gc)
            return (fr * (fr + 1) // 2 + fc, 0)
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(M // bm, T, n_c, n_k),
        in_specs=[pl.BlockSpec((bm, bs), left_map(p)) for p in range(tmax)]
        + [pl.BlockSpec((bs, bs), right_map(qt)) for qt in range(tmax)],
        out_specs=pl.BlockSpec((bm, bs), lambda i, j, c, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
    )
    kernel = functools.partial(_fused_symm_kernel, tmax=tmax, nbm=nbm, q=q,
                               n_c=n_c, n_k=n_k, blocks=B,
                               diag_sym=diag_sym)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*tables, *([x] * tmax), *([s_packed] * tmax))
    return out[:m]


# ---------------------------------------------------------------------------
# Analytic HBM traffic model for the fused ATA kernel.
#
# In interpret mode (CPU) the Pallas pipeline is *emulated* with XLA loops
# whose HLO carries full-array state buffers, so an HLO census of the
# interpret lowering measures the emulation, not the kernel.  On hardware
# the kernel's HBM behaviour is exact and simple by construction — grid
# DMA reads of A tiles, one write per packed output tile, and NO other
# HBM buffer (operand sums, M_i products and recombination temporaries
# live only in VMEM) — so we model it in closed form, the same way
# bench_roofline treats Pallas flash-attention FLOPs analytically.
# ---------------------------------------------------------------------------

def ata_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    bk: int = 256, bn: int = 256, in_bytes: int = 4, out_bytes: int = 4,
) -> dict:
    """HBM bytes of ``fused_ata_packed`` on an (m, n) input.

    Returns reads (streamed A-tile fetches, incl. padded null slots —
    the contribution axis is padded to ``max_contributions``, so the
    read term honestly reflects that amplification), writes (each packed
    output tile exactly once) and ``intermediate_bytes`` —
    HBM-materialized temporaries, which is just the zero-pad copy of A
    when the shape is not tile-aligned, and 0 otherwise.  Uses the same
    ``_ata_geometry`` as the executor, so the model cannot drift from
    the kernel's clamping/padding.
    """
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    plan, n_tri, n_k = geo["plan"], geo["n_tri"], geo["n_k"]
    M, N = geo["M"], geo["N"]
    grid = n_tri * plan.max_contributions * n_k
    reads = grid * 2 * plan.max_terms * bk * bn * in_bytes
    writes = n_tri * bn * bn * out_bytes
    pad_copy = M * N * in_bytes if (M, N) != (m, n) else 0
    return {
        "grid_steps": grid,
        "read_bytes": reads,
        "write_bytes": writes,
        "intermediate_bytes": pad_copy,
        "padded_shape": (M, N),
    }


def ata_bwd_traffic_model(
    m: int, n: int, *, levels: int = 2, variant: str = "strassen",
    bk: int = 256, bn: int = 256, in_bytes: int = 4, cot_bytes: int = 4,
    cotangent: str = "packed",
) -> dict:
    """HBM bytes of the Gram *backward* ``dA = A (S + S^t)`` on an (m, n)
    forward problem — the fused symm-schedule kernel vs the dense-dot
    baseline it replaces.  Shares ``_ata_geometry`` / ``_symm_geometry``
    with the executors, so the model cannot drift from their clamping.

    ``cotangent="packed"``: the cotangent arrives as the packed stack
    (``fused_ata_packed``'s VJP) and feeds the kernel directly — zero
    HBM intermediates beyond an optional pad copy of A.
    ``cotangent="dense"``: the dense entry point first gathers tril(g)
    into the packed stack (the stack — n(n+1)/2-ish bytes — is the only
    temporary).

    The baseline models what the dense-dot backward materializes
    semantically: ``tril(g)`` (select), ``S^t`` (transpose) and
    ``S + S^t`` (add) — three dense N^2 buffers.  An
    ``hbm_intermediate_census`` of its compiled HLO lands near this
    (XLA fusion may materialize fewer; the packed entry's unpack scatter
    adds more).  The fused read term honestly includes the
    contribution-slot padding amplification, same as the forward model.
    """
    geo = _ata_geometry(m, n, levels, variant, bk, bn)
    M, N = geo["M"], geo["N"]
    T = N // bn
    sgeo = _symm_geometry(M, T, geo["levels"], variant, bk)
    plan, q = sgeo["plan"], sgeo["q"]
    assert sgeo["M"] == M, (sgeo["M"], M)   # bwd reuses the forward padding
    grid = (M // bk) * T * plan.max_contributions * q
    reads = grid * plan.max_terms * (bk * bn * in_bytes
                                     + bn * bn * cot_bytes)
    writes = M * N * 4                       # dA in the fp32 accum dtype
    stack_bytes = T * (T + 1) // 2 * bn * bn * cot_bytes
    pad_copy = M * N * in_bytes if (M, N) != (m, n) else 0
    fused_inter = pad_copy + (stack_bytes if cotangent == "dense" else 0)
    dense_inter = 3 * N * N * cot_bytes
    return {
        "grid_steps": grid,
        "read_bytes": reads,
        "write_bytes": writes,
        "intermediate_bytes": fused_inter,
        "packed_stack_bytes": stack_bytes,
        "padded_shape": (M, N),
        "levels": sgeo["levels"],
        "dense_baseline": {
            "read_bytes": M * N * in_bytes + N * N * cot_bytes,
            "write_bytes": M * N * 4,
            "intermediate_bytes": dense_inter,
        },
        "intermediate_ratio_dense_over_fused": (
            dense_inter / fused_inter if fused_inter else None),
    }


# ---------------------------------------------------------------------------
# Fused Strassen matmul: C = A @ B, dense output.
# ---------------------------------------------------------------------------

def _fused_matmul_kernel(sign_ref, lrow_ref, lcol_ref, lsgn_ref,
                         rrow_ref, rcol_ref, rsgn_ref, *refs,
                         tmax: int, nbm: int, nbn: int, n_c: int, n_k: int,
                         blocks: int, trans_a: bool, trans_b: bool):
    a_refs = refs[:tmax]
    b_refs = refs[tmax:2 * tmax]
    o_ref, acc_ref = refs[2 * tmax], refs[2 * tmax + 1]
    i, j = pl.program_id(0), pl.program_id(1)
    c, k = pl.program_id(2), pl.program_id(3)
    ld = (i // nbm) * blocks + (j // nbn)
    sgn = sign_ref[ld, c]

    @pl.when((c == 0) & (k == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(sgn != 0)
    def _accumulate():
        # transposed operands are fetched mirrored (see the index maps)
        # and flipped in VMEM *after* the signed sum — (sum s_p X_p)^t =
        # sum s_p X_p^t, so one transpose serves the whole gather.
        left = _signed_sum(a_refs, lsgn_ref, ld, c)
        if trans_a:
            left = left.T
        right = _signed_sum(b_refs, rsgn_ref, ld, c)
        if trans_b:
            right = right.T
        acc_ref[...] += sgn.astype(jnp.float32) * jnp.dot(
            left, right, preferred_element_type=jnp.float32)

    @pl.when((c == n_c - 1) & (k == n_k - 1))
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    levels: int = 2,
    variant: str = "strassen",
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret=None,
    bwd: str = "fused",
) -> jax.Array:
    """``a @ b`` via the flattened Strassen schedule, one fused kernel.

    Same fusion contract as :func:`fused_ata_packed`: operand sums live in
    VMEM only, every output tile is written once, no ``M_i`` in HBM; the
    same level/fan-in clamps keep leaves at tile granularity and the
    operand gather inside VMEM.

    Differentiable: ``bwd="fused"`` (default) runs both VJP products
    through the same schedule executor with the transposes *folded into
    the index maps* (``da = g b^t`` fetches b tiles mirrored, ``db =
    a^t g`` fetches a tiles mirrored — neither transpose materializes in
    HBM), so the backward costs what the forward costs.  ``bwd="dense"``
    keeps the classical ``jnp.dot`` VJP.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes for matmul: {a.shape} x {b.shape}")
    interpret = _auto_interpret(interpret)
    out_dtype = (jnp.promote_types(jnp.promote_types(a.dtype, b.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    return _fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                              interpret, bwd)


def _fused_matmul_exec(a, b, levels, variant, bm, bk, bn, out_dtype,
                       interpret, trans_a=False, trans_b=False):
    """Schedule executor for C = op(a) @ op(b), op = transpose when the
    flag is set — the transpose is folded into the BlockSpec index maps
    (mirrored tile fetches) and undone tile-wise in VMEM, so no
    transposed copy of an operand ever exists in HBM."""
    m, k_dim = a.shape[::-1] if trans_a else a.shape
    n, _ = b.shape if trans_b else b.shape[::-1]
    levels = min(levels, strassen_levels_for(m, k_dim, n, max(bm, bk, bn)))
    levels = _fan_in_clamp("matmul", plan_matmul, levels, variant)
    plan = plan_matmul(levels, variant)
    B = plan.blocks
    mb = _round_up(max(m, 1), B * bm) // B
    kb = _round_up(max(k_dim, 1), B * bk) // B
    nb = _round_up(max(n, 1), B * bn) // B
    M, K, N = B * mb, B * kb, B * nb
    a_shape = (K, M) if trans_a else (M, K)
    b_shape = (N, K) if trans_b else (K, N)
    if a.shape != a_shape:
        a = jnp.pad(a, [(0, t - s) for s, t in zip(a.shape, a_shape)])
    if b.shape != b_shape:
        b = jnp.pad(b, [(0, t - s) for s, t in zip(b.shape, b_shape)])

    n_k = kb // bk
    nbm, nbn = mb // bm, nb // bn
    tmax, n_c = plan.max_terms, plan.max_contributions
    tables = _matmul_tables(levels, variant)

    def left_map(p):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            ld = (i // nbm) * B + j // nbn
            r = lrow[ld, c, p] * nbm + i % nbm
            kk = lcol[ld, c, p] * n_k + k
            return (kk, r) if trans_a else (r, kk)
        return index_map

    def right_map(q):
        def index_map(i, j, c, k, sign, lrow, lcol, lsgn, rrow, rcol, rsgn):
            ld = (i // nbm) * B + j // nbn
            kk = rrow[ld, c, q] * n_k + k
            cc = rcol[ld, c, q] * nbn + j % nbn
            return (cc, kk) if trans_b else (kk, cc)
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(M // bm, N // bn, n_c, n_k),
        in_specs=[pl.BlockSpec((bk, bm) if trans_a else (bm, bk),
                               left_map(p)) for p in range(tmax)]
        + [pl.BlockSpec((bn, bk) if trans_b else (bk, bn),
                        right_map(q)) for q in range(tmax)],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, c, k, *_: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_fused_matmul_kernel, tmax=tmax, nbm=nbm,
                               nbn=nbn, n_c=n_c, n_k=n_k, blocks=B,
                               trans_a=trans_a, trans_b=trans_b)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(*tables, *([a] * tmax), *([b] * tmax))
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9))
def _fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                       interpret, bwd):
    return _fused_matmul_exec(a, b, levels, variant, bm, bk, bn, out_dtype,
                              interpret)


def _fused_matmul_fwd(a, b, levels, variant, bm, bk, bn, out_dtype,
                      interpret, bwd):
    return (_fused_matmul_core(a, b, levels, variant, bm, bk, bn, out_dtype,
                               interpret, bwd), (a, b))


def _fused_matmul_bwd(levels, variant, bm, bk, bn, out_dtype, interpret,
                      bwd, res, g):
    a, b = res
    acc = jnp.promote_types(jnp.promote_types(a.dtype, b.dtype), jnp.float32)
    gf = g.astype(acc)
    if bwd == "dense":
        da = jnp.dot(gf, b.T.astype(acc), preferred_element_type=acc)
        db = jnp.dot(a.T.astype(acc), gf, preferred_element_type=acc)
    else:
        # the kernel upcasts tile-wise in VMEM, so bf16 residuals feed the
        # backward without an HBM-wide fp32 copy
        # da = g @ b^t — (m, n) x (n, k): K-dim is n, output cols k
        da = _fused_matmul_exec(gf, b, levels, variant,
                                bm, bn, bk, acc, interpret, trans_b=True)
        # db = a^t @ g — (k, m) x (m, n): K-dim is m, output rows k
        db = _fused_matmul_exec(a, gf, levels, variant,
                                bk, bm, bn, acc, interpret, trans_a=True)
    return da.astype(a.dtype), db.astype(b.dtype)


_fused_matmul_core.defvjp(_fused_matmul_fwd, _fused_matmul_bwd)
