"""Pallas TPU kernels for the ATA hot spots (validated in interpret mode).

- strassen_fused: the whole flattened ATA/Strassen schedule in one kernel
                  (leaf tasks x K blocks; no per-level HBM round-trips),
                  forward AND backward (packed-cotangent symm schedule)
- matmul:    tiled MXU matmul (ATA/HASA base case)
- syrk:      lower-triangular-blocks-only gram (the paper's n(n+1)/2 saving)
- combine:   fused Strassen recombination (HBM-traffic reduction)
- transpose: tiled transpose (cache-oblivious transpose analogue)
"""
from . import ops, ref
from .ops import (
    matmul, syrk, syrk_packed, strassen_combine, transpose,
    pallas_base_matmul, pallas_base_syrk,
    ata_fused, ata_fused_packed, matmul_fused, symm_matmul,
)

__all__ = ["ops", "ref", "matmul", "syrk", "syrk_packed", "strassen_combine",
           "transpose", "pallas_base_matmul", "pallas_base_syrk",
           "ata_fused", "ata_fused_packed", "matmul_fused", "symm_matmul"]
