"""Pallas TPU kernels for the ATA hot spots (validated in interpret mode).

- strassen_fused: ONE generic leaf-program executor (core/leaf_ir.py)
                  behind a single pallas_call — forward grams (ata AND
                  the 2021 aat row gram), matmul with trans folding,
                  the packed-cotangent symm backward, and the
                  accumulating rank-k update
- matmul:    tiled MXU matmul (ATA/HASA base case)
- syrk:      lower-triangular-blocks-only gram (the paper's n(n+1)/2 saving)
- combine:   fused Strassen recombination (HBM-traffic reduction)
- transpose: tiled transpose (cache-oblivious transpose analogue)
"""
from . import ops, ref
from .ops import (
    matmul, syrk, syrk_packed, strassen_combine, transpose,
    pallas_base_matmul, pallas_base_syrk,
    ata_fused, ata_fused_packed, aat_fused, aat_fused_packed,
    matmul_fused, symm_matmul, rank_k_update,
)

__all__ = ["ops", "ref", "matmul", "syrk", "syrk_packed", "strassen_combine",
           "transpose", "pallas_base_matmul", "pallas_base_syrk",
           "ata_fused", "ata_fused_packed", "aat_fused", "aat_fused_packed",
           "matmul_fused", "symm_matmul", "rank_k_update"]
