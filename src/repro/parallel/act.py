"""Activation-sharding constraints, decoupled from model code.

Model code calls ``constrain(x, "residual")`` etc.; the launcher installs an
:class:`ActivationSharding` policy (mesh + name->PartitionSpec) via
``use_activation_sharding``. With no policy installed the call is a no-op,
so unit tests and single-device runs never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_tls = threading.local()


@dataclass
class ActivationSharding:
    mesh: Mesh
    specs: Dict[str, P] = field(default_factory=dict)
    # MoE decode: keep expert weights STATIONARY (experts -> tp, FFN dim ->
    # fsdp axes); replicate the (tiny) token set into the MoE block and
    # psum the partial outputs — removes the per-step expert-bank gather.
    moe_stationary: bool = False
    fsdp_axes: tuple = ("data",)

    @classmethod
    def for_training(cls, mesh: Mesh, *, dp_axes=("pod", "data"),
                     tp_axis="model", sp: bool = True,
                     fsdp_axes=("data",)):
        """Standard policy: batch -> DP axes; residual embed dim unsharded;
        sequence -> TP axis between blocks (SP) when ``sp``; logits vocab ->
        TP axis."""
        dp = tuple(a for a in dp_axes if a in mesh.axis_names)
        specs = {
            "residual": P(dp, tp_axis if sp else None, None),
            "logits": P(dp, None, tp_axis),
        }
        return cls(mesh, specs, fsdp_axes=tuple(
            a for a in fsdp_axes if a in mesh.axis_names))

    @classmethod
    def for_decode(cls, mesh: Mesh, *, dp_axes=("pod", "data"),
                   tp_axis="model", fsdp_axes=("data",),
                   moe_stationary: bool = True):
        """Decode: seq dim is 1 — batch -> DP, no SP; logits vocab -> TP;
        stationary expert weights (see class docstring)."""
        dp = tuple(a for a in dp_axes if a in mesh.axis_names)
        specs = {
            "residual": P(dp, None, None),
            "logits": P(dp, None, tp_axis),
        }
        return cls(mesh, specs, moe_stationary=moe_stationary,
                   fsdp_axes=tuple(a for a in fsdp_axes
                                   if a in mesh.axis_names))


@contextlib.contextmanager
def use_activation_sharding(policy: Optional[ActivationSharding]):
    prev = getattr(_tls, "policy", None)
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def current_policy() -> Optional[ActivationSharding]:
    return getattr(_tls, "policy", None)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Apply the installed sharding constraint for logical tensor ``name``.

    Divisibility-checked: a dim whose size does not divide by its assigned
    axes is left unsharded (e.g. seq=1 in decode, tiny smoke shapes).
    """
    pol = current_policy()
    if pol is None or name not in pol.specs:
        return x
    spec = pol.specs[name]
    fixed = _fit_spec(spec, x.shape, pol.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, fixed))


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    out = []
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(part if dim % size == 0 and dim >= size else None)
    return P(*out)
