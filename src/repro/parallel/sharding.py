"""Parameter / batch / cache sharding rules (DP + FSDP + TP + EP).

``param_specs`` walks a parameter pytree and assigns a PartitionSpec per
leaf from name-based rules (Megatron column/row TP over 'model', FSDP over
'data' (optionally +'pod'), EP: experts over 'model'). Every assignment is
divisibility-checked — a dim that does not divide evenly falls back to
replicated, so the same rules serve full production configs and tiny smoke
configs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

# Stacked (scan-over-layers) param groups: leaves carry a leading L dim.
STACKED_GROUPS = {"blocks", "mla_dense", "mla_moe", "enc_blocks"}

# name -> (spec for 2-D [in, out]) with 'fsdp' / 'tp' placeholders
_COL = ("fsdp", "tp")     # column-parallel: output dim sharded over model
_ROW = ("tp", "fsdp")     # row-parallel: input dim sharded over model
RULES_2D = {
    "embed": ("tp", "fsdp"),
    "unembed": _COL,
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "w_gate": _COL, "w_up": _COL, "w_in": _COL,
    "w_down": _ROW, "w_out": _ROW,
    "w_dq": _COL, "w_uq": _COL, "w_dkv": _COL, "w_uk": _COL, "w_uv": _COL,
    "router": ("fsdp", None),
    "proj": _COL,
    "conv_w": (None, "tp"),
    "enc_pos": (None, None), "dec_pos": (None, None),
}
# MoE expert weights are 3-D (E, d, f): EP shards E over 'model'.
# Training: FSDP over the d dim (gathered on use, grads reduce-scatter).
RULES_MOE_3D = {
    "w_gate": ("ep", "fsdp", None),
    "w_up": ("ep", "fsdp", None),
    "w_down": ("ep", None, "fsdp"),
}
# Decode: STATIONARY layout — FFN dim over fsdp so the weights are consumed
# exactly as stored by the stationary-EP shard_map (no per-step gather).
RULES_MOE_3D_STATIONARY = {
    "w_gate": ("ep", None, "fsdp"),
    "w_up": ("ep", None, "fsdp"),
    "w_down": ("ep", "fsdp", None),
}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check(spec_dims, shape, mesh) -> P:
    out = []
    for dim, part in zip(shape, spec_dims):
        if part is not None and dim % _axis_size(mesh, part) == 0 \
                and dim >= _axis_size(mesh, part):
            out.append(part)
        else:
            out.append(None)
    return P(*out)


def param_specs(params, mesh: Mesh, *,
                fsdp_axes: Tuple[str, ...] = ("data",),
                tp_axis: str = "model",
                moe_stationary: bool = False) -> object:
    """PartitionSpec pytree matching ``params`` (same structure)."""
    fsdp = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    fsdp = fsdp if len(fsdp) != 1 else fsdp[0]
    subst = {"fsdp": fsdp, "tp": tp_axis, "ep": tp_axis, None: None}
    moe_rules = RULES_MOE_3D_STATIONARY if moe_stationary else RULES_MOE_3D

    def leaf_spec(path, leaf):
        keys = [k.key for k in path if isinstance(k, DictKey)]
        name = keys[-1] if keys else ""
        stacked = bool(keys) and keys[0] in STACKED_GROUPS
        shape = leaf.shape[1:] if stacked else leaf.shape
        in_moe = "moe" in keys

        if name in moe_rules and in_moe and len(shape) == 3:
            dims = [subst[d] for d in moe_rules[name]]
        elif name in RULES_2D and len(shape) == 2:
            dims = [subst[d] for d in RULES_2D[name]]
        elif len(shape) >= 2:
            dims = [subst["fsdp"]] + [None] * (len(shape) - 1)
        else:
            dims = [None] * len(shape)
        spec = _check(dims, shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return spec

    return tree_map_with_path(leaf_spec, params)


def batch_spec(mesh: Mesh, *, dp_axes=("pod", "data")) -> P:
    """(B, S) token batches: batch over all DP axes."""
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    return P(dp, None)


def _greedy(shape, mesh, prefs):
    """Assign axis groups to dims greedily with divisibility fallback.

    prefs: list of (axes, [dim indices in priority order]).
    """
    assigned = {}
    used_dims = set()
    for axes, candidates in prefs:
        size = _axis_size(mesh, axes)
        for d in candidates:
            if d in used_dims or d >= len(shape):
                continue
            if shape[d] % size == 0 and shape[d] >= size:
                assigned[d] = axes
                used_dims.add(d)
                break
    return P(*[assigned.get(i) for i in range(len(shape))])


def cache_specs(cache, mesh: Mesh, *, dp_axes=("pod", "data"),
                tp_axis: str = "model") -> object:
    """Decode-cache sharding: batch -> DP (falling back to seq for B=1 long
    contexts), heads/state -> TP (falling back to seq)."""
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)

    def leaf_spec(path, leaf):
        keys = [k.key for k in path if isinstance(k, DictKey)]
        name = keys[-1] if keys else ""
        sh = leaf.shape
        if name in ("k", "v", "ck", "cv"):       # (L, B, S, H, D)
            return _greedy(sh, mesh, [(dp, [1, 2]), (tp_axis, [3, 2, 4])])
        if name in ("ckv", "krope"):             # (L, B, S, r)
            # shard the SEQ dim over tp (flash-decode: local partial scores
            # + small softmax-stat psums) — never the latent r dim, which
            # would force a full cache gather per step.
            return _greedy(sh, mesh, [(dp, [1]), (tp_axis, [2])])
        if name == "ssm":                        # (L, B, H, P, N)
            return _greedy(sh, mesh, [(dp, [1]), (tp_axis, [2, 3, 4])])
        if name == "conv":                       # (L, B, W-1, C)
            return _greedy(sh, mesh, [(dp, [1]), (tp_axis, [3])])
        return P()                               # index & anything scalar

    return tree_map_with_path(leaf_spec, cache)


def to_named(tree_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda s: isinstance(s, P))
