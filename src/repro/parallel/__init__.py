"""Parallelism: mesh-aware parameter sharding rules + activation constraints.

DP over ('pod','data') (hierarchical across pods), FSDP parameter sharding
over 'data', TP (Megatron column/row) over 'model', EP (experts -> 'model')
for MoE, SP (sequence/activation sharding over 'model') for long context.
"""
from .act import ActivationSharding, constrain, use_activation_sharding  # noqa: F401
from .sharding import param_specs, batch_spec, cache_specs  # noqa: F401
