"""Request-scoped tracing: spans + instant events on one timeline.

The flight-recorder layer of the observability stack (DESIGN.md §14): a
thread-safe span API whose events land in a bounded ring buffer and
export to Chrome trace-event JSON (loadable in Perfetto / chrome://tracing)
and JSONL.

Design constraints, in order:

1. **Near-zero cost when disabled.**  Every hook in the serving hot path
   goes through the module-level helpers (:func:`span`, :func:`instant`,
   :func:`add_span`), which are a single attribute check when the tracer
   is off — no allocation, no lock, no timestamp read.  The default
   tracer starts disabled; chaos drills and ``--trace-out`` runs enable
   it.
2. **Request-scoped.**  A span carries a ``trace_id`` (the serving layer
   threads the request uid); children inherit it from the enclosing span
   (per-thread stack), so one request's submit → queue-wait → execute →
   verify → done chain is reconstructible from the buffer even though
   the events were emitted from batch-level code.
3. **Bounded.**  The buffer is a ring (``capacity`` events, default
   65536): a long-running service records the *recent* past, the flight
   recorder discipline, rather than growing without bound.
4. **Retroactive spans.**  Batch serving knows a request's queue wait
   only once the batch starts; :func:`add_span` emits a span with
   explicit start/end timestamps after the fact — Chrome trace events
   carry their own ``ts``/``dur``, so the export is indistinguishable
   from a live span.

All timestamps are ``time.perf_counter()`` (monotonic); the export
rebases them to microseconds since the tracer's epoch.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = [
    "TraceEvent", "Span", "Tracer", "get_tracer", "set_tracer",
    "span", "instant", "add_span", "tracing_enabled",
    "disabled_hook_cost",
]


@dataclass
class TraceEvent:
    """One recorded event: a completed span (``ph="X"``) or an instant
    (``ph="i"``)."""
    name: str
    ph: str                      # "X" complete span | "i" instant
    t0: float                    # perf_counter seconds
    t1: float                    # == t0 for instants
    span_id: int
    parent_id: Optional[int]
    trace_id: Optional[int]      # request uid (or None for engine-level)
    tid: int                     # thread ident
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Span:
    """A live span: context manager handed out by :meth:`Tracer.span`.

    ``annotate(**attrs)`` attaches attributes any time before exit;
    ``trace_id`` is inherited by child spans and instants opened on the
    same thread while this span is current.
    """

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "trace_id",
                 "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str,
                 trace_id: Optional[int], attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.span_id = tracer._next_id()
        self.parent_id: Optional[int] = None
        self.trace_id = trace_id
        self.attrs = attrs
        self.t0 = 0.0

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self._tracer._stack()
        if stack:
            parent = stack[-1]
            self.parent_id = parent.span_id
            if self.trace_id is None:
                self.trace_id = parent.trace_id
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:                  # tolerate exotic unwinding
            stack.remove(self)
        if exc_type is not None:
            self.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        self._tracer._record(TraceEvent(
            name=self.name, ph="X", t0=self.t0, t1=t1,
            span_id=self.span_id, parent_id=self.parent_id,
            trace_id=self.trace_id, tid=threading.get_ident(),
            attrs=self.attrs))
        return False


class _NullSpan:
    """The disabled-path span: every operation a no-op, one shared
    instance — ``span()`` on a disabled tracer allocates nothing."""

    __slots__ = ()
    name = ""
    span_id = -1
    parent_id = None
    trace_id = None
    attrs: Dict[str, Any] = {}

    def annotate(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe span/instant recorder over a bounded ring buffer."""

    def __init__(self, *, enabled: bool = False, capacity: int = 65536):
        self.enabled = enabled
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self.epoch = time.perf_counter()
        self.dropped = 0            # events evicted by the ring bound

    # -- internals --------------------------------------------------------
    def _next_id(self) -> int:
        with self._lock:
            return next(self._ids)

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _record(self, ev: TraceEvent) -> None:
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(ev)

    # -- recording API ----------------------------------------------------
    def span(self, name: str, *, trace_id: Optional[int] = None,
             **attrs) -> Span:
        """Context manager for a timed span.  When the tracer is
        disabled, returns the shared no-op span."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, trace_id, attrs)

    def instant(self, name: str, *, trace_id: Optional[int] = None,
                **attrs) -> None:
        """One point-in-time event (fault firing, guard veto, rung
        transition) on the same timeline as the spans."""
        if not self.enabled:
            return
        stack = self._stack()
        parent = stack[-1] if stack else None
        if trace_id is None and parent is not None:
            trace_id = parent.trace_id
        now = time.perf_counter()
        self._record(TraceEvent(
            name=name, ph="i", t0=now, t1=now, span_id=self._next_id(),
            parent_id=parent.span_id if parent else None,
            trace_id=trace_id, tid=threading.get_ident(), attrs=attrs))

    def instant_at(self, name: str, t: float, *,
                   trace_id: Optional[int] = None, **attrs) -> None:
        """An instant with an explicit ``perf_counter`` timestamp — for
        moments only recognized after the fact (a deadline miss is
        stamped at the deadline, not at detection).  Parentless, like
        ``add_span``: the emitting thread's stack is not the context the
        moment happened in."""
        if not self.enabled:
            return
        self._record(TraceEvent(
            name=name, ph="i", t0=t, t1=t, span_id=self._next_id(),
            parent_id=None, trace_id=trace_id,
            tid=threading.get_ident(), attrs=attrs))

    def add_span(self, name: str, t0: float, t1: float, *,
                 trace_id: Optional[int] = None, **attrs) -> None:
        """Record a span with explicit ``perf_counter`` endpoints — for
        intervals only known after the fact (queue wait, request
        lifetime)."""
        if not self.enabled:
            return
        self._record(TraceEvent(
            name=name, ph="X", t0=t0, t1=max(t1, t0),
            span_id=self._next_id(), parent_id=None, trace_id=trace_id,
            tid=threading.get_ident(), attrs=attrs))

    # -- introspection / export -------------------------------------------
    def events(self) -> List[TraceEvent]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    def __len__(self) -> int:
        return len(self._buf)

    def _us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``),
        loadable in Perfetto / chrome://tracing.

        Events are sorted by timestamp (the ring buffer holds them in
        *completion* order — a parent span completes after its children),
        so ``ts`` is monotonic per thread in the export.  ``pid`` is the
        constant serving process; ``tid`` the emitting thread; the
        request uid rides in ``args.trace_id``.
        """
        evs = sorted(self.events(), key=lambda e: e.t0)
        out = []
        for e in evs:
            args = {k: _jsonable(v) for k, v in e.attrs.items()}
            if e.trace_id is not None:
                args["trace_id"] = e.trace_id
            rec = {
                "name": e.name,
                "ph": e.ph,
                "ts": self._us(e.t0),
                "pid": 1,
                "tid": e.tid % (1 << 31),
                "args": args,
            }
            if e.ph == "X":
                rec["dur"] = max((e.t1 - e.t0) * 1e6, 0.001)
            else:
                rec["s"] = "t"           # thread-scoped instant
            out.append(rec)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write_chrome_trace(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def to_jsonl(self) -> str:
        """One JSON object per event, chronological — the grep-friendly
        export."""
        lines = []
        for e in sorted(self.events(), key=lambda ev: ev.t0):
            lines.append(json.dumps({
                "name": e.name, "ph": e.ph,
                "ts_us": self._us(e.t0),
                "dur_us": (e.t1 - e.t0) * 1e6 if e.ph == "X" else 0.0,
                "span_id": e.span_id, "parent_id": e.parent_id,
                "trace_id": e.trace_id, "tid": e.tid % (1 << 31),
                "attrs": {k: _jsonable(v) for k, v in e.attrs.items()},
            }))
        return "\n".join(lines) + ("\n" if lines else "")

    def write_jsonl(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


# ---------------------------------------------------------------------------
# The process-wide tracer + the hot-path helpers.
# ---------------------------------------------------------------------------

_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install a tracer as the process-wide one (None resets to a fresh
    disabled tracer).  Returns the installed tracer."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer(enabled=False)
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER.enabled


def span(name: str, *, trace_id: Optional[int] = None, **attrs):
    """Module-level hot-path hook: one attribute check when disabled."""
    t = _TRACER
    if not t.enabled:
        return _NULL_SPAN
    return Span(t, name, trace_id, attrs)


def instant(name: str, *, trace_id: Optional[int] = None, **attrs) -> None:
    t = _TRACER
    if not t.enabled:
        return
    t.instant(name, trace_id=trace_id, **attrs)


def instant_at(name: str, at: float, *,
               trace_id: Optional[int] = None, **attrs) -> None:
    t = _TRACER
    if not t.enabled:
        return
    t.instant_at(name, at, trace_id=trace_id, **attrs)


def add_span(name: str, t0: float, t1: float, *,
             trace_id: Optional[int] = None, **attrs) -> None:
    t = _TRACER
    if not t.enabled:
        return
    t.add_span(name, t0, t1, trace_id=trace_id, **attrs)


def disabled_hook_cost(n: int = 20000) -> float:
    """Measured seconds per *disabled* ``span()`` hook (enter + exit) —
    the unit cost the <2% tracer-overhead acceptance bound is derived
    from (hooks-per-request x this, over the per-request wall)."""
    saved = _TRACER.enabled
    try:
        _TRACER.enabled = False
        t0 = time.perf_counter()
        for _ in range(n):
            with span("probe"):
                pass
        dt = time.perf_counter() - t0
    finally:
        _TRACER.enabled = saved
    return dt / n
