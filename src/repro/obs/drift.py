"""Online cost-model drift detection (DESIGN.md §14).

The CAPS line of work (Ballard et al., arXiv 1202.3173) and the
Benson–Ballard practical-fast-matmul framework (arXiv 1409.2908) both
stress that a fast algorithm only pays off when it is *measured against
its model per configuration*.  This repo predicts every serving config's
cost in closed form (``core.cost_model``, the IR-driven traffic models
in ``kernels.strassen_fused``) and autotunes winners from those
predictions — but a persisted winner is a measurement of one moment: the
toolchain drifts, thermals drift, a neighbour tenant appears, and the
tuned config silently stops being the right one.

:class:`DriftDetector` keeps, per ``(key, channel)``, an EWMA of the
``measured / predicted`` ratio and flags keys whose ratio leaves the
``[1/theta, theta]`` band:

- channel ``"wall"`` — measured executable seconds vs predicted model
  *bytes*.  The units differ by an unknown machine constant
  (bytes/second), so findings normalize each key's ratio by the **median
  ratio across keys**: the constant cancels, and a bucket is flagged
  only when it deviates from how the model tracks the *rest of the
  fleet* — exactly the "this bucket's winner has drifted" signal, robust
  to the whole machine speeding up or slowing down.
- channel ``"traffic"`` — HLO-census HBM bytes vs traffic-model bytes.
  Same units, ratio ≈ 1 by construction when the model is honest, so
  the band applies directly (no normalization).

A finding is advisory: the serving layer surfaces it
(``GramEngine.stats()["drift"]``) and can hand it to
``gram.autotune.invalidate`` to drop the stale winner so the next
autotune re-measures (``GramEngine.invalidate_drifted``).
"""
from __future__ import annotations

import statistics
import threading
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple

__all__ = ["DriftRecord", "DriftFinding", "DriftDetector"]


@dataclass
class DriftRecord:
    """EWMA state for one (key, channel)."""
    ewma_ratio: float = 0.0
    n: int = 0
    last_measured: float = 0.0
    last_predicted: float = 0.0
    meta: dict = field(default_factory=dict)


@dataclass
class DriftFinding:
    key: Hashable
    channel: str                 # "wall" | "traffic"
    ratio: float                 # the flagged (normalized) ratio
    raw_ratio: float             # the un-normalized EWMA measured/predicted
    n: int                       # samples behind the EWMA
    theta: float
    meta: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"key": str(self.key), "channel": self.channel,
                "ratio": self.ratio, "raw_ratio": self.raw_ratio,
                "n": self.n, "theta": self.theta, **self.meta}


class DriftDetector:
    """Per-(key, channel) EWMA of measured/predicted with a theta band.

    ``alpha`` is the EWMA weight of the newest sample; ``min_samples``
    gates findings (one noisy first batch must not quarantine a
    winner).  Thread-safe: the engine observes from its serving thread,
    scrapes read from anywhere.
    """

    def __init__(self, *, theta: float = 2.0, alpha: float = 0.25,
                 min_samples: int = 3):
        if theta <= 1.0:
            raise ValueError(f"theta must be > 1, got {theta}")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.theta = theta
        self.alpha = alpha
        self.min_samples = max(1, min_samples)
        self._lock = threading.Lock()
        self._records: Dict[Tuple[Hashable, str], DriftRecord] = {}

    # -- observation ------------------------------------------------------
    def observe(self, key: Hashable, *, measured: float, predicted: float,
                channel: str = "wall", **meta) -> Optional[float]:
        """Fold one (measured, predicted) pair in; returns the updated
        EWMA ratio (None when the pair is unusable — non-positive values
        carry no ratio information and are dropped)."""
        if not (measured > 0 and predicted > 0):
            return None
        r = measured / predicted
        with self._lock:
            rec = self._records.get((key, channel))
            if rec is None:
                rec = self._records[(key, channel)] = DriftRecord()
            if rec.n == 0:
                rec.ewma_ratio = r
            else:
                rec.ewma_ratio = ((1 - self.alpha) * rec.ewma_ratio
                                  + self.alpha * r)
            rec.n += 1
            rec.last_measured = measured
            rec.last_predicted = predicted
            if meta:
                rec.meta.update(meta)
            return rec.ewma_ratio

    # -- introspection ----------------------------------------------------
    def record(self, key: Hashable, channel: str = "wall"
               ) -> Optional[DriftRecord]:
        with self._lock:
            return self._records.get((key, channel))

    def ratios(self, channel: str = "wall") -> Dict[Hashable, float]:
        with self._lock:
            return {k: rec.ewma_ratio
                    for (k, ch), rec in self._records.items()
                    if ch == channel}

    def _mature(self, channel: str) -> Dict[Hashable, DriftRecord]:
        with self._lock:
            return {k: rec for (k, ch), rec in self._records.items()
                    if ch == channel and rec.n >= self.min_samples}

    def findings(self, channel: Optional[str] = None) -> List[DriftFinding]:
        """Keys whose (normalized) ratio left ``[1/theta, theta]``.

        ``channel=None`` scans both channels.  The ``"wall"`` channel
        normalizes by the cross-key median (module docstring) — with
        fewer than two mature keys it cannot flag anything, by design:
        one bucket cannot be distinguished from the machine constant.
        """
        channels = (channel,) if channel else ("wall", "traffic")
        out: List[DriftFinding] = []
        for ch in channels:
            mature = self._mature(ch)
            if not mature:
                continue
            if ch == "wall":
                if len(mature) < 2:
                    continue
                med = statistics.median(
                    rec.ewma_ratio for rec in mature.values())
                if med <= 0:
                    continue
                norm = {k: rec.ewma_ratio / med
                        for k, rec in mature.items()}
            else:
                norm = {k: rec.ewma_ratio for k, rec in mature.items()}
            for k, ratio in sorted(norm.items(), key=lambda kv: str(kv[0])):
                if not (1.0 / self.theta <= ratio <= self.theta):
                    rec = mature[k]
                    out.append(DriftFinding(
                        key=k, channel=ch, ratio=ratio,
                        raw_ratio=rec.ewma_ratio, n=rec.n,
                        theta=self.theta, meta=dict(rec.meta)))
        return out

    def stale_keys(self, channel: Optional[str] = None) -> List[Hashable]:
        return [f.key for f in self.findings(channel)]

    def reset(self, key: Hashable = None,
              channel: Optional[str] = None) -> None:
        """Forget state — everything, one key, or one (key, channel)
        (after a winner is invalidated its history is meaningless)."""
        with self._lock:
            if key is None and channel is None:
                self._records.clear()
                return
            drop = [kc for kc in self._records
                    if (key is None or kc[0] == key)
                    and (channel is None or kc[1] == channel)]
            for kc in drop:
                del self._records[kc]

    def snapshot(self) -> dict:
        """JSON-friendly dump of every record + current findings."""
        with self._lock:
            records = {
                f"{k}|{ch}": {"ewma_ratio": rec.ewma_ratio, "n": rec.n,
                              "last_measured": rec.last_measured,
                              "last_predicted": rec.last_predicted,
                              **rec.meta}
                for (k, ch), rec in self._records.items()}
        return {"theta": self.theta, "alpha": self.alpha,
                "min_samples": self.min_samples, "records": records,
                "findings": [f.as_dict() for f in self.findings()]}
