"""Observability: the flight recorder for the Gram service (DESIGN.md §14).

Three layers, one timeline:

- ``trace``   — request-scoped spans + instant events in a bounded ring
                buffer; Chrome trace-event JSON (Perfetto-loadable) and
                JSONL export.  Near-zero cost when disabled.
- ``metrics`` — process-wide registry of counters / gauges /
                log-bucketed histograms with (bucket, dtype, gram_of,
                scheme, rung) labels; Prometheus-style text snapshots.
- ``drift``   — online cost-model drift detection: EWMA of the
                measured/predicted ratio per (bucket, winner), findings
                when a bucket leaves the ``[1/theta, theta]`` band.

The paper's claims are quantitative (2/7·n^log2(7) products, minimal
messages); ``cost_model`` / ``ata_traffic_model`` predict them, and this
package makes the prediction-vs-reality comparison a continuously
running, inspectable part of the serving stack.
"""
from . import drift, metrics, trace  # noqa: F401
from .drift import DriftDetector, DriftFinding  # noqa: F401
from .metrics import (  # noqa: F401
    MetricsRegistry, counter, gauge, histogram, get_registry,
    render_prometheus, snapshot,
)
from .trace import (  # noqa: F401
    Tracer, get_tracer, set_tracer, span, instant, add_span,
    tracing_enabled,
)

__all__ = [
    "trace", "metrics", "drift",
    "Tracer", "get_tracer", "set_tracer", "span", "instant", "add_span",
    "tracing_enabled",
    "MetricsRegistry", "counter", "gauge", "histogram", "get_registry",
    "render_prometheus", "snapshot",
    "DriftDetector", "DriftFinding",
]
