"""Process-wide metrics registry: counters, gauges, log-bucketed histograms.

The second observability layer (DESIGN.md §14): every serving-path count
the engine used to keep as an ad-hoc attribute — queue depth, batch fill
fraction, recompiles, cache hits, guard vetoes, retries, per-rung served
counts — lands in ONE registry, labeled by the dimensions the Gram
service actually varies over: ``(bucket, dtype, gram_of, scheme, rung)``.

Three instrument kinds:

- :class:`Counter` — monotone; ``inc(amount, **labels)``.
- :class:`Gauge`   — settable; ``set(v, **labels)`` / ``inc`` / ``dec``.
- :class:`Histogram` — **log-bucketed**: bucket ``k`` holds values in
  ``[lo * base^k, lo * base^(k+1))``.  An observation is one integer
  increment, so percentile reads are O(num_buckets) and *updates are
  O(1)* — the property ``GramEngine.stats()`` needs to stop re-sorting
  its full latency history on every call.  Quantiles interpolate
  geometrically inside the winning bucket (exact to within one bucket
  ratio, base 2^(1/4) ≈ 19% by default — telemetry resolution, not
  measurement resolution).

Labeled children are created on first touch; a label *schema* is pinned
by the first observation (inconsistent label names raise — silent label
drift makes snapshots unmergeable).  ``snapshot()`` returns a plain
nested dict; :func:`render_prometheus` emits the Prometheus text format
(counters get a ``_total`` suffix; histograms export ``_bucket`` /
``_sum`` / ``_count`` with cumulative ``le`` edges).

The module-level registry is process-wide by design — one scrape shows
every engine in the process; per-engine views label their series with an
``engine`` id.  Tests isolate themselves with :func:`reset` or a local
:class:`MetricsRegistry`.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_registry", "counter", "gauge", "histogram",
    "snapshot", "render_prometheus", "reset",
]

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(names: Tuple[str, ...], labels: dict) -> _LabelKey:
    if tuple(sorted(labels)) != names:
        raise ValueError(
            f"label names {tuple(sorted(labels))} do not match the "
            f"metric's schema {names}")
    return tuple((k, str(labels[k])) for k in names)


class _Metric:
    """Shared label-handling core."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._names: Optional[Tuple[str, ...]] = None   # pinned on 1st use
        self._series: Dict[_LabelKey, object] = {}

    def _key(self, labels: dict) -> _LabelKey:
        if self._names is None:
            self._names = tuple(sorted(labels))
        return _label_key(self._names, labels)

    def series(self) -> Dict[_LabelKey, object]:
        with self._lock:
            return dict(self._series)


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            k = self._key(labels)
            self._series[k] = self._series.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            if self._names is None:
                return 0.0
            return self._series.get(_label_key(self._names, labels), 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._series.values())


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        with self._lock:
            k = self._key(labels)
            self._series[k] = self._series.get(k, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            if self._names is None:
                return 0.0
            return self._series.get(_label_key(self._names, labels), 0.0)


class _HistSeries:
    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self, nbuckets: int):
        self.counts = [0] * (nbuckets + 2)   # [underflow] + buckets + [over]
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Log-bucketed histogram: O(1) observe, O(buckets) quantile.

    ``lo`` is the lower edge of the first bucket, ``hi`` the upper edge
    of the last; values outside land in under/overflow buckets whose
    quantile estimate clamps to the edge.  Defaults cover 1µs..~1000s at
    2^(1/4) resolution — the serving latency range.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-6,
                 hi: float = 1e3, base: float = 2 ** 0.25):
        super().__init__(name, help)
        if not (lo > 0 and hi > lo and base > 1):
            raise ValueError("need 0 < lo < hi and base > 1")
        self.lo, self.base = lo, base
        self.nbuckets = int(math.ceil(math.log(hi / lo, base)))
        # upper edges, ascending
        self.edges = [lo * base ** (k + 1) for k in range(self.nbuckets)]

    def _bucket(self, v: float) -> int:
        """Index into the counts array (0 = underflow, nbuckets+1 = over)."""
        if v < self.lo:
            return 0
        idx = int(math.log(v / self.lo, self.base))
        return min(idx, self.nbuckets - 1) + 1 \
            if idx < self.nbuckets else self.nbuckets + 1

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        with self._lock:
            k = self._key(labels)
            s = self._series.get(k)
            if s is None:
                s = self._series[k] = _HistSeries(self.nbuckets)
            s.counts[self._bucket(v)] += 1
            s.sum += v
            s.count += 1
            s.min = min(s.min, v)
            s.max = max(s.max, v)

    def _merged(self, labels: Optional[dict]) -> Optional[_HistSeries]:
        """Merge every series whose labels are a superset of ``labels``
        (``None`` / ``{}`` merges all) — so a per-engine percentile is
        ``quantile(q, {"engine": "e0"})`` over (engine, bucket) series."""
        with self._lock:
            want = tuple((k, str(v)) for k, v in sorted((labels or {}).items()))
            picked = [s for key, s in self._series.items()
                      if all(kv in key for kv in want)]
            if not picked:
                return None
            if len(picked) == 1:
                return picked[0]
            out = _HistSeries(self.nbuckets)
            for s in picked:
                out.counts = [a + b for a, b in zip(out.counts, s.counts)]
                out.sum += s.sum
                out.count += s.count
                out.min = min(out.min, s.min)
                out.max = max(out.max, s.max)
            return out

    def quantile(self, q: float, labels: Optional[dict] = None
                 ) -> Optional[float]:
        """q-quantile estimate (geometric interpolation inside the
        winning bucket).  ``labels=None`` merges every labeled series —
        the engine-wide percentile."""
        s = self._merged(labels)
        if s is None or s.count == 0:
            return None
        rank = q * (s.count - 1)
        acc = 0
        for i, c in enumerate(s.counts):
            if c == 0:
                continue
            acc += c
            # bucket i covers sorted indices [acc - c, acc); take the
            # bucket holding index ceil(rank) (upper nearest-rank)
            if acc - 1 >= rank:
                if i == 0:
                    return s.min if math.isfinite(s.min) else self.lo
                if i == self.nbuckets + 1:
                    return s.max if math.isfinite(s.max) else self.edges[-1]
                hi = self.edges[i - 1]
                lo = hi / self.base
                est = math.sqrt(lo * hi)
                # clamp to the observed range: a one-sample histogram
                # must answer with that sample's bucket, not beyond it
                return min(max(est, s.min), s.max)
        return s.max

    def count(self, labels: Optional[dict] = None) -> int:
        s = self._merged(labels)
        return 0 if s is None else s.count

    def sum(self, labels: Optional[dict] = None) -> float:
        s = self._merged(labels)
        return 0.0 if s is None else s.sum


class MetricsRegistry:
    """Name -> instrument map; instruments are created on first request
    and must keep their kind (a ``counter`` name cannot be re-registered
    as a gauge)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        return self._get(Histogram, name, help, **kw)

    def metrics(self) -> Dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    # -- export -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain nested dict of every series:
        ``{name: {kind, help, series: {label-string: value-or-hist}}}``."""
        out = {}
        for name, m in sorted(self.metrics().items()):
            series = {}
            for key, v in m.series().items():
                lbl = ",".join(f"{k}={val}" for k, val in key) or ""
                if isinstance(v, _HistSeries):
                    series[lbl] = {"count": v.count, "sum": v.sum,
                                   "min": v.min if v.count else None,
                                   "max": v.max if v.count else None}
                else:
                    series[lbl] = v
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition of the whole registry."""
        lines: List[str] = []
        for name, m in sorted(self.metrics().items()):
            pname = name + ("_total" if m.kind == "counter"
                            and not name.endswith("_total") else "")
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            lines.append(f"# TYPE {pname} {m.kind}")
            for key, v in sorted(m.series().items()):
                lbl = ",".join(f'{k}="{val}"' for k, val in key)
                if isinstance(v, _HistSeries):
                    acc = 0
                    for i, edge in enumerate(m.edges):
                        acc += v.counts[i + 1] + (v.counts[0] if i == 0
                                                 else 0)
                        le = f'le="{edge:g}"'
                        full = f"{lbl},{le}" if lbl else le
                        lines.append(f"{name}_bucket{{{full}}} {acc}")
                    le = 'le="+Inf"'
                    full = f"{lbl},{le}" if lbl else le
                    lines.append(f"{name}_bucket{{{full}}} {v.count}")
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{suffix} {v.sum:g}")
                    lines.append(f"{name}_count{suffix} {v.count}")
                else:
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{pname}{suffix} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# The process-wide registry + convenience accessors.
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    global _REGISTRY
    _REGISTRY = reg if reg is not None else MetricsRegistry()
    return _REGISTRY


def counter(name: str, help: str = "") -> Counter:
    return _REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _REGISTRY.gauge(name, help)


def histogram(name: str, help: str = "", **kw) -> Histogram:
    return _REGISTRY.histogram(name, help, **kw)


def snapshot() -> dict:
    return _REGISTRY.snapshot()


def render_prometheus() -> str:
    return _REGISTRY.render_prometheus()


def reset() -> None:
    _REGISTRY.clear()
