"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) — computed with a
counter-based Philox generator — so a restart at step k reproduces the
exact stream with NO saved iterator state beyond the step integer, and a
different data-parallel topology reads identical global batches (elastic
restarts keep the data order bit-exact).

Two generators:
  * "markov": a noisy affine token chain x_{t+1} = (a*x_t + b + noise) mod V
    with per-sequence (a, b) — learnable structure so example training runs
    show loss decreasing;
  * "uniform": i.i.d. uniform tokens (pure-throughput benchmarking).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "markov"          # markov | uniform
    noise: float = 0.05           # markov corruption rate
    enc_seq: int = 0              # >0: also emit enc_inputs (B, enc_seq, enc_dim)
    enc_dim: int = 0


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=[seed, step]))


def get_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Batch for ``step``: {"inputs","labels"} (B, S) int32 [+ enc_inputs]."""
    rng = _rng(cfg.seed, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    if cfg.kind == "uniform":
        toks = rng.integers(0, v, size=(b, s + 1), dtype=np.int64)
    else:
        # ONE affine successor map per seed (a learnable V->V lookup);
        # sequences start at random tokens.
        map_rng = _rng(cfg.seed, 2**31 - 1)
        a = int(map_rng.integers(1, max(v - 1, 2)))
        c = int(map_rng.integers(0, v))
        x0 = rng.integers(0, v, size=(b,))
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, 0] = x0
        for t in range(s):
            toks[:, t + 1] = (a * toks[:, t] + c) % v
        flip = rng.random((b, s + 1)) < cfg.noise
        toks = np.where(flip, rng.integers(0, v, size=(b, s + 1)), toks)
    batch = {"inputs": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32)}
    if cfg.enc_seq:
        batch["enc_inputs"] = rng.normal(
            0, 1, size=(b, cfg.enc_seq, cfg.enc_dim)).astype(np.float32)
    return batch


class SyntheticStream:
    """Stateful iterator facade; state == the step integer (resumable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        batch = get_batch(self.cfg, self.step)
        self.step += 1
        return batch

    @property
    def state(self) -> int:
        return self.step

    def restore(self, step: int) -> "SyntheticStream":
        self.step = step
        return self
