from .pipeline import DataConfig, SyntheticStream, get_batch  # noqa: F401
