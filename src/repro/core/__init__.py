"""Core library: the paper's Strassen-based A^tA contribution in JAX."""
from .ata import ata, ata_full, ata_levels_for
from .strassen import strassen_matmul, strassen_levels_for
from .symmetry import (
    pack_tril, unpack_tril, pack_tril_blocks, unpack_tril_blocks,
    symmetrize_from_lower, tri_count, tri_index, tri_coords,
)
from .distributed import (
    gram_allreduce, gram_reducescatter, gram_ring, gram_bfs25d,
    distributed_gram, ring_layout_coords, assemble_ring_gram,
    ring_stack_len, feasible_schemes, default_gram_axes,
)
from .schedule import (
    plan_ata, plan_matmul, evaluate_ata_plan, evaluate_matmul_plan,
)
from .leaf_ir import (
    compile_program, interpret_program, register_algebra,
    registered_algebras, PROGRAM_KINDS,
)
from . import cost_model, leaf_ir, schedule

__all__ = [
    "ata", "ata_full", "ata_levels_for",
    "strassen_matmul", "strassen_levels_for",
    "plan_ata", "plan_matmul", "evaluate_ata_plan", "evaluate_matmul_plan",
    "schedule", "leaf_ir",
    "compile_program", "interpret_program", "register_algebra",
    "registered_algebras", "PROGRAM_KINDS",
    "pack_tril", "unpack_tril", "pack_tril_blocks", "unpack_tril_blocks",
    "symmetrize_from_lower", "tri_count", "tri_index", "tri_coords",
    "gram_allreduce", "gram_reducescatter", "gram_ring", "gram_bfs25d",
    "distributed_gram", "ring_layout_coords", "assemble_ring_gram",
    "ring_stack_len", "feasible_schemes", "default_gram_axes",
    "cost_model",
]
