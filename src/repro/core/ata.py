"""ATA: the paper's cache-oblivious Strassen-based algorithm for C = A^t A.

Algorithm 1 of the paper, adapted for TPU (DESIGN.md §2):

    split A into quadrants A11 A12 / A21 A22, then
      C11 = ATA(A11) + ATA(A21)                  (recursive, symmetric)
      C22 = ATA(A12) + ATA(A22)                  (recursive, symmetric)
      C21 = HASA(A12^t, A11) + HASA(A22^t, A21)  (rectangular Strassen)
      C12 = C21^t                                (never computed)

Only the lower triangle is computed; multiplication count is upper-bounded
by (2/7) n^{log2 7} (paper §3.1) versus n^2(n+1)/2 classical.

Two execution modes (DESIGN.md §4):

* ``mode="fused"`` — the hot path.  The recursion is flattened at trace
  time into a leaf-task schedule (``core/schedule.py``) and executed by a
  single Pallas kernel (``kernels/strassen_fused.py``): operand sums live
  in VMEM, products accumulate in fp32 VMEM scratch, and each packed
  lower-triangular output block is written to HBM exactly once.
* ``mode="reference"`` — the original trace-time recursion, capped at
  ``levels``.  Materializes per-level temporaries in HBM; kept as the
  numerical oracle, for autodiff, and for custom ``base_syrk`` /
  ``base_matmul`` hooks.

``mode="auto"`` picks fused on TPU (reference when custom leaf hooks are
given, which the fused schedule cannot honor) and reference elsewhere.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from .strassen import (
    strassen_matmul, resolve_mode, AUTO_MAX_LEVELS, DEFAULT_LEAF,
    DEFAULT_LEVELS,
)
from .symmetry import symmetrize_from_lower

__all__ = ["ata", "ata_full", "ata_levels_for"]


def _default_base_syrk(a: jax.Array) -> jax.Array:
    """Classical leaf gram with >=fp32 accumulation (lower triangle kept)."""
    acc = jnp.promote_types(a.dtype, jnp.float32)
    return jnp.tril(jnp.dot(a.T, a, preferred_element_type=acc))


def ata(
    a: jax.Array,
    *,
    gram_of: str = "cols",
    levels: Union[int, str] = DEFAULT_LEVELS,
    leaf: int = DEFAULT_LEAF,
    variant: str = "strassen",
    gram: str = "strassen",
    base_syrk: Optional[Callable] = None,
    base_matmul: Optional[Callable] = None,
    mode: str = "auto",
    bwd: str = "fused",
    out_dtype=None,
    block: Optional[int] = None,
    interpret: Optional[bool] = None,
    pipeline_depth: Optional[int] = None,
    operand_dtype=None,
    acc_dtype=None,
    sr_seed: Optional[int] = None,
) -> jax.Array:
    """Lower triangle of ``a.T @ a`` via the paper's ATA recursion.

    Args:
      a: (m, n) array — general rectangular, any size.
      gram_of: which gram to compute — ``"cols"`` (default, the paper's
        ``tril(a.T @ a)``, an (n, n) result) or ``"rows"``
        (``tril(a @ a.T)``, an (m, m) result — the Arrigoni-Massini 2021
        transpose-gram recursion).  On the fused path ``"rows"`` runs
        the dedicated ``aat`` leaf program: the transpose of ``a`` never
        materializes in HBM.  The reference recursion computes it as
        ``ATA(a.T)`` (the identity the 2021 paper exploits), which is
        the oracle but does materialize the transpose.  NOTE: the row
        gram currently differentiates through the dense-dot VJP
        (``dA = (S + S^t) A`` — a symmetric-LEFT product the symm
        program does not yet express), so ``bwd=`` applies to the
        ``"cols"`` path only.
      levels: recursion depth cap (0 => classical SYRK), or ``"auto"`` to
        recurse until a dimension reaches ``leaf`` (capped at
        ``AUTO_MAX_LEVELS`` — see strassen.py for the rationale).
      leaf: stop recursing when m or n <= leaf (paper: 32; TPU: 256).
        Reference mode only (the fused schedule unrolls exactly ``levels``);
        also sets the ``levels="auto"`` depth for both modes.
      variant: Strassen variant for the off-diagonal C21 products
        (any registered algebra — "strassen" | "winograd" | "classical"
        by default; ``leaf_ir.registered_algebras()``).
      gram: registered gram algebra for the symmetric decomposition on
        the FUSED path ("strassen" = the paper's 4-gram + 2-product
        recursion, "dps" = the Dumas-Pernet-Sedoglavic-shaped 5-product
        scheme; ``leaf_ir.registered_gram_algebras()``).  The reference
        recursion is the paper's fixed oracle and ignores it.
      base_syrk: leaf gram fn (n-triangular); default jnp, or Pallas syrk.
        Forces reference mode under ``mode="auto"``.
      base_matmul: leaf matmul for the HASA calls.  Same.
      mode: "auto" | "fused" | "reference" (see module docstring).
      bwd: VJP engine for the fused path — "fused" (default: the
        packed-cotangent symm-schedule kernel, DESIGN.md §11) or "dense"
        (the classical ``A (S + S^t)`` dense-dot baseline).  Reference
        mode differentiates through the recursion and ignores this.
      out_dtype: result dtype.  Defaults to the *promoted accumulation
        dtype* — fp32 for bf16/fp32 inputs — instead of silently
        downcasting fp32-accumulated results back to the input dtype
        (Strassen recombination loses ~1 bit/level; see strassen.py).
      block: Pallas tile edge for the fused path (bk = bn = block);
        ``None`` consults the gram autotune cache for this shape bucket
        (256 when untuned).
      interpret: Pallas interpret-mode override for the fused path
        (default: interpret off-TPU).
      pipeline_depth: revolving-buffer DMA pipeline depth for the fused
        path (DESIGN.md §16).  ``None`` = backend default (2 compiled,
        1 interpret); 1 reproduces the unpipelined grid walk bit-exactly.
      operand_dtype: quantize operand tiles to this dtype (fp8 e4m3/e5m2,
        bf16, ...) before the kernel; accumulation stays >=fp32.  Fused
        path only; ``None`` keeps the native operand dtype.
      acc_dtype: VMEM accumulator storage dtype on the fused path
        (default fp32).
      sr_seed: when set (with bf16 ``out_dtype``), apply deterministic
        stochastic rounding to the fused Gram output under this seed.

    Returns:
      (n, n) array, strictly upper triangle zeroed, dtype ``out_dtype``.
    """
    if a.ndim != 2:
        raise ValueError(f"ata expects a matrix, got shape {a.shape}")
    if gram_of not in ("cols", "rows"):
        raise ValueError(f"gram_of must be 'cols' or 'rows', got "
                         f"{gram_of!r}")
    m, n = a.shape
    if levels == "auto":
        levels = min(ata_levels_for(m, n, leaf), AUTO_MAX_LEVELS)
    out_dtype = (jnp.promote_types(a.dtype, jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    mode = resolve_mode(mode, base_syrk, base_matmul)
    if mode != "fused" and operand_dtype is not None:
        # Reference oracle for quantized operands: quantize once, then
        # recurse in the promoted compute dtype (the fused kernel upcasts
        # quantized tiles to fp32 before every signed sum / dot).
        a = a.astype(jnp.dtype(operand_dtype)).astype(
            jnp.promote_types(a.dtype, jnp.float32))
    if gram_of == "rows":
        if mode == "fused":
            from ..kernels.ops import aat_fused
            return aat_fused(a, levels=levels, variant=variant, gram=gram,
                             bm=block, bk=block, out_dtype=out_dtype,
                             interpret=interpret,
                             pipeline_depth=pipeline_depth,
                             operand_dtype=operand_dtype,
                             acc_dtype=acc_dtype, sr_seed=sr_seed)
        # reference oracle: AAT(A) = ATA(A^t) — the 2021 paper's identity
        syrk = base_syrk or _default_base_syrk
        out = _ata_rec(a.T, levels, leaf, variant, syrk, base_matmul)
        return out.astype(out_dtype)
    if mode == "fused":
        from ..kernels.ops import ata_fused
        return ata_fused(a, levels=levels, variant=variant, gram=gram,
                         bk=block, bn=block, out_dtype=out_dtype,
                         interpret=interpret, bwd=bwd,
                         pipeline_depth=pipeline_depth,
                         operand_dtype=operand_dtype, acc_dtype=acc_dtype,
                         sr_seed=sr_seed)
    syrk = base_syrk or _default_base_syrk
    out = _ata_rec(a, levels, leaf, variant, syrk, base_matmul)
    return out.astype(out_dtype)


def _ata_rec(a, levels, leaf, variant, syrk, base_matmul):
    m, n = a.shape
    # Base case (paper: m or n <= 32; TPU leaf rescaled).
    if levels <= 0 or m <= leaf or n <= leaf:
        return syrk(a)

    # Pad odd dims (exact: zero rows of A add nothing to A^tA; zero cols add
    # zero rows+cols to C, sliced away below).
    pm, pn = m % 2, n % 2
    ap = jnp.pad(a, ((0, pm), (0, pn))) if (pm or pn) else a
    mp, np_ = ap.shape
    m2, n2 = mp // 2, np_ // 2

    a11 = ap[:m2, :n2]
    a12 = ap[:m2, n2:]
    a21 = ap[m2:, :n2]
    a22 = ap[m2:, n2:]

    rec = lambda x: _ata_rec(x, levels - 1, leaf, variant, syrk, base_matmul)

    # C11, C22: sums of two symmetric recursive grams (lines 7-10, Alg. 1).
    c11 = rec(a11) + rec(a21)
    c22 = rec(a12) + rec(a22)

    # C21: two generalized-Strassen rectangular products (lines 11-12).
    c21 = strassen_matmul(
        a12.T, a11, levels=levels - 1, leaf=leaf, variant=variant,
        base_matmul=base_matmul, mode="reference",
    ) + strassen_matmul(
        a22.T, a21, levels=levels - 1, leaf=leaf, variant=variant,
        base_matmul=base_matmul, mode="reference",
    )

    top = jnp.concatenate([c11, jnp.zeros((n2, np_ - n2), c11.dtype)], axis=1)
    bot = jnp.concatenate([c21.astype(c11.dtype), c22], axis=1)
    c = jnp.concatenate([top, bot], axis=0)
    return c[:n, :n]


def ata_full(a: jax.Array, **kw) -> jax.Array:
    """Full symmetric ``a.T @ a`` (mirrors C21 into C12, per the paper)."""
    return symmetrize_from_lower(ata(a, **kw))


def ata_levels_for(m: int, n: int, leaf: int = DEFAULT_LEAF) -> int:
    """Natural recursion depth: recurse until a dim hits the leaf size."""
    leaf = max(leaf, 1)        # (1+1)//2 == 1: leaf=0 would never terminate
    lv = 0
    while m > leaf and n > leaf:
        m, n = (m + 1) // 2, (n + 1) // 2
        lv += 1
    return lv
