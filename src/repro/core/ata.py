"""ATA: the paper's cache-oblivious Strassen-based algorithm for C = A^t A.

Algorithm 1 of the paper, adapted for TPU (DESIGN.md §2):

    split A into quadrants A11 A12 / A21 A22, then
      C11 = ATA(A11) + ATA(A21)                  (recursive, symmetric)
      C22 = ATA(A12) + ATA(A22)                  (recursive, symmetric)
      C21 = HASA(A12^t, A11) + HASA(A22^t, A21)  (rectangular Strassen)
      C12 = C21^t                                (never computed)

Only the lower triangle is computed; multiplication count is upper-bounded
by (2/7) n^{log2 7} (paper §3.1) versus n^2(n+1)/2 classical.

The recursion unrolls at trace time over static shapes, capped at ``levels``.
The base case is a SYRK (half-work block gram): ``jnp.dot(a.T, a)`` under XLA
or the Pallas ``syrk`` kernel which skips upper-triangular blocks entirely.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .strassen import strassen_matmul, DEFAULT_LEAF, DEFAULT_LEVELS
from .symmetry import symmetrize_from_lower

__all__ = ["ata", "ata_full", "ata_levels_for"]


def _default_base_syrk(a: jax.Array) -> jax.Array:
    """Classical leaf gram with >=fp32 accumulation (lower triangle kept)."""
    acc = jnp.promote_types(a.dtype, jnp.float32)
    return jnp.tril(jnp.dot(a.T, a, preferred_element_type=acc))


def ata(
    a: jax.Array,
    *,
    levels: int = DEFAULT_LEVELS,
    leaf: int = DEFAULT_LEAF,
    variant: str = "strassen",
    base_syrk: Optional[Callable] = None,
    base_matmul: Optional[Callable] = None,
) -> jax.Array:
    """Lower triangle of ``a.T @ a`` via the paper's ATA recursion.

    Args:
      a: (m, n) array — general rectangular, any size.
      levels: recursion depth cap (0 => classical SYRK).
      leaf: stop recursing when m or n <= leaf (paper: 32; TPU: 256).
      variant: Strassen variant used for the off-diagonal C21 products.
      base_syrk: leaf gram fn (n-triangular); default jnp, or Pallas syrk.
      base_matmul: leaf matmul for the HASA calls.

    Returns:
      (n, n) array, strictly upper triangle zeroed, dtype promoted from a.
    """
    if a.ndim != 2:
        raise ValueError(f"ata expects a matrix, got shape {a.shape}")
    syrk = base_syrk or _default_base_syrk
    out = _ata_rec(a, levels, leaf, variant, syrk, base_matmul)
    return out.astype(a.dtype)


def _ata_rec(a, levels, leaf, variant, syrk, base_matmul):
    m, n = a.shape
    # Base case (paper: m or n <= 32; TPU leaf rescaled).
    if levels <= 0 or m <= leaf or n <= leaf:
        return syrk(a)

    # Pad odd dims (exact: zero rows of A add nothing to A^tA; zero cols add
    # zero rows+cols to C, sliced away below).
    pm, pn = m % 2, n % 2
    ap = jnp.pad(a, ((0, pm), (0, pn))) if (pm or pn) else a
    mp, np_ = ap.shape
    m2, n2 = mp // 2, np_ // 2

    a11 = ap[:m2, :n2]
    a12 = ap[:m2, n2:]
    a21 = ap[m2:, :n2]
    a22 = ap[m2:, n2:]

    rec = lambda x: _ata_rec(x, levels - 1, leaf, variant, syrk, base_matmul)

    # C11, C22: sums of two symmetric recursive grams (lines 7-10, Alg. 1).
    c11 = rec(a11) + rec(a21)
    c22 = rec(a12) + rec(a22)

    # C21: two generalized-Strassen rectangular products (lines 11-12).
    c21 = strassen_matmul(
        a12.T, a11, levels=levels - 1, leaf=leaf, variant=variant,
        base_matmul=base_matmul,
    ) + strassen_matmul(
        a22.T, a21, levels=levels - 1, leaf=leaf, variant=variant,
        base_matmul=base_matmul,
    )

    top = jnp.concatenate([c11, jnp.zeros((n2, np_ - n2), c11.dtype)], axis=1)
    bot = jnp.concatenate([c21.astype(c11.dtype), c22], axis=1)
    c = jnp.concatenate([top, bot], axis=0)
    return c[:n, :n]


def ata_full(a: jax.Array, **kw) -> jax.Array:
    """Full symmetric ``a.T @ a`` (mirrors C21 into C12, per the paper)."""
    return symmetrize_from_lower(ata(a, **kw))


def ata_levels_for(m: int, n: int, leaf: int = DEFAULT_LEAF) -> int:
    """Natural recursion depth: recurse until a dim hits the leaf size."""
    lv = 0
    while m > leaf and n > leaf:
        m, n = (m + 1) // 2, (n + 1) // 2
        lv += 1
    return lv
