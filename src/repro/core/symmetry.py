"""Packed lower-triangular storage utilities.

The paper stores only the lower triangle of C = A^t A — n(n+1)/2 words
instead of n^2. On TPU we keep the same saving but at *block* granularity so
every tile stays MXU-shaped: the packed representation is a stack of
T(T+1)/2 blocks of shape (bn, bn), ordered row-major over the lower triangle
((i, j) with i >= j, i major).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def tri_count(t: int) -> int:
    return t * (t + 1) // 2


def tri_index(i: int, j: int) -> int:
    """Linear index of lower-triangular block (i, j), i >= j."""
    if j > i:
        raise ValueError(f"upper-triangular block ({i},{j}) is never stored")
    return i * (i + 1) // 2 + j


def tri_coords(t: int) -> np.ndarray:
    """(tri_count(t), 2) int array of (i, j) for linear indices 0.. ."""
    out = np.zeros((tri_count(t), 2), dtype=np.int32)
    k = 0
    for i in range(t):
        for j in range(i + 1):
            out[k] = (i, j)
            k += 1
    return out


def pack_tril(c: jax.Array) -> jax.Array:
    """Dense symmetric/lower (n, n) -> packed vector of n(n+1)/2 entries."""
    n = c.shape[0]
    idx = jnp.tril_indices(n)
    return c[idx]


def unpack_tril(packed: jax.Array, n: int, *, symmetrize: bool = True) -> jax.Array:
    """Packed n(n+1)/2 vector -> dense (n, n); mirrors to the upper half when
    ``symmetrize`` (C12 = C21^t, per the paper)."""
    rows, cols = jnp.tril_indices(n)
    c = jnp.zeros((n, n), packed.dtype).at[rows, cols].set(packed)
    if symmetrize:
        c = c + c.T - jnp.diag(jnp.diag(c))
    return c


def pack_tril_blocks(c: jax.Array, bn: int) -> jax.Array:
    """Dense (n, n) with n % bn == 0 -> (tri_count(t)*bn, bn) block stack."""
    n = c.shape[0]
    if n % bn:
        raise ValueError(f"n={n} not divisible by block {bn}")
    t = n // bn
    blocks = [c[i * bn:(i + 1) * bn, j * bn:(j + 1) * bn]
              for i in range(t) for j in range(i + 1)]
    return jnp.concatenate(blocks, axis=0)


def unpack_tril_blocks(packed: jax.Array, n: int, bn: int,
                       *, symmetrize: bool = True) -> jax.Array:
    """Inverse of :func:`pack_tril_blocks`."""
    t = n // bn
    c = jnp.zeros((n, n), packed.dtype)
    k = 0
    for i in range(t):
        for j in range(i + 1):
            blk = jax.lax.dynamic_slice_in_dim(packed, k * bn, bn, axis=0)
            c = jax.lax.dynamic_update_slice(c, blk, (i * bn, j * bn))
            k += 1
    if symmetrize:
        # Diagonal blocks carry their own (symmetric) upper halves — drop
        # them before mirroring so they are not double-counted.
        c = jnp.tril(c)
        c = c + jnp.tril(c, -1).T
    return c


def tril_vector_from_blocks(packed: jax.Array, bn: int, n: int) -> jax.Array:
    """Element-packed tril vector (n(n+1)/2,) straight from a packed
    lower-triangular *block* stack ((tri_count(T)*bn, bn), syrk /
    fused-ATA layout over a padded T*bn >= n grid).

    One static gather — the dense (n, n) matrix never materializes, and
    (because the VJP of a gather is a scatter-add into the stack) packed
    cotangents stay packed through ``jax.grad``: this is the bridge that
    keeps ``gram.stream`` differentiable through the fused packed kernel
    without a dense round-trip.
    """
    rows, cols = np.tril_indices(n)
    bi, bj = rows // bn, cols // bn
    blk = bi * (bi + 1) // 2 + bj
    gr = jnp.asarray(blk * bn + rows % bn)
    gc = jnp.asarray(cols % bn)
    return packed[gr, gc]


def symmetrize_from_lower(c_lower: jax.Array) -> jax.Array:
    """Mirror the strict lower triangle to the upper half (C12 = C21^t)."""
    tri = jnp.tril(c_lower, -1)
    return jnp.tril(c_lower) + tri.T
